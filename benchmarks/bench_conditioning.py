"""Theorems 1-2 measured: condition number of the global Hessian vs the
FedSubAvg-preconditioned Hessian on a synthetic LR problem with controlled
heat dispersion."""
import time

import numpy as np
import jax.numpy as jnp

from repro.core.preconditioner import condition_number, preconditioned_hessian


def run():
    rng = np.random.default_rng(0)
    n_clients, m = 128, 24
    involved = rng.random((n_clients, m)) < np.geomspace(0.03, 1.0, m)
    involved[:, -1] = True
    involved[0] = True
    counts = involved.sum(axis=0).astype(np.float64)
    # per-client quadratic f_i = ||x_Si - e_i||^2 with mild anisotropy
    t0 = time.perf_counter()
    h = np.zeros((m, m))
    for i in range(n_clients):
        idx = np.where(involved[i])[0]
        a = np.eye(len(idx)) * rng.uniform(0.8, 1.2)
        hi = np.zeros((m, m))
        hi[np.ix_(idx, idx)] = 2 * a
        h += hi / n_clients
    kappa = condition_number(jnp.asarray(h))
    kappa_hat = condition_number(preconditioned_hessian(jnp.asarray(h), counts,
                                                        float(n_clients)))
    us = (time.perf_counter() - t0) * 1e6
    dispersion = counts.max() / counts.min()
    return [("conditioning/thm1_thm2", us,
             f"dispersion={dispersion:.1f};kappa={kappa:.1f};"
             f"kappa_preconditioned={kappa_hat:.2f};reduction={kappa/kappa_hat:.1f}x")]
