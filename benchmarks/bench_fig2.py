"""Paper Figure 2: FedAvg vs FedSubAvg on Example 1 (dispersion 100).

Analytic matrix-power iteration; derived field reports the loss after r
rounds for both algorithms (FedSubAvg reaches optimum, FedAvg crawls on w1).
"""
import time

import numpy as np


def run():
    n, rounds = 100, 50
    eta = gamma = 0.5
    t0 = time.perf_counter()
    w_avg = np.array([1.0, 1.0])
    w_sub = np.array([1.0, 1.0])
    for _ in range(rounds):
        w_avg = np.array([(1 - 2 * eta / n) * w_avg[0], (1 - 2 * eta) * w_avg[1]])
        w_sub = (1 - 2 * gamma) * w_sub
    us = (time.perf_counter() - t0) * 1e6
    f_avg = w_avg[0] ** 2 / n + w_avg[1] ** 2
    f_sub = w_sub[0] ** 2 / n + w_sub[1] ** 2
    return [("fig2/example1", us,
             f"rounds={rounds};fedavg_loss={f_avg:.3e};fedsubavg_loss={f_sub:.3e};"
             f"fedavg_w1={w_avg[0]:.4f}")]
