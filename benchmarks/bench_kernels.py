"""Kernel microbenchmarks: Pallas (interpret mode on CPU — correctness-path
timing, the TPU target numbers come from the roofline) vs the jnp oracle."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.kernels import ops, ref


def run():
    rng = np.random.default_rng(0)
    rows = []

    t, d, v = 4096, 128, 2048
    ids = jnp.asarray(rng.integers(0, v, t), jnp.int32)
    grads = jnp.asarray(rng.normal(0, 1, (t, d)), jnp.float32)
    heat = jnp.asarray(rng.integers(1, 50, v), jnp.float32)
    us = time_us(lambda: ops.heat_scatter(ids, grads, heat, 1e4, v))
    us_ref = time_us(lambda: ref.heat_scatter_ref(ids, grads, heat, 1e4, v))
    rows.append(("kernels/heat_scatter", us,
                 f"T={t};D={d};V={v};ref_us={us_ref:.0f};mode=interpret"))

    b, s, h, kv, hd = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd)), jnp.bfloat16)
    vv = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd)), jnp.bfloat16)
    us = time_us(lambda: ops.flash_attention(q, k, vv, blk_q=256, blk_k=256), iters=2)
    us_ref = time_us(lambda: ref.flash_attention_ref(q, k, vv), iters=2)
    rows.append(("kernels/flash_attention", us,
                 f"B={b};S={s};H={h};KV={kv};hd={hd};ref_us={us_ref:.0f};mode=interpret"))

    s_cache = 8192
    kc = jnp.asarray(rng.normal(0, 1, (b, kv, s_cache, hd)), jnp.bfloat16)
    vc = jnp.asarray(rng.normal(0, 1, (b, kv, s_cache, hd)), jnp.bfloat16)
    qd = jnp.asarray(rng.normal(0, 1, (b, h, hd)), jnp.bfloat16)
    kpos = jnp.arange(s_cache)
    us = time_us(lambda: ops.flash_decode(qd, kc, vc, kpos, s_cache - 1, blk_s=1024),
                 iters=2)
    us_ref = time_us(lambda: ref.flash_decode_ref(qd, kc, vc, kpos, s_cache - 1), iters=2)
    rows.append(("kernels/flash_decode", us,
                 f"B={b};S={s_cache};ref_us={us_ref:.0f};mode=interpret"))
    return rows
