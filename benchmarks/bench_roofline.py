"""Roofline terms per (arch x shape) on the single-pod mesh, read from the
dry-run artifact (results/dryrun.json). One row per baselined combination —
this is the §Roofline table of EXPERIMENTS.md."""
import os

from benchmarks.roofline import build_table


def run():
    path = "results/dryrun.json"
    if not os.path.exists(path):
        return [("roofline/missing", 0.0,
                 "run `python -m repro.launch.dryrun --all --out results/dryrun` first")]
    rows = []
    for r in build_table(path):
        if r.mesh != "16x16":
            continue
        derived = (f"compute_s={r.compute_s:.3e};memory_s={r.memory_s:.3e};"
                   f"collective_s={r.collective_s:.3e};bound={r.bottleneck};"
                   f"useful={r.useful_ratio:.2f};mem_dev={r.mem_per_dev_gib:.2f}GiB;"
                   f"fits={'Y' if r.fits else 'N'}")
        # us_per_call: the roofline-projected step time on the target pod
        step_s = max(r.compute_s, r.memory_s, r.collective_s)
        rows.append((f"roofline/{r.arch}/{r.shape}", step_s * 1e6, derived))
    return rows
