"""Sparse submodel update plane: aggregation backends + the server engine.

Three sections, all emitted to the CSV stream and to
``BENCH_sparse_engine.json`` (the artifact CI uploads):

1. dense vs row-sparse cohort aggregation (the PR-1 comparison): K client
   deltas over a (V, D) feature table, cohort-mean + FedSubAvg correction on
   both planes.
2. union-backend comparison for ``aggregate_rowsparse``: jnp-sort vs
   jnp-bitmap vs the fused ``union_segsum`` Pallas kernel across
   V in {65k, 262k} x density in {1%, 10%}. On CPU the kernel runs in
   interpret mode, which executes the kernel body in Python — honest but
   orders of magnitude off the compiled path — so off-TPU the pallas column
   is measured at a reduced proxy shape and labelled as such (nothing is
   silently dropped; the JSON carries the actual shape measured).
3. server engine: host-loop ``run_round`` x n vs the in-jit
   ``run_rounds(n)`` scan on a real ``FederatedTrainer`` (LSTM over a
   sent140-like corpus), wall-clock per round after warmup.

4. replicated local training: dense per-client replicas
   (``sparse_local="replicated"``, the K*V*D memory wall) vs gathered
   submodel replicas (``"sparse_replicated"``, K*capacity*D) — time per
   round and the analytic replica-memory curve at V in {65k, 262k}.

5. cohort-sharded rounds: the ``run_rounds`` engine driven single-device vs
   through ``CohortSharding`` meshes of every available power-of-two device
   count — per-round wall time vs device count, ``speedup_vs_1dev`` per
   mesh. Force virtual CPU devices with
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI smoke job
   does); with one visible device only the plain unsharded 1-device
   baseline is measured (no shard_map runs).

6. telemetry plane: the same fedsubavg sparse round with the in-jit
   ``RoundTelemetry`` counters off vs on — per-round wall time for both,
   the on/off overhead ratio, and the run-level counter summary (drop
   totals, mean union size / density). The telemetry-on trainer streams
   its round events through a ``TraceSink`` into ``BENCH_telemetry.jsonl``
   (CI uploads it as an artifact; ``check_regression`` validates the
   section's schema and that trainer-derived rounds report zero drops).

7. collective bytes: the hlo_audit oracle run as a benchmark — for each
   sharded sparse plan x combine, the HLO-measured per-kind collective bytes
   of one compiled round step, next to the analytic budget
   (``round_collective_budget``) and the contract/drift verdict. Bytes are
   static-shape-deterministic, so ``check_regression`` pins them against the
   committed baseline directly (no timing hermeticity needed): growth means
   a resharding or densified combine crept into the lowering. Needs a
   multi-device host (the forced-8 CI smoke job); skipped with a note on a
   single device.

8. buffered-async throughput: the event-stream engine (``run_async``) vs
   the synchronous barrier under a heavy-tailed log-normal delay
   distribution with injected stragglers. Two kinds of numbers: honest
   measured wall time per scanned event, and the seed-deterministic
   *modeled* makespans from the compiled schedule — clients absorbed per
   simulated time unit for both engines and their ratio (``sim_speedup``).
   The modeled ratio is machine-independent, so ``check_regression`` pins
   async > barrier directly against the committed baseline.

9. kernel roofline: achieved vs analytic bandwidth per union backend. The
   analytic bytes come from the kernel-contract plane — the pallas column is
   ``repro.analysis.kernel_audit.cost_model`` run on the ``pallas_call``
   captured out of the traced aggregate at the bench shape (so operand
   re-streaming, e.g. the heat table refetched per vocab block, is priced
   in), the jnp columns are documented closed forms over the same shapes.
   Analytic bytes/FLOPs are static-shape-deterministic, so
   ``check_regression`` pins them against the baseline directly (growth =
   re-streaming or a densified path crept in); achieved GB/s is honest
   measured wall time and stays machine-local (fresh-run sanity only).

``REPRO_BENCH_SMOKE=1`` shrinks every section to seconds of runtime (tiny V,
2 rounds, interpret-mode kernel) — the CI smoke job runs that on every PR so
the pallas backend, the scan engine and the sharded engine stay exercised.

Artifacts land under ``benchmarks/`` by default (``REPRO_BENCH_JSON`` /
``REPRO_BENCH_TELEMETRY_JSONL`` override) so bench runs never litter the
repo root.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.configs import FedConfig
from repro.core.aggregate import HeatSpec, correct_update_tree
from repro.data.synthetic import make_sent140_like
from repro.federated import (ArrivalSim, BufferedAsyncServerUpdate,
                             FederatedTrainer)
from repro.kernels import ops, ref
from repro.models.recsys import lstm_logits, lstm_loss, make_lstm_params
from repro.sparse import RowSparse, aggregate_rowsparse, tree_wire_bytes

import functools

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
_BENCH_DIR = os.path.dirname(os.path.abspath(__file__))
JSON_PATH = os.environ.get(
    "REPRO_BENCH_JSON", os.path.join(_BENCH_DIR, "BENCH_sparse_engine.json"))


def _cohort(rng, k: int, v: int, r: int, d: int):
    ids = np.full((k, r), -1, np.int32)
    rows = np.zeros((k, r, d), np.float32)
    heat = np.zeros(v, np.float32)
    for i in range(k):
        sup = np.sort(rng.choice(v, size=r, replace=False))
        ids[i] = sup
        rows[i] = rng.normal(size=(r, d)).astype(np.float32)
        heat[sup] += 1
    return jnp.asarray(ids), jnp.asarray(rows), jnp.asarray(heat)


def _bench_dense_vs_sparse(rng, out, records):
    """Section 1: the dense plane vs the row-sparse plane (PR-1 comparison)."""
    k, d, total = (4, 8, 100.0) if SMOKE else (16, 64, 100.0)
    spec = HeatSpec({"emb": ("vocab", 0)})
    vs = (4_096,) if SMOKE else (65_536, 262_144)
    densities = (0.01, 0.10) if SMOKE else (0.001, 0.01, 0.05, 0.10)

    for v in vs:
        for density in densities:
            r = max(int(v * density), 1)
            ids, rows, heat = _cohort(rng, k, v, r, d)
            stacked = RowSparse(ids, rows, v)

            sparse_fn = jax.jit(
                lambda s: aggregate_rowsparse(s, heat, total, 1.0 / k))
            us_sparse = time_us(sparse_fn, stacked, iters=3)

            # dense baseline starts from already-densified per-client deltas
            dense_in = jax.vmap(lambda i_, r_: RowSparse(i_, r_, v).to_dense())(
                ids, rows)
            counts = {"vocab": heat}
            dense_fn = jax.jit(lambda dt: correct_update_tree(
                {"emb": dt.mean(axis=0)}, spec, counts, total)["emb"])
            us_dense = time_us(dense_fn, dense_in, iters=2)

            bytes_sparse = tree_wire_bytes({"emb": stacked})
            bytes_dense = float(k * v * d * 4)
            out.append((
                "sparse/aggregate", us_sparse,
                f"V={v};density={density};K={k};D={d};us_dense={us_dense:.0f};"
                f"speedup={us_dense / us_sparse:.2f}x;"
                f"bytes_sparse={bytes_sparse:.0f};bytes_dense={bytes_dense:.0f};"
                f"wire_ratio={bytes_dense / bytes_sparse:.1f}x"))
            records.append(dict(section="dense_vs_sparse", v=v, density=density,
                                k=k, d=d, us_sparse=us_sparse,
                                us_dense=us_dense))
            del dense_in


def _bench_union_backends(rng, out, records):
    """Section 2: jnp-sort vs jnp-bitmap vs pallas union backends."""
    on_tpu = jax.default_backend() == "tpu"
    k, d, total = (4, 8, 100.0) if SMOKE else (16, 64, 100.0)
    vs = (512,) if SMOKE else (65_536, 262_144)
    for v in vs:
        for density in (0.01, 0.10):
            r = max(int(v * density), 1)
            ids, rows, heat = _cohort(rng, k, v, r, d)
            stacked = RowSparse(ids, rows, v)
            row = dict(section="union_backends", v=v, density=density, k=k, d=d)
            for backend in ("sort", "bitmap") + (("pallas",) if on_tpu or SMOKE
                                                 else ()):
                fn = jax.jit(lambda s, _b=backend: aggregate_rowsparse(
                    s, heat, total, 1.0 / k, union_backend=_b))
                us = time_us(fn, stacked, iters=3)
                mode = ("compiled" if on_tpu else "interpret") \
                    if backend == "pallas" else "xla"
                out.append((f"sparse/union_{backend}", us,
                            f"V={v};density={density};K={k};D={d};mode={mode}"))
                row[f"us_{backend}"] = us
            records.append(row)
    if not (on_tpu or SMOKE):
        # off-TPU the interpreter cannot run the paper-scale shapes in
        # reasonable time; measure the kernel at a reduced proxy shape
        v, r = 2_048, 204
        ids, rows, heat = _cohort(rng, k, v, r, d)
        stacked = RowSparse(ids, rows, v)
        fn = jax.jit(lambda s: aggregate_rowsparse(s, heat, total, 1.0 / k,
                                                   union_backend="pallas"))
        us = time_us(fn, stacked, iters=2)
        out.append(("sparse/union_pallas", us,
                    f"V={v};density={r / v:.2f};K={k};D={d};mode=interpret;"
                    f"note=proxy_shape_cpu"))
        records.append(dict(section="union_backends", v=v, density=r / v,
                            k=k, d=d, us_pallas=us, proxy=True))


def _bench_engine(out, records):
    """Section 3: host-loop round driving vs the in-jit run_rounds scan."""
    if SMOKE:
        vocab, clients, kpr, n_rounds, mean_samples = 512, 16, 4, 2, 8
    else:
        vocab, clients, kpr, n_rounds, mean_samples = 262_144, 32, 8, 8, 25
    ds = make_sent140_like(num_clients=clients, vocab=vocab,
                           mean_samples=mean_samples, seq_len=24)
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=kpr,
                    local_iters=2, local_batch=4, lr=0.3,
                    algorithm="fedsubavg", sparse=True)

    def make_trainer():
        return FederatedTrainer(
            ds, functools.partial(make_lstm_params, ds.num_features,
                                  emb_dim=16, hidden=32, layers=1),
            lstm_loss, cfg,
            predict_fn=lambda p, t: lstm_logits(
                p, jnp.asarray(t["tokens"]),
                (jnp.asarray(t["tokens"]) >= 0).astype(jnp.float32)))

    tr_loop = make_trainer()
    tr_loop.run_round()                                  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        tr_loop.run_round()
    us_loop = (time.perf_counter() - t0) / n_rounds * 1e6

    tr_scan = make_trainer()
    tr_scan.run_rounds(n_rounds)                         # warmup/compile
    t0 = time.perf_counter()
    tr_scan.run_rounds(n_rounds)
    us_scan = (time.perf_counter() - t0) / n_rounds * 1e6

    density = tr_loop.comm_summary()["mean_density"]
    out.append(("sparse/engine_host_loop", us_loop,
                f"V={vocab};K={kpr};rounds={n_rounds};density={density:.4f}"))
    out.append(("sparse/engine_in_jit", us_scan,
                f"V={vocab};K={kpr};rounds={n_rounds};density={density:.4f};"
                f"speedup={us_loop / us_scan:.2f}x"))
    records.append(dict(section="engine", v=vocab, k=kpr, rounds=n_rounds,
                        density=density, us_per_round_host_loop=us_loop,
                        us_per_round_in_jit=us_scan,
                        speedup=us_loop / us_scan))


def _bench_replicated(out, records):
    """Section 4: dense-replica vs gathered-submodel local training."""
    if SMOKE:
        shapes = ((512,),)
        clients, kpr, n_rounds, mean_samples, emb = 16, 4, 2, 8, 8
    else:
        shapes = ((65_536,), (262_144,))
        clients, kpr, n_rounds, mean_samples, emb = 32, 8, 4, 25, 16
    for (vocab,) in shapes:
        ds = make_sent140_like(num_clients=clients, vocab=vocab,
                               mean_samples=mean_samples, seq_len=24)

        def make_trainer(local_mode):
            cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=kpr,
                            local_iters=2, local_batch=4, lr=0.3,
                            algorithm="fedsubavg", sparse=True,
                            sparse_local=local_mode)
            return FederatedTrainer(
                ds, functools.partial(make_lstm_params, ds.num_features,
                                      emb_dim=emb, hidden=32, layers=1),
                lstm_loss, cfg)

        row = dict(section="replicated", v=vocab, k=kpr, d=emb,
                   rounds=n_rounds)
        for local_mode in ("replicated", "sparse_replicated"):
            tr = make_trainer(local_mode)
            tr.run_round()                               # warmup/compile
            t0 = time.perf_counter()
            for _ in range(n_rounds):
                tr.run_round()
            us = (time.perf_counter() - t0) / n_rounds * 1e6
            # replica HBM for the feature table: K*V*D dense vs K*cap*D
            rows_per_client = (min(tr._last_capacity, ds.num_features)
                               if local_mode == "sparse_replicated"
                               else ds.num_features)
            replica_bytes = kpr * rows_per_client * emb * 4
            row[f"us_{local_mode}"] = us
            row[f"replica_bytes_{local_mode}"] = replica_bytes
            out.append((f"sparse/local_{local_mode}", us,
                        f"V={vocab};K={kpr};D={emb};I=2;"
                        f"replica_bytes={replica_bytes:.0f}"))
        row["speedup"] = row["us_replicated"] / row["us_sparse_replicated"]
        row["mem_ratio"] = (row["replica_bytes_replicated"]
                            / row["replica_bytes_sparse_replicated"])
        out.append(("sparse/local_mode_win", row["speedup"],
                    f"V={vocab};mem_ratio={row['mem_ratio']:.1f}x;"
                    f"speedup={row['speedup']:.2f}x"))
        records.append(row)


def _bench_sharded(out, records):
    """Section 5: cohort-sharded run_rounds engine vs single-device.

    The cohort is sized local-phase-heavy (I=4, B=8, hidden=64): sharding
    parallelises the K clients' local training, so the win grows with local
    compute and saturates at the physical core count; the replicated server
    apply and the collectives are the fixed sharded overhead the tiny smoke
    shapes expose (speedup < 1 there is expected and gated relatively).
    """
    from repro.launch.mesh import make_cohort_mesh

    if SMOKE:
        vocab, clients, kpr, n_rounds, mean_samples, emb, hid, li, lb = (
            512, 16, 8, 2, 8, 8, 32, 2, 4)
    else:
        vocab, clients, kpr, n_rounds, mean_samples, emb, hid, li, lb = (
            65_536, 32, 16, 8, 25, 16, 64, 4, 8)
    ds = make_sent140_like(num_clients=clients, vocab=vocab,
                           mean_samples=mean_samples, seq_len=24)
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=kpr,
                    local_iters=li, local_batch=lb, lr=0.3,
                    algorithm="fedsubavg", sparse=True)

    def make_trainer(mesh):
        return FederatedTrainer(
            ds, functools.partial(make_lstm_params, ds.num_features,
                                  emb_dim=emb, hidden=hid, layers=1),
            lstm_loss, cfg, mesh=mesh)

    n_avail = len(jax.devices())
    ndevs = [n for n in (1, 2, 4, 8) if n <= n_avail]
    us_1dev = None
    for ndev in ndevs:
        mesh = None if ndev == 1 else make_cohort_mesh(ndev)
        tr = make_trainer(mesh)
        tr.run_rounds(n_rounds)                          # warmup/compile
        t0 = time.perf_counter()
        tr.run_rounds(n_rounds)
        us = (time.perf_counter() - t0) / n_rounds * 1e6
        if ndev == 1:
            us_1dev = us
        speedup = us_1dev / us
        out.append((f"sparse/sharded_engine_{ndev}dev", us,
                    f"V={vocab};K={kpr};rounds={n_rounds};ndev={ndev};"
                    f"speedup_vs_1dev={speedup:.2f}x"))
        records.append(dict(section="sharded", v=vocab, k=kpr,
                            rounds=n_rounds, ndev=ndev, us_per_round=us,
                            speedup_vs_1dev=speedup))


def _bench_telemetry(out, records):
    """Section 6: in-jit telemetry counters off vs on, plus the counters.

    Same fedsubavg sparse shapes as section 3. The counters are pure reads
    of values the round already computes, so the overhead ratio should hover
    near 1.0x; the JSONL sink receives one round event per dispatched round
    (warmup included) and lands wherever ``REPRO_BENCH_TELEMETRY_JSONL``
    points (default ``BENCH_telemetry.jsonl``).
    """
    from repro.telemetry import TraceSink

    if SMOKE:
        vocab, clients, kpr, n_rounds, mean_samples = 512, 16, 4, 2, 8
    else:
        vocab, clients, kpr, n_rounds, mean_samples = 65_536, 32, 8, 8, 25
    ds = make_sent140_like(num_clients=clients, vocab=vocab,
                           mean_samples=mean_samples, seq_len=24)
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=kpr,
                    local_iters=2, local_batch=4, lr=0.3,
                    algorithm="fedsubavg", sparse=True)

    def make_trainer(telemetry, sink=None):
        return FederatedTrainer(
            ds, functools.partial(make_lstm_params, ds.num_features,
                                  emb_dim=16, hidden=32, layers=1),
            lstm_loss, cfg, telemetry=telemetry, sink=sink)

    tr_off = make_trainer(False)
    tr_off.run_round()                                   # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        tr_off.run_round()
    us_off = (time.perf_counter() - t0) / n_rounds * 1e6

    jsonl_path = os.environ.get(
        "REPRO_BENCH_TELEMETRY_JSONL",
        os.path.join(_BENCH_DIR, "BENCH_telemetry.jsonl"))
    with TraceSink(jsonl_path) as sink:
        tr_on = make_trainer(True, sink=sink)
        tr_on.run_round()                                # warmup/compile
        t0 = time.perf_counter()
        for _ in range(n_rounds):
            tr_on.run_round()
        us_on = (time.perf_counter() - t0) / n_rounds * 1e6
        n_events = len(sink.events)
    summary = tr_on.telemetry_summary()

    overhead = us_on / us_off
    out.append(("sparse/telemetry_off", us_off,
                f"V={vocab};K={kpr};rounds={n_rounds}"))
    out.append(("sparse/telemetry_on", us_on,
                f"V={vocab};K={kpr};rounds={n_rounds};"
                f"overhead={overhead:.2f}x;"
                f"dropped_ids={summary['dropped_ids']};"
                f"mean_union={summary['mean_union_size']:.1f};"
                f"jsonl={jsonl_path}"))
    records.append(dict(section="telemetry", v=vocab, k=kpr, rounds=n_rounds,
                        us_per_round_off=us_off, us_per_round_on=us_on,
                        overhead=overhead,
                        dropped_ids=summary["dropped_ids"],
                        dropped_mass=summary["dropped_mass"],
                        mean_union_size=summary["mean_union_size"],
                        mean_density=summary["mean_density"],
                        jsonl_events=n_events, jsonl=jsonl_path))


def _bench_collectives(out, records):
    """Section 7: HLO-measured combine bytes vs the analytic budget.

    Not a timing benchmark: collective byte totals are static-shape
    deterministic, so the records double as a regression pin — the
    committed baseline's bytes must not grow (a growth is a resharding or
    a densified combine, the class the hlo_audit CI gate catches one plan
    at a time; here the whole matrix lands in the bench artifact).
    """
    import dataclasses

    from repro.analysis.hlo_audit import (collective_contract, comm_drift,
                                          lower_round_step)
    from repro.federated import CohortSharding, resolve_plan
    from repro.launch.mesh import make_cohort_mesh

    ndev = len(jax.devices())
    if ndev < 2:
        out.append(("sparse/collectives_skipped", 0.0,
                    f"ndev={ndev};needs>=2;force_with=XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8"))
        return
    vocab, emb = (512, 8) if SMOKE else (65_536, 16)
    mesh = make_cohort_mesh()
    params = make_lstm_params(vocab, emb_dim=emb, hidden=8, layers=1,
                              rng=jax.random.PRNGKey(1))
    fed = FedConfig(num_clients=16, clients_per_round=3, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    rng = np.random.default_rng(0)
    cohort_batch = {
        "tokens": jnp.asarray(rng.integers(-1, vocab, (3, 2, 2, 6)),
                              jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, (3, 2, 2)), jnp.int32),
        "heat_vocab": jnp.asarray(rng.integers(0, 6, vocab), jnp.float32)}
    flat_batch = {
        "tokens": jnp.asarray(rng.integers(0, vocab, (8, 8)), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, 8), jnp.int32),
        "heat_vocab": jnp.asarray(rng.integers(0, 6, vocab), jnp.float32)}
    for mode in ("sparse", "sparse_replicated"):
        for combine in ("psum", "union"):
            plan = dataclasses.replace(
                resolve_plan(mode, fed),
                sharding=CohortSharding(mesh, combine=combine))
            batch = flat_batch if mode == "sparse" else cohort_batch
            compiled = lower_round_step(plan, lstm_loss, params, fed, batch)
            con = collective_contract(plan, lstm_loss, params, fed, batch,
                                      compiled=compiled)
            drift = comm_drift(plan, lstm_loss, params, fed, batch,
                               compiled=compiled)
            ok = con.ok and drift.ok
            ar = con.measured_by_op.get("all-reduce", 0)
            ag = con.measured_by_op.get("all-gather", 0)
            out.append((f"sparse/collectives_{mode}_{combine}",
                        float(ar + ag),
                        f"V={vocab};D={emb};ndev={ndev};all_reduce_B={ar};"
                        f"all_gather_B={ag};ok={ok}"))
            records.append(dict(
                section="collectives", mode=mode, combine=combine, v=vocab,
                emb=emb, ndev=ndev, ok=ok,
                all_reduce_bytes=ar, all_gather_bytes=ag,
                budget_all_reduce=con.budget_by_op.get("all-reduce", 0.0),
                budget_all_gather=con.budget_by_op.get("all-gather", 0.0),
                failures=con.failures + drift.failures))


def _bench_async(out, records):
    """Section 8: buffered-async engine vs the barrier under heavy tails.

    Heavy-tailed log-normal delays (sigma=1.5) with 10% injected 10x
    stragglers — the regime where the barrier engine serialises on its
    slowest client every round. ``us_per_event`` is honest measured wall
    time for the jitted event scan; the clients-per-simulated-unit columns
    come from the schedule's deterministic makespan model, so the async >
    barrier claim is machine-independent and baseline-pinnable.
    """
    if SMOKE:
        vocab, clients, kpr, n_rounds, mean_samples = 512, 16, 4, 4, 8
    else:
        vocab, clients, kpr, n_rounds, mean_samples = 65_536, 32, 8, 12, 25
    ds = make_sent140_like(num_clients=clients, vocab=vocab,
                           mean_samples=mean_samples, seq_len=24)
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=kpr,
                    local_iters=2, local_batch=4, lr=0.3,
                    algorithm="fedsubavg", sparse=True)
    tr = FederatedTrainer(
        ds, functools.partial(make_lstm_params, ds.num_features,
                              emb_dim=16, hidden=32, layers=1),
        lstm_loss, cfg)
    sim = ArrivalSim(num_rounds=n_rounds, delay="lognormal", delay_scale=0.5,
                     lognormal_sigma=1.5, straggler_frac=0.1,
                     straggler_factor=10.0, seed=0)
    srv = BufferedAsyncServerUpdate(buffer_size=max(kpr // 2, 1),
                                    staleness="polynomial", heat="ema")
    sch = sim.compile(kpr, srv.buffer_size)

    tr.run_async(sim, server=srv)                        # warmup/compile
    t0 = time.perf_counter()
    tr.run_async(sim, server=srv)
    us_event = (time.perf_counter() - t0) / sch.num_events * 1e6

    barrier, asynchronous = sch.barrier_makespan(), sch.async_makespan()
    per_unit_barrier = sch.num_arrivals / barrier
    per_unit_async = sch.num_arrivals / asynchronous
    out.append(("sparse/async_event_scan", us_event,
                f"V={vocab};K={kpr};M={srv.buffer_size};"
                f"events={sch.num_events};fires={sch.num_fires}"))
    out.append(("sparse/async_sim_speedup", sch.sim_speedup(),
                f"barrier_makespan={barrier:.2f};"
                f"async_makespan={asynchronous:.2f};"
                f"clients_per_unit={per_unit_async:.3f}vs"
                f"{per_unit_barrier:.3f}"))
    records.append(dict(
        section="async", v=vocab, k=kpr, rounds=n_rounds,
        buffer=srv.buffer_size, events=sch.num_events, fires=sch.num_fires,
        arrivals=sch.num_arrivals, us_per_event=us_event,
        barrier_makespan=barrier, async_makespan=asynchronous,
        clients_per_unit_barrier=per_unit_barrier,
        clients_per_unit_async=per_unit_async,
        sim_speedup=sch.sim_speedup()))


def _ceil_log2(x: int) -> int:
    return max(int(x) - 1, 1).bit_length()


def _bench_kernel_roofline(rng, out, records):
    """Section 9: analytic bytes/FLOPs vs achieved bandwidth per backend.

    One record per (shape, union backend). ``analytic_bytes`` for the pallas
    backend is the kernel-audit cost model evaluated on the ``pallas_call``
    captured from the traced aggregate (re-streaming priced in via the grid
    x BlockSpec fetch counts); the jnp backends get closed forms: the
    payload movement every backend pays (stream ids + rows in, gather heat
    at the union, write the union out) plus the backend's union-structure
    cost — bitmap: mark/cumsum/nonzero passes over the (V,) bitmap plus the
    rank gather; sort: ~log2(T) read+write key passes plus the
    binary-search remap. Achieved GB/s divides the analytic bytes by
    measured wall time; off-TPU at full shapes the pallas interpreter would
    crawl, so that cell is analytic-only (``us`` absent, nothing silently
    dropped).
    """
    from repro.analysis import kernel_audit
    from repro.common.hw import HW

    on_tpu = jax.default_backend() == "tpu"
    k, d, total = (4, 8, 100.0) if SMOKE else (16, 64, 100.0)
    vs = (512,) if SMOKE else (65_536,)
    densities = (0.10,) if SMOKE else (0.01, 0.10)
    for v in vs:
        for density in densities:
            r = max(int(v * density), 1)
            ids, rows, heat = _cohort(rng, k, v, r, d)
            stacked = RowSparse(ids, rows, v)
            t = k * r
            cap = min(v, t)
            payload = (t + t * d) * 4 + cap * 4 + (cap + cap * d) * 4
            payload_flops = float(t * d + 2 * cap * d)
            analytic = {
                # (V,) bool mark written then read twice (cumsum, bounded
                # nonzero), (V,) i32 rank written, (T,) i32 rank gather
                "bitmap": payload + v * (1 + 2 + 4) + t * 4,
                # ~log2(T) read+write passes over the (T,) i32 keys, then a
                # log2(cap) binary-search remap per element
                "sort": payload + (2 * _ceil_log2(t) + _ceil_log2(cap)) * t * 4,
            }
            flops = {
                "bitmap": payload_flops + float(v),
                "sort": payload_flops + float(t * _ceil_log2(t)),
            }
            restream = {}
            caps = kernel_audit.capture_pallas_calls(
                lambda s: aggregate_rowsparse(s, heat, total, 1.0 / k,
                                              union_backend="pallas"),
                stacked)
            cost = kernel_audit.cost_model(caps[0], kernel="union_segsum")
            analytic["pallas"] = cost.bytes_touched
            flops["pallas"] = cost.flops
            restream["pallas"] = max(
                op["restream"] for op in cost.per_operand.values())

            for backend in ("sort", "bitmap", "pallas"):
                rec = dict(section="kernel_roofline", v=v, density=density,
                           k=k, d=d, backend=backend,
                           analytic_bytes=int(analytic[backend]),
                           analytic_flops=flops[backend],
                           intensity=flops[backend] / analytic[backend],
                           restream=restream.get(backend, 1.0))
                timed = backend != "pallas" or on_tpu or SMOKE
                tail = ""
                if timed:
                    fn = jax.jit(lambda s, _b=backend: aggregate_rowsparse(
                        s, heat, total, 1.0 / k, union_backend=_b))
                    us = time_us(fn, stacked, iters=3)
                    achieved = analytic[backend] / (us * 1e-6)
                    rec.update(us=us, achieved_gbps=achieved / 1e9,
                               hbm_frac=achieved / HW["hbm_bandwidth"])
                    tail = (f";achieved_GBps={achieved / 1e9:.2f}"
                            f";hbm_frac={achieved / HW['hbm_bandwidth']:.4f}")
                else:
                    rec["analytic_only"] = True
                    tail = ";note=analytic_only_off_tpu"
                out.append((f"sparse/roofline_{backend}", rec.get("us", 0.0),
                            f"V={v};density={density};K={k};D={d};"
                            f"analytic_B={rec['analytic_bytes']};"
                            f"restream={rec['restream']:.1f}x" + tail))
                records.append(rec)


def run():
    out = []
    records = []
    rng = np.random.default_rng(0)
    # production-shaped round: 16-client cohort, 64-wide embedding rows.
    # Dense cohort aggregation is then DRAM-bound on the cold rows nobody
    # touched — exactly the inefficiency the sparse plane removes.
    _bench_dense_vs_sparse(rng, out, records)
    _bench_union_backends(rng, out, records)
    _bench_engine(out, records)
    _bench_replicated(out, records)
    _bench_sharded(out, records)
    _bench_telemetry(out, records)
    _bench_collectives(out, records)
    _bench_async(out, records)
    _bench_kernel_roofline(rng, out, records)

    # Pallas kernel (dense-output TPU path) at a kernel-friendly shape
    k, d, total = (4, 8, 100.0) if SMOKE else (16, 64, 100.0)
    v, r = (256, 32) if SMOKE else (2_048, 256)
    ids, rows, heat = _cohort(rng, k, v, r, d)
    flat_ids, flat_rows = ids.reshape(-1), rows.reshape(k * r, d)
    us_kern = time_us(
        lambda: ops.rowsparse_scatter(flat_ids, flat_rows, heat, total, v,
                                      scale=1.0 / k, v_blk=512, t_blk=512),
        iters=2)
    us_ref = time_us(
        lambda: jax.jit(ref.rowsparse_scatter_ref,
                        static_argnames=("total", "vocab", "scale"))(
            flat_ids, flat_rows, heat, total, v, scale=1.0 / k), iters=2)
    mode = "compiled" if jax.default_backend() == "tpu" else "interpret"
    out.append(("sparse/rowsparse_scatter_kernel", us_kern,
                f"V={v};T={k * r};D={d};ref_us={us_ref:.0f};mode={mode}"))

    with open(JSON_PATH, "w") as f:
        json.dump({"backend": jax.default_backend(), "smoke": SMOKE,
                   "records": records}, f, indent=2)
    out.append(("sparse/engine_json", 0.0, f"path={JSON_PATH}"))
    return out
