"""Sparse submodel update plane: dense vs row-sparse cohort aggregation.

Measures the server's per-round aggregation step — K client deltas over a
(V, D) feature table, cohort-mean + FedSubAvg heat correction — on both
planes:

dense   the seed path: per-client dense deltas, ``mean(axis=0)`` then
        ``correct_update_tree`` (O(K V D) touched floats, K*V*D*4 wire bytes)
sparse  the repro.sparse path: per-client (ids, rows), union segment-sum with
        fused correction (O(K R D) floats, K*R*(4 + D*4) wire bytes)

Also times the generalized Pallas ``rowsparse_scatter`` kernel (interpret
mode on CPU — the TPU-compiled path is selected automatically at runtime)
against its jnp oracle at a kernel-friendly shape.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.core.aggregate import HeatSpec, correct_update_tree
from repro.kernels import ops, ref
from repro.sparse import RowSparse, aggregate_rowsparse, tree_wire_bytes


def _cohort(rng, k: int, v: int, r: int, d: int):
    ids = np.full((k, r), -1, np.int32)
    rows = np.zeros((k, r, d), np.float32)
    heat = np.zeros(v, np.float32)
    for i in range(k):
        sup = np.sort(rng.choice(v, size=r, replace=False))
        ids[i] = sup
        rows[i] = rng.normal(size=(r, d)).astype(np.float32)
        heat[sup] += 1
    return jnp.asarray(ids), jnp.asarray(rows), jnp.asarray(heat)


def run():
    out = []
    rng = np.random.default_rng(0)
    # production-shaped round: 16-client cohort, 64-wide embedding rows.
    # Dense cohort aggregation is then DRAM-bound on the cold rows nobody
    # touched — exactly the inefficiency the sparse plane removes.
    k, d, total = 16, 64, 100.0
    spec = HeatSpec({"emb": ("vocab", 0)})

    for v in (65_536, 262_144):
        for density in (0.001, 0.01, 0.05, 0.10):
            r = max(int(v * density), 1)
            ids, rows, heat = _cohort(rng, k, v, r, d)
            stacked = RowSparse(ids, rows, v)

            sparse_fn = jax.jit(
                lambda s: aggregate_rowsparse(s, heat, total, 1.0 / k))
            us_sparse = time_us(sparse_fn, stacked, iters=3)

            # dense baseline starts from already-densified per-client deltas
            dense_in = jax.vmap(lambda i_, r_: RowSparse(i_, r_, v).to_dense())(
                ids, rows)
            counts = {"vocab": heat}
            dense_fn = jax.jit(lambda dt: correct_update_tree(
                {"emb": dt.mean(axis=0)}, spec, counts, total)["emb"])
            us_dense = time_us(dense_fn, dense_in, iters=2)

            bytes_sparse = tree_wire_bytes({"emb": stacked})
            bytes_dense = float(k * v * d * 4)
            out.append((
                "sparse/aggregate", us_sparse,
                f"V={v};density={density};K={k};D={d};us_dense={us_dense:.0f};"
                f"speedup={us_dense / us_sparse:.2f}x;"
                f"bytes_sparse={bytes_sparse:.0f};bytes_dense={bytes_dense:.0f};"
                f"wire_ratio={bytes_dense / bytes_sparse:.1f}x"))
            del dense_in

    # Pallas kernel (dense-output TPU path) at a kernel-friendly shape
    v, r = 2_048, 256
    ids, rows, heat = _cohort(rng, k, v, r, d)
    flat_ids, flat_rows = ids.reshape(-1), rows.reshape(k * r, d)
    us_kern = time_us(
        lambda: ops.rowsparse_scatter(flat_ids, flat_rows, heat, total, v,
                                      scale=1.0 / k, v_blk=512, t_blk=512),
        iters=2)
    us_ref = time_us(
        lambda: jax.jit(ref.rowsparse_scatter_ref,
                        static_argnames=("total", "vocab", "scale"))(
            flat_ids, flat_rows, heat, total, v, scale=1.0 / k), iters=2)
    mode = "compiled" if jax.default_backend() == "tpu" else "interpret"
    out.append(("sparse/rowsparse_scatter_kernel", us_kern,
                f"V={v};T={k * r};D={d};ref_us={us_ref:.0f};mode={mode}"))
    return out
