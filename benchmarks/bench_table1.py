"""Paper Table 1: dataset statistics incl. feature heat dispersion.

The container has no internet, so the four datasets are the statistically
matched synthetics (see repro/data/synthetic.py); this benchmark verifies the
regime (clients / samples-per-client / dispersion) and times generation.
"""
import time

from repro.data.synthetic import DATASETS


def run():
    rows = []
    for name in ("movielens", "sent140", "amazon", "alibaba"):
        t0 = time.perf_counter()
        ds = DATASETS[name]()
        us = (time.perf_counter() - t0) * 1e6
        s = ds.stats()
        derived = (f"clients={s['clients']};samples={s['samples']};"
                   f"per_client={s['samples_per_client']:.1f};"
                   f"dispersion={s['dispersion']:.0f};coverage={s['coverage']:.2f}")
        rows.append((f"table1/{name}", us, derived))
    return rows
