"""Paper Table 2 / Figure 3: communication rounds to reach a target train
loss for CentralSGD / FedAvg / FedProx / Scaffold / FedAdam / FedSubAvg.

Protocol matches the paper at reduced scale: target = CentralSGD's best loss
(x1.02 slack); `R+` (here max_rounds+1) marks targets not reached.
"""
from repro.data import make_movielens_like
from benchmarks.common import rounds_to_target

ALGOS = ("central", "fedavg", "fedprox", "scaffold", "fedadam", "fedsubavg")
MAX_ROUNDS = 60


def run():
    ds = make_movielens_like(num_clients=150, num_items=120, mean_samples=30)
    rows = []
    # central run defines the target
    central_rounds, central_best, central_wall = rounds_to_target(
        ds, "central", target_loss=-1.0, max_rounds=MAX_ROUNDS)
    target = central_best * 1.02
    rows.append(("table2/movielens/central",
                 central_wall * 1e6 / MAX_ROUNDS,
                 f"best_loss={central_best:.4f};target={target:.4f}"))
    for alg in ALGOS[1:]:
        kw = {"server_lr": 1.0}
        if alg == "fedadam":
            kw.update(server_lr=0.03)
        r, best, wall = rounds_to_target(ds, alg, target, MAX_ROUNDS, fed_kw=kw)
        plus = "+" if r > MAX_ROUNDS else ""
        rows.append((f"table2/movielens/{alg}", wall * 1e6 / max(r, 1),
                     f"rounds={min(r, MAX_ROUNDS)}{plus};best_loss={best:.4f}"))
    return rows
