"""Paper Table 3 / Figure 4: FedSubAvg with varying participation K."""
from repro.data import make_movielens_like
from benchmarks.common import rounds_to_target

MAX_ROUNDS = 60


def run():
    ds = make_movielens_like(num_clients=150, num_items=120, mean_samples=30)
    # shared target from a K=10 central baseline
    _, central_best, _ = rounds_to_target(ds, "central", -1.0, MAX_ROUNDS)
    target = central_best * 1.05
    rows = []
    for k in (5, 10, 30):
        r, best, wall = rounds_to_target(ds, "fedsubavg", target, MAX_ROUNDS,
                                         fed_kw={"clients_per_round": k})
        plus = "+" if r > MAX_ROUNDS else ""
        rows.append((f"table3/movielens/K={k}", wall * 1e6 / max(r, 1),
                     f"rounds={min(r, MAX_ROUNDS)}{plus};best_loss={best:.4f}"))
    return rows
