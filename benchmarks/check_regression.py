"""Bench-smoke regression gate: fresh BENCH_sparse_engine.json vs baseline.

Compares the union-backend and in-jit-engine sections of a fresh smoke-mode
``bench_sparse`` run against the committed baseline
(``benchmarks/BENCH_baseline_smoke.json``) and fails on a >25% regression.

Hermeticity: raw microseconds are machine-speed-dependent (a CI runner is not
the machine the baseline was recorded on), so the gate compares
*within-run relative* metrics only — quantities in which the host's absolute
speed cancels:

- union_backends: each backend's time normalised by the SAME record's
  ``us_sort`` (the jnp sort backend is the in-run reference). A code change
  that slows the bitmap or pallas path shows up as a ratio regression no
  matter how fast the runner is. Proxy-shape records (off-TPU pallas runs
  without an in-run reference) are skipped.
- engine: the host-loop / in-jit ``speedup`` column. The in-jit scan losing
  ground against the per-round loop is a regression regardless of runner.
- sharded: each mesh size's ``speedup_vs_1dev`` column (sharded engine time
  normalised by the SAME run's 1-device engine time). The sharded round step
  losing ground against its own single-device baseline is a regression
  regardless of runner. Both runs must see the same device count
  (``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in CI); a mesh
  size present in the baseline but absent from the fresh run fails the gate.

Both runs must use the same smoke shapes (``REPRO_BENCH_SMOKE=1``); records
are matched on their shape keys and a missing match fails the gate.

- collectives: the per-plan collective byte totals (section 7) are
  static-shape-deterministic, not machine-speed-dependent, so they compare
  directly: the fresh run's measured bytes must not exceed the baseline's
  by more than the threshold (growth = a resharding or densified combine
  crept into the lowering), and every fresh record must carry ``ok: true``
  (its own contract + drift verdict). Both runs must see the same forced
  device count, same as the sharded section.

- async: the buffered-async section's ``sim_speedup`` (modeled barrier /
  async makespan ratio) is seeded-schedule-deterministic, so the gate pins
  the acceptance claim directly — async must absorb clients faster than the
  barrier (``sim_speedup > 1``) under the heavy-tailed straggler schedule —
  and compares the ratio against the baseline like the other sections.

- kernel_roofline: the per-backend analytic bytes/FLOPs (section 9) come
  from static shapes — the kernel-audit cost model for the pallas backend,
  closed forms for the jnp backends — so they compare directly: fresh
  analytic bytes (or the pallas max-restream factor) exceeding the
  baseline's by more than the threshold means operand re-streaming or a
  densified path crept into the aggregation. Achieved GB/s is wall-clock
  and machine-local, so it is sanity-checked on the fresh run only.

The telemetry section is validated on the FRESH run only (no baseline
ratio): the record must carry the full counter schema, a trainer-derived
run must report zero capacity drops (the trainer sizes ``sub_ids`` to fit,
so any nonzero ``dropped_ids`` means the accounting or the capacity
derivation broke), union size must be positive, and the JSONL sink must
have received at least one event per timed round. The existing ratio gates
above are untouched.

Usage:
    python -m benchmarks.check_regression BENCH_sparse_engine.json \
        [--baseline benchmarks/BENCH_baseline_smoke.json] [--threshold 0.25]
"""
from __future__ import annotations

import argparse
import json
import sys

_UNION_KEY = ("v", "density", "k", "d")
_ENGINE_KEY = ("v", "k", "rounds")
_ASYNC_KEY = ("v", "k", "rounds", "buffer")
_SHARDED_KEY = ("v", "k", "rounds", "ndev")
_COLLECTIVES_KEY = ("mode", "combine", "v", "emb", "ndev")
_ROOFLINE_KEY = ("v", "density", "k", "d", "backend")

#: byte columns of a collectives record the gate pins against the baseline
_COLLECTIVES_BYTES = ("all_reduce_bytes", "all_gather_bytes")

#: every field a telemetry record must carry (section 6 of bench_sparse)
_TELEMETRY_FIELDS = (
    "v", "k", "rounds", "us_per_round_off", "us_per_round_on", "overhead",
    "dropped_ids", "dropped_mass", "mean_union_size", "mean_density",
    "jsonl_events", "jsonl",
)


def _index(records, section, key_fields):
    out = {}
    for r in records:
        if r.get("section") != section or r.get("proxy"):
            continue
        out[tuple(r.get(f) for f in key_fields)] = r
    return out


def _union_ratios(rec):
    """Per-backend time relative to the in-run sort reference."""
    ref = rec.get("us_sort")
    if not ref:
        return {}
    return {k: rec[k] / ref for k in rec
            if k.startswith("us_") and k != "us_sort"}


def check(fresh: dict, baseline: dict, threshold: float):
    failures = []

    # a baseline that lacks a whole section the fresh bench emits means the
    # committed file is stale or truncated: every comparison in that section
    # would be skipped silently and the gate would pass vacuously. Fail by
    # section name instead (telemetry is fresh-only by design, not listed).
    fresh_sections = {r.get("section") for r in fresh.get("records", [])}
    base_sections = {r.get("section") for r in baseline.get("records", [])}
    for section in ("union_backends", "engine", "sharded", "collectives",
                    "async", "kernel_roofline"):
        if section in fresh_sections and section not in base_sections:
            failures.append(
                f"baseline has no '{section}' section but the fresh run "
                f"emits one — the committed baseline is stale or truncated; "
                "regenerate it with REPRO_BENCH_SMOKE=1 bench_sparse")

    fresh_u = _index(fresh.get("records", []), "union_backends", _UNION_KEY)
    base_u = _index(baseline.get("records", []), "union_backends", _UNION_KEY)
    if not fresh_u:
        failures.append("fresh run has no union_backends records")
    for key, brec in base_u.items():
        frec = fresh_u.get(key)
        if frec is None:
            failures.append(f"union_backends record missing from fresh run: {key}")
            continue
        bratios, fratios = _union_ratios(brec), _union_ratios(frec)
        for name, bval in bratios.items():
            fval = fratios.get(name)
            if fval is None:
                failures.append(f"union_backends {key}: fresh run lacks {name}")
            elif fval > bval * (1.0 + threshold):
                failures.append(
                    f"union_backends {key} {name}/us_sort regressed "
                    f"{bval:.3f} -> {fval:.3f} (>{threshold:.0%})")

    fresh_e = _index(fresh.get("records", []), "engine", _ENGINE_KEY)
    base_e = _index(baseline.get("records", []), "engine", _ENGINE_KEY)
    if not fresh_e:
        failures.append("fresh run has no engine records")
    for key, brec in base_e.items():
        frec = fresh_e.get(key)
        if frec is None:
            failures.append(f"engine record missing from fresh run: {key}")
            continue
        bsp, fsp = brec.get("speedup"), frec.get("speedup")
        if bsp and not fsp:
            # a missing/zero speedup must fail loudly, same as the union
            # section — a silently skipped comparison is exactly the
            # regression class this gate exists to catch
            failures.append(f"engine {key}: fresh run lacks a usable speedup "
                            f"(got {fsp!r})")
        elif bsp and fsp < bsp / (1.0 + threshold):
            failures.append(
                f"engine {key} in-jit speedup regressed "
                f"{bsp:.2f}x -> {fsp:.2f}x (>{threshold:.0%})")

    fresh_s = _index(fresh.get("records", []), "sharded", _SHARDED_KEY)
    base_s = _index(baseline.get("records", []), "sharded", _SHARDED_KEY)
    if base_s and not fresh_s:
        failures.append("fresh run has no sharded records")
    for key, brec in base_s.items():
        frec = fresh_s.get(key)
        if frec is None:
            failures.append(f"sharded record missing from fresh run: {key} "
                            "(device-count mismatch? run under the same "
                            "XLA_FLAGS forced device count)")
            continue
        bsp, fsp = brec.get("speedup_vs_1dev"), frec.get("speedup_vs_1dev")
        if bsp and not fsp:
            failures.append(f"sharded {key}: fresh run lacks a usable "
                            f"speedup_vs_1dev (got {fsp!r})")
        elif bsp and fsp < bsp / (1.0 + threshold):
            failures.append(
                f"sharded {key} speedup_vs_1dev regressed "
                f"{bsp:.2f}x -> {fsp:.2f}x (>{threshold:.0%})")

    fresh_c = _index(fresh.get("records", []), "collectives",
                     _COLLECTIVES_KEY)
    base_c = _index(baseline.get("records", []), "collectives",
                    _COLLECTIVES_KEY)
    if base_c and not fresh_c:
        failures.append("fresh run has no collectives records "
                        "(device-count mismatch? run under the same "
                        "XLA_FLAGS forced device count)")
    for key, brec in base_c.items():
        frec = fresh_c.get(key)
        if frec is None:
            failures.append(f"collectives record missing from fresh run: "
                            f"{key}")
            continue
        if not frec.get("ok"):
            failures.append(
                f"collectives {key}: contract/drift verdict is not ok: "
                f"{frec.get('failures')}")
        for col in _COLLECTIVES_BYTES:
            bval, fval = brec.get(col, 0), frec.get(col, 0)
            if bval and fval > bval * (1.0 + threshold):
                failures.append(
                    f"collectives {key} {col} grew {bval} -> {fval} B "
                    f"(>{threshold:.0%}): a resharding or densified "
                    "combine crept into the lowering")

    # async: the modeled makespans are schedule-deterministic (seeded sim,
    # no wall clock involved), so the acceptance claim — async absorbs
    # clients faster than the barrier under heavy-tailed delays with
    # stragglers — is pinned directly, plus a ratio gate vs the baseline.
    fresh_a = _index(fresh.get("records", []), "async", _ASYNC_KEY)
    base_a = _index(baseline.get("records", []), "async", _ASYNC_KEY)
    if not fresh_a:
        failures.append("fresh run has no async records")
    for key, frec in fresh_a.items():
        fsp = frec.get("sim_speedup")
        if not fsp or not fsp > 1.0:
            failures.append(
                f"async {key}: sim_speedup must exceed 1.0 under the "
                f"heavy-tailed straggler schedule (got {fsp!r}) — the "
                "buffered engine no longer beats the barrier")
        if not frec.get("us_per_event", 0) > 0:
            failures.append(f"async {key}: non-positive us_per_event")
        if frec.get("fires", 0) < 1:
            failures.append(f"async {key}: schedule produced no buffer "
                            "fires — the section measured nothing")
    for key, brec in base_a.items():
        frec = fresh_a.get(key)
        if frec is None:
            failures.append(f"async record missing from fresh run: {key}")
            continue
        bsp, fsp = brec.get("sim_speedup"), frec.get("sim_speedup")
        if bsp and fsp and fsp < bsp / (1.0 + threshold):
            failures.append(
                f"async {key} sim_speedup regressed {bsp:.2f}x -> "
                f"{fsp:.2f}x (>{threshold:.0%}): the schedule model or the "
                "sim defaults changed")

    # kernel_roofline: analytic bytes/FLOPs are static-shape-deterministic
    # (cost model for pallas, closed forms for the jnp backends) — growth
    # means operand re-streaming or a densified path crept in. Achieved
    # bandwidth is machine-local: fresh-run sanity only.
    fresh_r = _index(fresh.get("records", []), "kernel_roofline",
                     _ROOFLINE_KEY)
    base_r = _index(baseline.get("records", []), "kernel_roofline",
                    _ROOFLINE_KEY)
    if not fresh_r:
        failures.append("fresh run has no kernel_roofline records")
    for key, frec in fresh_r.items():
        if not frec.get("analytic_bytes", 0) > 0:
            failures.append(f"kernel_roofline {key}: non-positive "
                            f"analytic_bytes ({frec.get('analytic_bytes')!r})")
        if not frec.get("analytic_only") and not frec.get(
                "achieved_gbps", 0) > 0:
            failures.append(f"kernel_roofline {key}: timed record with "
                            "non-positive achieved_gbps")
    for key, brec in base_r.items():
        frec = fresh_r.get(key)
        if frec is None:
            failures.append(f"kernel_roofline record missing from fresh "
                            f"run: {key}")
            continue
        bval, fval = brec.get("analytic_bytes", 0), frec.get(
            "analytic_bytes", 0)
        if bval and fval > bval * (1.0 + threshold):
            failures.append(
                f"kernel_roofline {key} analytic_bytes grew {bval} -> "
                f"{fval} B (>{threshold:.0%}): operand re-streaming or a "
                "densified path crept into the aggregation")
        brs, frs = brec.get("restream", 0.0), frec.get("restream", 0.0)
        if brs and frs > brs * (1.0 + threshold):
            failures.append(
                f"kernel_roofline {key} max restream grew {brs:.1f}x -> "
                f"{frs:.1f}x (>{threshold:.0%}): an operand is streamed "
                "through VMEM more often per invocation than the baseline "
                "kernel")

    failures.extend(check_telemetry(fresh))
    return failures


def check_telemetry(fresh: dict):
    """Fresh-only validation of the telemetry section (no baseline ratio)."""
    failures = []
    recs = [r for r in fresh.get("records", [])
            if r.get("section") == "telemetry"]
    if not recs:
        failures.append("fresh run has no telemetry records")
    for rec in recs:
        key = (rec.get("v"), rec.get("k"), rec.get("rounds"))
        missing = [f for f in _TELEMETRY_FIELDS if f not in rec]
        if missing:
            failures.append(f"telemetry {key}: record missing fields "
                            f"{missing}")
            continue
        # trainer-derived sub_ids always fit their pow2 capacity: any drop
        # means the accounting or the capacity derivation broke
        if rec["dropped_ids"] != 0 or rec["dropped_mass"] != 0.0:
            failures.append(
                f"telemetry {key}: trainer-derived run reports nonzero "
                f"capacity drops (dropped_ids={rec['dropped_ids']}, "
                f"dropped_mass={rec['dropped_mass']})")
        if not rec["mean_union_size"] > 0:
            failures.append(f"telemetry {key}: mean_union_size must be "
                            f"positive (got {rec['mean_union_size']!r})")
        if not 0.0 < rec["mean_density"] <= 1.0:
            failures.append(f"telemetry {key}: mean_density out of (0, 1] "
                            f"(got {rec['mean_density']!r})")
        # warmup + timed rounds each emit one JSONL round event
        if rec["jsonl_events"] < rec["rounds"]:
            failures.append(
                f"telemetry {key}: JSONL sink saw {rec['jsonl_events']} "
                f"events for {rec['rounds']} timed rounds")
        if not rec["us_per_round_on"] > 0 or not rec["us_per_round_off"] > 0:
            failures.append(f"telemetry {key}: non-positive per-round times")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="path to the freshly generated bench JSON")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline_smoke.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed relative regression (0.25 = 25%%)")
    args = ap.parse_args(argv)
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    if fresh.get("smoke") != baseline.get("smoke"):
        print(f"smoke-mode mismatch: fresh={fresh.get('smoke')} "
              f"baseline={baseline.get('smoke')}", file=sys.stderr)
        return 1
    failures = check(fresh, baseline, args.threshold)
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        return 1
    print("bench-smoke regression gate: OK "
          f"(threshold {args.threshold:.0%}, baseline {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
