"""Shared benchmark utilities: timing + the rounds-to-target protocol."""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig
from repro.data.synthetic import FederatedDataset
from repro.federated import FederatedTrainer
from repro.models.recsys import (din_logits, din_loss, lr_logits, lr_loss,
                                 lstm_logits, lstm_loss, make_din_params,
                                 make_lr_params, make_lstm_params)


def time_us(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def task_bindings(ds: FederatedDataset):
    """(make_params, loss, predict) for the dataset's task."""
    if ds.task == "lr":
        return (functools.partial(make_lr_params, ds.num_features), lr_loss,
                lambda p, t: lr_logits(p, jnp.asarray(t["features"])))
    if ds.task == "lstm":
        return (functools.partial(make_lstm_params, ds.num_features), lstm_loss,
                lambda p, t: lstm_logits(p, jnp.asarray(t["tokens"]),
                                         (jnp.asarray(t["tokens"]) >= 0).astype(jnp.float32)))
    if ds.task == "din":
        return (functools.partial(make_din_params, ds.num_features), din_loss,
                lambda p, t: din_logits(p, jnp.asarray(t["hist"]), jnp.asarray(t["target"])))
    raise ValueError(ds.task)


def rounds_to_target(ds: FederatedDataset, algorithm: str, target_loss: float,
                     max_rounds: int, fed_kw: Optional[Dict] = None,
                     eval_every: int = 5, seed: int = 0) -> Tuple[int, float, float]:
    """Returns (rounds or max_rounds+, best train loss, wall time s)."""
    mk, loss_fn, predict = task_bindings(ds)
    kw = dict(num_clients=ds.num_clients, clients_per_round=10, local_iters=5,
              local_batch=5, lr=0.5, algorithm=algorithm)
    kw.update(fed_kw or {})
    tr = FederatedTrainer(ds, mk, loss_fn, FedConfig(**kw), predict_fn=predict,
                          metric="auc", rng_seed=seed)
    t0 = time.perf_counter()
    best = float("inf")
    reached = None
    for r in range(max_rounds):
        tr.run_round()
        if (r + 1) % eval_every == 0:
            cur = tr.train_loss(num_batches=4, batch=256)
            best = min(best, cur)
            if cur <= target_loss and reached is None:
                reached = r + 1
                break
    wall = time.perf_counter() - t0
    return (reached if reached is not None else max_rounds + 1, best, wall)
