"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

    compute term    = FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HBM bytes / (chips x 819 GB/s)
    collective term = collective bytes / (chips x 50 GB/s per ICI link)

Sources & corrections
---------------------
``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified: a
10-iteration scanned matmul reports ~1 matmul of FLOPs), so raw HLO numbers
undercount scan-over-layers models by ~L. We therefore report BOTH:

  * ``hlo_flops`` / ``hlo_bytes`` — raw compiled numbers (body-once), and
  * analytic totals from the model structure (validated against unrolled
    small-config HLO in tests/test_roofline.py), used for the terms.

Collective bytes come from the loop-aware HLO parser (repro.launch.hlo),
which multiplies collectives inside while bodies by XLA's recorded
``known_trip_count`` — exact, no correction needed. Collective shapes in the
partitioned module are per-device shards already.

MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) is reported alongside the
analytic total; their ratio exposes remat/attention/dispatch overhead.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

from repro.common.hw import HW
from repro.configs import SHAPES, get_config


def analytic_flops(cfg, shape_name: str) -> Dict[str, float]:
    """Structural FLOP count for one step of the lowered program."""
    sc = SHAPES[shape_name]
    return analytic_flops_for(cfg, sc.kind, sc.global_batch, sc.seq_len)


def analytic_flops_for(cfg, kind: str, b: int, s: int) -> Dict[str, float]:
    class _SC:
        pass
    sc = _SC()
    sc.kind, sc.global_batch, sc.seq_len = kind, b, s
    pc = cfg.param_counts()
    hd = cfg.head_dim

    def attn_flops(tokens, kv_len, heads):
        # qk + pv matmuls: 2 * 2 * tokens * kv_len * hd per head
        return 4.0 * tokens * kv_len * hd * heads

    if sc.kind == "train":
        tokens = float(b) * s
        # matmul fwd = 2*active_params*tokens ; bwd = 2x fwd ; remat ~ +1 fwd
        matmul = 2.0 * pc["active"] * tokens * (3.0 + 1.0)
        kv_len = min(cfg.sliding_window, s) if cfg.sliding_window else s
        causal_frac = 0.5 if not cfg.sliding_window else 1.0
        attn = 0.0
        if cfg.family != "ssm":
            n_attn = (cfg.num_layers if cfg.family != "hybrid"
                      else cfg.num_layers // max(cfg.attn_every, 1))
            attn = attn_flops(tokens, kv_len, cfg.num_heads) * n_attn \
                * causal_frac * 4.0          # fwd+bwd+remat
        return {"total": matmul + attn, "matmul": matmul, "attn": attn,
                "model_flops": 6.0 * pc["active"] * tokens}

    if sc.kind == "prefill":
        tokens = float(b) * s
        matmul = 2.0 * pc["active"] * tokens
        kv_len = min(cfg.sliding_window, s) if cfg.sliding_window else s
        attn = 0.0
        if cfg.family != "ssm":
            n_attn = (cfg.num_layers if cfg.family != "hybrid"
                      else cfg.num_layers // max(cfg.attn_every, 1))
            frac = 0.5 if not cfg.sliding_window else 1.0
            attn = attn_flops(tokens, kv_len, cfg.num_heads) * n_attn * frac
        return {"total": matmul + attn, "matmul": matmul, "attn": attn,
                "model_flops": 2.0 * pc["active"] * tokens}

    # decode: one token per sequence
    tokens = float(b)
    matmul = 2.0 * pc["active"] * tokens
    kv_len = min(cfg.sliding_window, s) if cfg.sliding_window else s
    attn = 0.0
    if cfg.family != "ssm":
        n_attn = (cfg.num_layers if cfg.family != "hybrid"
                  else cfg.num_layers // max(cfg.attn_every, 1))
        attn = attn_flops(tokens, kv_len, cfg.num_heads) * n_attn
    if cfg.family in ("ssm", "hybrid"):
        # recurrent state update ~ 6 * state_size per token per layer
        di = cfg.ssm_expand * cfg.d_model
        state = cfg.ssm_heads * (di // max(cfg.ssm_heads, 1)) * max(cfg.ssm_state, di // max(cfg.ssm_heads, 1))
        attn += 6.0 * state * tokens * cfg.num_layers
    return {"total": matmul + attn, "matmul": matmul, "attn": attn,
            "model_flops": 2.0 * pc["active"] * tokens}


def analytic_hbm_bytes(cfg, shape_name: str) -> float:
    """Dominant HBM traffic per step per *cluster* (bytes)."""
    sc = SHAPES[shape_name]
    b, s = sc.global_batch, sc.seq_len
    pc = cfg.param_counts()
    dt = 2.0  # bf16
    if sc.kind == "train":
        # read params + write params + read/write f32 grad accumulation
        p_traffic = pc["total"] * (dt * 2 + 4 * 2)
        act = 3.0 * b * s * cfg.d_model * dt * cfg.num_layers    # residual rd/wr
        return p_traffic + act
    if sc.kind == "prefill":
        kv = 2.0 * b * min(cfg.sliding_window or s, s) * cfg.num_kv_heads * cfg.head_dim \
            * dt * cfg.num_layers
        return pc["total"] * dt + 2.0 * b * s * cfg.d_model * dt * cfg.num_layers + kv
    # decode: every live param + the whole KV cache is read once per token
    kv_len = min(cfg.sliding_window, s) if cfg.sliding_window else s
    kv = 2.0 * b * kv_len * cfg.num_kv_heads * cfg.head_dim * dt * cfg.num_layers
    if cfg.family == "hybrid":
        kv = kv / max(cfg.attn_every, 1)
        kv += b * cfg.num_layers * cfg.ssm_heads * (cfg.ssm_expand * cfg.d_model //
                                                    max(cfg.ssm_heads, 1)) * cfg.ssm_state * 4
    if cfg.family == "ssm":
        kv = b * cfg.num_layers * (cfg.ssm_expand * cfg.d_model) ** 2 // max(cfg.ssm_heads, 1) * 4
    return pc["active"] * dt + kv


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    analytic_flops: float
    hlo_flops: float
    useful_ratio: float
    fits: bool
    mem_per_dev_gib: float

    def as_dict(self):
        return self.__dict__.copy()


def roofline_from_record(rec: Dict) -> Optional[RooflineRow]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    chips = rec["num_devices"]
    af = analytic_flops(cfg, rec["shape"])
    hbm = analytic_hbm_bytes(cfg, rec["shape"])
    compute_s = af["total"] / (chips * HW["peak_flops_bf16"])
    memory_s = hbm / (chips * HW["hbm_bandwidth"])
    # parsed collective bytes are per-device already (post-partitioning)
    coll_s = rec["collectives"]["total_collective_bytes"] / HW["ici_bandwidth"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mem_dev = (rec["memory"]["argument_size_in_bytes"]
               + rec["memory"]["temp_size_in_bytes"]) / 2**30
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops=af["model_flops"], analytic_flops=af["total"],
        hlo_flops=rec["flops"],
        useful_ratio=af["model_flops"] / max(af["total"], 1.0),
        fits=mem_dev <= HW["hbm_bytes"] / 2**30,
        mem_per_dev_gib=mem_dev,
    )


def build_table(dryrun_json: str = "results/dryrun.json"):
    recs = json.load(open(dryrun_json))
    rows = []
    for rec in recs:
        row = roofline_from_record(rec)
        if row:
            rows.append(row)
    return rows


def main():
    rows = build_table()
    print(f"{'arch':28s} {'shape':12s} {'mesh':8s} "
          f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
          f"{'bound':>10s} {'useful':>7s} {'mem/dev':>8s} fits")
    for r in rows:
        print(f"{r.arch:28s} {r.shape:12s} {r.mesh:8s} "
              f"{r.compute_s:10.3e} {r.memory_s:10.3e} {r.collective_s:10.3e} "
              f"{r.bottleneck:>10s} {r.useful_ratio:7.2f} "
              f"{r.mem_per_dev_gib:7.2f}G {'Y' if r.fits else 'N'}")
    out = [r.as_dict() for r in rows]
    os.makedirs("results", exist_ok=True)
    json.dump(out, open("results/roofline.json", "w"), indent=1)
    with open("results/roofline_table.md", "w") as f:
        f.write("| arch | shape | mesh | compute_s | memory_s | collective_s "
                "| bound | useful | mem/dev | fits |\n|---|---|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            f.write(f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} "
                    f"| {r.memory_s:.3e} | {r.collective_s:.3e} | {r.bottleneck} "
                    f"| {r.useful_ratio:.2f} | {r.mem_per_dev_gib:.2f}G "
                    f"| {'Y' if r.fits else 'N'} |\n")
    print("wrote results/roofline.json + results/roofline_table.md")


if __name__ == "__main__":
    main()
