"""Benchmark driver: one suite per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
"""
from __future__ import annotations

import sys
import time
import traceback

SUITES = (
    "benchmarks.bench_fig2",
    "benchmarks.bench_table1",
    "benchmarks.bench_conditioning",
    "benchmarks.bench_kernels",
    "benchmarks.bench_sparse",
    "benchmarks.bench_table2",
    "benchmarks.bench_table3",
    "benchmarks.bench_roofline",
)


def main() -> None:
    import importlib
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for modname in SUITES:
        if only and only not in modname:
            continue
        try:
            mod = importlib.import_module(modname)
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{modname},nan,ERROR:{type(e).__name__}", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
