"""Async quickstart: buffered-async FedSubAvg under heavy-tailed client delays.

The synchronous engine pays the barrier: every round waits for its slowest
client, so a single 10x straggler stalls the whole cohort. The buffered-async
engine dispatches waves at a fixed cadence and applies a staleness-weighted
server update every ``buffer_size`` arrivals instead — stragglers land late
(down-weighted by ``1/(1+s)^a``) and dropouts simply never land.

Three runs on the same MovieLens-like task:

1. synchronous FedSubAvg baseline (``run_rounds`` via ``run``),
2. the degeneracy check — a zero-delay async run with ``buffer_size = K``
   reproduces the synchronous losses (same math, same RNG stream),
3. buffered-async under a lognormal delay model with stragglers + dropouts,
   polynomial staleness weighting and streaming (EMA) heat, reporting the
   modeled barrier-vs-async makespans.

    PYTHONPATH=src python examples/async_quickstart.py
    PYTHONPATH=src python examples/async_quickstart.py --rounds 6 --clients 40  # CI
"""
import argparse
import functools

from repro.configs import FedConfig
from repro.data import make_movielens_like
from repro.federated import (ArrivalSim, BufferedAsyncServerUpdate,
                             FederatedTrainer, RoundPlan, RowSparseTransport,
                             ServerUpdate, SubmodelReplicatedLocal)
from repro.models.recsys import lr_loss, make_lr_params


def make_trainer(ds):
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=8,
                    local_iters=3, local_batch=5, lr=0.5,
                    algorithm="fedsubavg")
    plan = RoundPlan(SubmodelReplicatedLocal(),
                     RowSparseTransport(),
                     ServerUpdate("fedsubavg"))
    mk = functools.partial(make_lr_params, ds.num_features)
    return FederatedTrainer(ds, mk, lr_loss, cfg, plan=plan)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=80)
    ap.add_argument("--items", type=int, default=60)
    args = ap.parse_args()

    ds = make_movielens_like(num_clients=args.clients, num_items=args.items,
                             mean_samples=20)
    print(f"dataset: {ds.stats()}")

    # 1. synchronous barrier baseline (the in-jit scan engine)
    tr = make_trainer(ds)
    sync_losses = tr.run_rounds(args.rounds)
    print(f"==> sync fedsubavg: final loss={sync_losses[-1]:.4f}")

    # 2. the pinned degeneracy: zero delay + buffer_size=K == run_rounds
    tr2 = make_trainer(ds)
    zero = ArrivalSim(num_rounds=args.rounds, delay="zero", seed=0)
    async_losses = tr2.run_async(zero)
    drift = max(abs(a - b) for a, b in zip(sync_losses, async_losses))
    print(f"==> zero-delay async (M=K): max |loss drift| vs sync = {drift:.2e}")
    assert drift < 1e-5, "zero-delay degeneracy broke"

    # 3. heavy-tailed delays + stragglers + dropouts, staleness-weighted
    tr3 = make_trainer(ds)
    sim = ArrivalSim(num_rounds=args.rounds, delay="lognormal",
                     delay_scale=0.5, lognormal_sigma=1.5,
                     straggler_frac=0.1, straggler_factor=10.0,
                     dropout_frac=0.05, seed=0)
    srv = BufferedAsyncServerUpdate(algorithm="fedsubavg",
                                    buffer_size=4,
                                    staleness="polynomial",
                                    staleness_alpha=0.5,
                                    heat="ema", heat_beta=0.05)
    losses = tr3.run_async(sim, server=srv)
    sch = sim.compile(tr3.cfg.clients_per_round, srv.buffer_size)
    print(f"==> buffered-async fedsubavg: {len(losses)} fires, "
          f"final loss={losses[-1]:.4f}")
    print(f"    modeled makespan: barrier={sch.barrier_makespan():.1f} "
          f"async={sch.async_makespan():.1f} "
          f"(speedup {sch.sim_speedup():.2f}x)")


if __name__ == "__main__":
    main()
