"""Paper Example 1 / Figure 2: the ill-conditioning mechanism, end to end.

Simulates the two-parameter problem with N clients where w1 is involved by a
single client (heat dispersion = N): FedAvg's update of w1 is attenuated by
1/N while FedSubAvg's correction restores it. Also prints the measured
condition numbers (Theorems 1-2).

    PYTHONPATH=src python examples/example1_illconditioning.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.preconditioner import condition_number, preconditioned_hessian


def main():
    n, rounds, lr = 100, 60, 0.5
    counts = np.array([1.0, float(n)])

    # H = (1/N) sum_i H_i = diag(2/N, 2) -> kappa = N
    h = jnp.diag(jnp.asarray([2.0 / n, 2.0]))
    print(f"kappa(H)              = {condition_number(h):8.1f}   (Theorem 1: >= N = {n})")
    h_hat = preconditioned_hessian(h, counts, float(n))
    print(f"kappa(D^1/2 H D^1/2)  = {condition_number(h_hat):8.2f}   (Theorem 2: Theta(1))")

    w_avg = np.array([1.0, 1.0])
    w_sub = np.array([1.0, 1.0])
    print(f"\n{'round':>5s} {'FedAvg w1':>10s} {'FedSubAvg w1':>13s}")
    for r in range(1, rounds + 1):
        g = np.array([2 * w_avg[0] / n, 2 * w_avg[1]])     # aggregated mean grad
        w_avg = w_avg - lr * g
        g = np.array([2 * w_sub[0] / n, 2 * w_sub[1]]) * (n / counts)
        w_sub = w_sub - lr * g
        if r % 10 == 0 or r == 1:
            print(f"{r:5d} {w_avg[0]:10.4f} {w_sub[0]:13.4g}")
    print("\nFedAvg's cold parameter decays as (1-1/N)^r; FedSubAvg reaches the"
          " optimum in one step — the Figure 2 picture.")


if __name__ == "__main__":
    main()
