"""End-to-end driver (deliverable b): federated training of a ~100M-param
decoder LM with FedSubAvg for a few hundred rounds on a Zipf-heat federated
corpus, with checkpointing and FedAvg comparison.

The model is the qwen2.5 family at ~100M scale (8 layers, d=512, vocab 8192);
one round = one FedSGD cohort step (Algorithm 1 with I=1), exactly the
computation the pod dry-run lowers at 14B-400B scale.

The round is expressed as a ``RoundPlan`` — the execution-plan API behind
both ``make_round_step`` and ``FederatedTrainer``. ``--sparse`` switches the
transport to the row-sparse submodel plane, and ``--topk`` / ``--int8``
compose compression onto it (a combination the legacy mode strings never
expressed), with the round's comm bytes priced by the transport.

    PYTHONPATH=src python examples/federated_llm.py [--rounds 200]
    PYTHONPATH=src python examples/federated_llm.py --sparse --topk 256
    PYTHONPATH=src python examples/federated_llm.py --smoke --rounds 2
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import FedConfig, get_config
from repro.data import make_lm_federated
from repro.federated import (DenseTransport, FedSgdLocal, RoundPlan,
                             RowSparseTransport, ServerUpdate,
                             make_round_step, plan_comm_meta)
from repro.models import build_model
from repro.common.pytree import tree_size


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--arch", default="qwen2_5_14b")
    ap.add_argument("--algorithm", default="fedsubavg",
                    choices=["fedsubavg", "fedavg"])
    ap.add_argument("--sparse", action="store_true",
                    help="row-sparse submodel transport (gather-before-backward)")
    ap.add_argument("--topk", type=int, default=0,
                    help="top-k delta-row compression (implies --sparse)")
    ap.add_argument("--int8", action="store_true",
                    help="int8 stochastic-rounding rows (implies --sparse)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + corpus for CI (seconds of CPU)")
    ap.add_argument("--ckpt", default="results/fed_llm_ckpt")
    args = ap.parse_args()

    # ~100M-parameter member of the assigned family (tiny under --smoke)
    if args.smoke:
        cfg = get_config(args.arch).replace(
            num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=512, dtype="float32",
            query_chunk=64, kv_chunk=64)
        clients, seq_len, cohort = 32, 32, 8
    else:
        cfg = get_config(args.arch).replace(
            num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
            d_ff=1408, vocab_size=8192, dtype="float32", query_chunk=128,
            kv_chunk=128)
        clients, seq_len, cohort = 256, 128, 16
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={tree_size(params)/1e6:.1f}M")

    ds = make_lm_federated(num_clients=clients, vocab=cfg.vocab_size,
                           seq_len=seq_len, samples_per_client=4, zipf_a=1.3)
    print(f"corpus: {ds.stats()}")

    fed = FedConfig(num_clients=ds.num_clients, clients_per_round=cohort,
                    lr=0.05, algorithm=args.algorithm)
    sparse = args.sparse or args.topk > 0 or args.int8
    transport = (RowSparseTransport(topk=args.topk, int8=args.int8)
                 if sparse else DenseTransport())
    plan = RoundPlan(FedSgdLocal(), transport, ServerUpdate(args.algorithm))
    print(f"plan: {plan.describe()}")
    step = jax.jit(make_round_step(api.loss, params, fed, mode=plan))
    heat = jnp.asarray(ds.heat.counts, jnp.float32)
    rng = np.random.default_rng(0)

    t0 = time.time()
    for r in range(args.rounds):
        ids = rng.choice(ds.num_clients, size=fed.clients_per_round, replace=False)
        sample = rng.integers(0, ds.client_data["tokens"].shape[1],
                              size=fed.clients_per_round)
        toks = ds.client_data["tokens"][ids, sample]
        params, metrics = step(params, {"tokens": jnp.asarray(toks),
                                        "heat_vocab": heat})
        if (r + 1) % 20 == 0 or args.smoke:
            line = (f"round {r+1:4d}  loss={float(metrics['loss']):.4f}  "
                    f"({(time.time()-t0)/(r+1):.2f}s/round)")
            if sparse:
                line += f"  density={float(metrics['density']):.3f}"
            print(line, flush=True)

    if sparse and args.rounds > 0:
        # price the last round's wire traffic through the plan's transport
        meta = plan_comm_meta(params)
        counts = np.asarray([int(metrics["sub_rows"])])
        stats = transport.round_comm(args.rounds, meta, counts,
                                     cfg.vocab_size)
        print(f"comm (last round, cohort as one union): "
              f"up {stats.bytes_up_sparse/1e6:.2f} MB sparse vs "
              f"{stats.bytes_up_dense/1e6:.2f} MB dense "
              f"({stats.up_ratio:.1f}x)")

    save_checkpoint(args.ckpt, params, step=args.rounds,
                    extra={"arch": cfg.name, "algorithm": args.algorithm})
    print(f"saved checkpoint to {args.ckpt}.npz")


if __name__ == "__main__":
    main()
