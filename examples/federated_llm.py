"""End-to-end driver (deliverable b): federated training of a ~100M-param
decoder LM with FedSubAvg for a few hundred rounds on a Zipf-heat federated
corpus, with checkpointing and FedAvg comparison.

The model is the qwen2.5 family at ~100M scale (8 layers, d=512, vocab 8192);
one round = one FedSGD cohort step (Algorithm 1 with I=1), exactly the
computation the pod dry-run lowers at 14B-400B scale.

    PYTHONPATH=src python examples/federated_llm.py [--rounds 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import FedConfig, get_config
from repro.data import make_lm_federated
from repro.federated import make_round_step
from repro.models import build_model
from repro.common.pytree import tree_size


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--arch", default="qwen2_5_14b")
    ap.add_argument("--algorithm", default="fedsubavg",
                    choices=["fedsubavg", "fedavg"])
    ap.add_argument("--ckpt", default="results/fed_llm_ckpt")
    args = ap.parse_args()

    # ~100M-parameter member of the assigned family
    cfg = get_config(args.arch).replace(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1408, vocab_size=8192, dtype="float32", query_chunk=128, kv_chunk=128)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={tree_size(params)/1e6:.1f}M")

    ds = make_lm_federated(num_clients=256, vocab=cfg.vocab_size, seq_len=128,
                           samples_per_client=4, zipf_a=1.3)
    print(f"corpus: {ds.stats()}")

    fed = FedConfig(num_clients=ds.num_clients, clients_per_round=16, lr=0.05,
                    algorithm=args.algorithm)
    step = jax.jit(make_round_step(api.loss, params, fed, mode="fedsgd",
                                   correct=args.algorithm == "fedsubavg"))
    heat = jnp.asarray(ds.heat.counts, jnp.float32)
    rng = np.random.default_rng(0)

    t0 = time.time()
    for r in range(args.rounds):
        ids = rng.choice(ds.num_clients, size=fed.clients_per_round, replace=False)
        sample = rng.integers(0, ds.client_data["tokens"].shape[1],
                              size=fed.clients_per_round)
        toks = ds.client_data["tokens"][ids, sample]
        params, metrics = step(params, {"tokens": jnp.asarray(toks),
                                        "heat_vocab": heat})
        if (r + 1) % 20 == 0:
            print(f"round {r+1:4d}  loss={float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0)/(r+1):.2f}s/round)", flush=True)

    save_checkpoint(args.ckpt, params, step=args.rounds,
                    extra={"arch": cfg.name, "algorithm": args.algorithm})
    print(f"saved checkpoint to {args.ckpt}.npz")


if __name__ == "__main__":
    main()
