"""Quickstart: FedSubAvg vs FedAvg on a MovieLens-like federated rating task.

Runs in ~1 minute on CPU and reproduces the paper's headline result: under
feature heat dispersion the heat-corrected aggregation converges much faster.
The third run drives the trainer through an explicit ``RoundPlan`` — the
execution-plan API — composing the paper's submodel-replica local training
with top-k compressed row-sparse transport.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --rounds 8 --clients 40  # CI
"""
import argparse
import functools

import jax.numpy as jnp

from repro.configs import FedConfig
from repro.data import make_movielens_like
from repro.federated import (FederatedTrainer, RoundPlan, RowSparseTransport,
                             ServerUpdate, SubmodelReplicatedLocal)
from repro.models.recsys import lr_logits, lr_loss, make_lr_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=150)
    ap.add_argument("--items", type=int, default=100)
    args = ap.parse_args()

    ds = make_movielens_like(num_clients=args.clients, num_items=args.items,
                             mean_samples=30)
    print(f"dataset: {ds.stats()}")

    mk = functools.partial(make_lr_params, ds.num_features)
    predict = lambda p, t: lr_logits(p, jnp.asarray(t["features"]))
    eval_every = max(args.rounds // 4, 1)

    for alg in ("fedavg", "fedsubavg"):
        cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=10,
                        local_iters=5, local_batch=5, lr=0.5, algorithm=alg)
        tr = FederatedTrainer(ds, mk, lr_loss, cfg, predict_fn=predict, metric="auc")
        tr.run(args.rounds, eval_every=eval_every, verbose=True)
        h = tr.history[-1]
        print(f"==> {alg}: loss={h.train_loss:.4f} auc={h.test_metric:.4f} "
              f"(dispersion={ds.heat.dispersion():.0f})\n")

    # the same trainer driven by an explicit execution plan: submodel-replica
    # local training + top-k compressed row-sparse transport, comm priced
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=10,
                    local_iters=5, local_batch=5, lr=0.5,
                    algorithm="fedsubavg")
    plan = RoundPlan(SubmodelReplicatedLocal(),
                     RowSparseTransport(topk=16),
                     ServerUpdate("fedsubavg"))
    tr = FederatedTrainer(ds, mk, lr_loss, cfg, predict_fn=predict,
                          metric="auc", plan=plan)
    tr.run(args.rounds, eval_every=eval_every, verbose=True)
    h, s = tr.history[-1], tr.comm_summary()
    print(f"==> plan [{tr.plan.describe()}]: loss={h.train_loss:.4f} "
          f"auc={h.test_metric:.4f} uplink {s['bytes_up_sparse']/1e6:.2f} MB "
          f"sparse vs {s['bytes_up_dense']/1e6:.2f} MB dense "
          f"({s['up_ratio']:.1f}x)")


if __name__ == "__main__":
    main()
