"""Quickstart: FedSubAvg vs FedAvg on a MovieLens-like federated rating task.

Runs in ~1 minute on CPU and reproduces the paper's headline result: under
feature heat dispersion the heat-corrected aggregation converges much faster.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax.numpy as jnp

from repro.configs import FedConfig
from repro.data import make_movielens_like
from repro.federated import FederatedTrainer
from repro.models.recsys import lr_logits, lr_loss, make_lr_params


def main():
    ds = make_movielens_like(num_clients=150, num_items=100, mean_samples=30)
    print(f"dataset: {ds.stats()}")

    mk = functools.partial(make_lr_params, ds.num_features)
    predict = lambda p, t: lr_logits(p, jnp.asarray(t["features"]))

    for alg in ("fedavg", "fedsubavg"):
        cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=10,
                        local_iters=5, local_batch=5, lr=0.5, algorithm=alg)
        tr = FederatedTrainer(ds, mk, lr_loss, cfg, predict_fn=predict, metric="auc")
        tr.run(40, eval_every=10, verbose=True)
        h = tr.history[-1]
        print(f"==> {alg}: loss={h.train_loss:.4f} auc={h.test_metric:.4f} "
              f"(dispersion={ds.heat.dispersion():.0f})\n")


if __name__ == "__main__":
    main()
