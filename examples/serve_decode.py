"""Serving example: batched prefill + greedy decode with every cache family.

Exercises the same serve path the decode_32k / long_500k dry-run shapes lower
(dense KV cache, sliding-window ring buffer, Mamba2/xLSTM recurrent states,
whisper cross-attention cache) at smoke scale on CPU.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model

ARCHS = ("mistral_large_123b", "mixtral_8x22b", "zamba2_1_2b", "xlstm_350m",
         "whisper_large_v3")


def main():
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        b, prompt, gen = 4, 32, 16
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, prompt),
                                              0, cfg.vocab_size)}
        if cfg.frontend == "audio_frames":
            batch["frames"] = 0.02 * jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                              jnp.dtype(cfg.dtype))
        cache = api.init_cache(b, prompt + gen)
        prefill = jax.jit(api.prefill)
        decode = jax.jit(api.decode_step)

        logits, cache = prefill(params, batch, cache)
        t0 = time.time()
        out = []
        for _ in range(gen):
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            logits, cache = decode(params, cache, {"tokens": nxt})
            out.append(nxt)
        dt = (time.time() - t0) / gen
        toks = jnp.stack(out, axis=1)
        print(f"{arch:22s} cache={type(cache).__name__:13s} "
              f"{dt*1e3:7.1f} ms/token  sample={toks[0][:8].tolist()}")


if __name__ == "__main__":
    main()
