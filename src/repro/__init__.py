"""repro: Federated Submodel Optimization (FedSubAvg) as a multi-pod JAX framework.

Reproduction of "Federated Submodel Optimization for Hot and Cold Data Features"
(Ding et al., NeurIPS 2022), extended into a production-grade federated training /
serving framework for embedding-heavy models on TPU pods.

Public API surface:
    repro.configs      -- architecture + federated configs (``get_config(name)``)
    repro.core         -- heat statistics, aggregation, server algorithms
    repro.federated    -- client/server round runtime and pod-scale simulation
    repro.models       -- the model zoo (10 assigned architectures + paper models)
    repro.kernels      -- Pallas TPU kernels (validated in interpret mode on CPU)
    repro.launch       -- mesh construction, dry-run, train/serve drivers
"""

__version__ = "1.0.0"
