"""repro.analysis: correctness tooling for the jitted federated round path.

Five layers, each machine-checking a bug class this repo has actually
shipped (see DESIGN.md "Static analysis & sanitizer" for the rule table):

``repro.analysis.lint``
    AST-based jit-hygiene linter, stdlib-only so the CI gate runs without
    jax installed: ``python -m repro.analysis.lint src/``.

``repro.analysis.jaxpr_audit``
    Compiled-artifact auditor: walks a built round step's closed jaxpr for
    dense ``(V, D)`` intermediates on RowSparse plans, checks donation
    actually aliased in the lowered HLO, and provides ``jit_cache_guard``
    (compile-count pinning across traced-hyperparameter sweeps).

``repro.analysis.sanitize``
    ``checkify``-wired runtime sanitizer behind ``RoundPlan(
    debug_checks=True)``: validates the RowSparse contract in-jit at the
    plane boundaries, bit-identical to the unchecked step when clean.

``repro.analysis.hlo_audit``
    Comm & memory oracle over the COMPILED artifact: collective-budget
    contracts, peak-live-byte gating via ``compiled.memory_analysis()``,
    and a drift check pinning the comm-accounting plane to the bytes the
    optimized HLO actually moves:
    ``python -m repro.analysis.hlo_audit --json contract-report.json``.

``repro.analysis.kernel_audit``
    Kernel contract plane: static Pallas VMEM/race/cost auditor over every
    ``pallas_call`` in ``repro.kernels`` — VMEM budget + guard-drift
    contract, Megacore grid-semantics race detector, and the analytic
    bytes/FLOPs cost model behind ``bench_sparse``'s kernel roofline:
    ``python -m repro.analysis.kernel_audit --json kernel-audit.json``.

Submodules are imported lazily: ``lint`` must stay importable in an
environment without jax, so this package must not pull the jax-dependent
layers at import time.
"""
from __future__ import annotations

_SUBMODULES = ("lint", "jaxpr_audit", "sanitize", "hlo_audit",
               "kernel_audit")

__all__ = list(_SUBMODULES)


def __getattr__(name):
    if name in _SUBMODULES:
        import importlib

        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
