"""Comm & memory oracle: contracts over the COMPILED round step (layer 4).

The analysis plane's first three layers stop before XLA: the linter reads
source, the jaxpr auditor reads traces, the sanitizer checks runtime values.
None of them would catch a silently inserted resharding all-gather of the
``(V, D)`` feature table — the exact failure mode that voids FedSubAvg's
O(rows-touched) transport claim. This layer closes the gap by auditing the
optimized HLO and the compiler's own memory analysis against analytic
budgets the plan derives from first principles:

- :func:`collective_contract` — lowers a ``CohortSharding`` round step,
  inventories every collective (loop-aware, async-pair-aware, attributed to
  mesh axes via ``replica_groups``), and checks the inventory against
  ``federated.plan.round_collective_budget``. Any collective KIND the plan
  didn't predict (an XLA resharding, an accidentally densified combine) or
  any byte total above budget is a named failure.
- :func:`memory_contract` — gates ``compiled.memory_analysis()`` peak live
  bytes against an analytic budget (params in/out + batch + per-table
  combine workspace + K·capacity·D submodel replicas + slack), catching
  dense-replica regressions before anything runs.
- :func:`comm_drift` — cross-checks the HLO-measured collective bytes
  against the comm-accounting plane's own prediction
  (``sparse.comm.sharded_combine_bytes`` from ``plan_comm_meta``), so the
  paper-facing byte accounting can never silently diverge from what the
  compiled artifact moves. Tolerance: 10% relative + 64 B absolute (the
  absolute term absorbs the loss / sub-row scalar reductions the
  comm plane deliberately does not price).

CLI (the CI gate)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.analysis.hlo_audit --json contract-report.json

runs the {sparse, sparse_replicated} x {fedavg, fedsubavg} x {psum, union}
matrix on the cohort mesh and exits non-zero on any contract failure.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import ServerState
from repro.federated.plan import (build_round_step, plan_comm_meta,
                                  round_collective_budget, sparse_table_paths,
                                  heat_spec_from_axes, round_capacity,
                                  split_heat_batch)
from repro.launch.hlo import analyze_hlo, mesh_axis_groups
from repro.sharding.logical import unbox
from repro.sparse.comm import sharded_combine_bytes
from repro.sparse.encode import tree_leaf_at

__all__ = [
    "ContractReport", "MemoryReport", "DriftReport", "lower_round_step",
    "collective_contract", "memory_contract", "memory_budget", "comm_drift",
    "main",
]


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def lower_round_step(plan, loss_fn, boxed_params, cfg, batch, *,
                     sub_ids=None, in_shardings=None, telemetry=False):
    """Lower + compile one round step exactly as the engine would run it.

    ``in_shardings`` (optional) is passed to ``jax.jit`` — the oracle's
    planted-violation tests use it to force a resharding the budget did not
    predict. Returns the compiled executable (``.as_text()`` /
    ``.memory_analysis()``).
    """
    step = build_round_step(plan, loss_fn, boxed_params, cfg,
                            telemetry=telemetry)
    state = ServerState(boxed_params, (), jnp.zeros((), jnp.int32))
    kw = {} if in_shardings is None else {"in_shardings": in_shardings}
    jitted = jax.jit(step, **kw)
    args = (state, batch) if sub_ids is None else (state, batch, sub_ids)
    return jitted.lower(*args).compile()


# ---------------------------------------------------------------------------
# collective contract
# ---------------------------------------------------------------------------


@dataclass
class ContractReport:
    """One plan's collective inventory vs its analytic budget."""

    plan: str
    budget_by_op: Dict[str, float]
    measured_by_op: Dict[str, int]
    by_axis: Dict[str, int]
    components: Dict[str, Dict]
    failures: List[str] = field(default_factory=list)
    unresolved_loops: int = 0

    @property
    def ok(self) -> bool:
        return not self.failures

    def to_dict(self) -> Dict:
        return {
            "plan": self.plan, "ok": self.ok,
            "budget_by_op": self.budget_by_op,
            "measured_by_op": self.measured_by_op,
            "by_axis": self.by_axis,
            "components": self.components,
            "failures": self.failures,
            "unresolved_loops": self.unresolved_loops,
        }


def collective_contract(plan, loss_fn, boxed_params, cfg, batch, *,
                        sub_ids=None, compiled=None, in_shardings=None,
                        slack_rel: float = 0.05,
                        slack_abs: float = 256.0) -> ContractReport:
    """Check a sharded round step's compiled collectives against its budget.

    The budget (``round_collective_budget``) was verified byte-exact against
    the compiled HLO for every {transport, combine} pair, so the default
    slack is tight: 5% relative + 256 B absolute. Three failure classes,
    each named after the offending collective:

    - an op under a while loop whose trip count XLA could not resolve
      (its multiplier — hence its bytes — is unverifiable);
    - a collective KIND outside the budget's ``allowed_ops`` (the
      resharding / densification class);
    - a predicted kind whose measured bytes exceed budget + slack.
    """
    budget = round_collective_budget(plan, boxed_params, cfg, batch,
                                     sub_ids=sub_ids)
    if compiled is None:
        compiled = lower_round_step(plan, loss_fn, boxed_params, cfg, batch,
                                    sub_ids=sub_ids, in_shardings=in_shardings)
    rep = analyze_hlo(compiled.as_text())
    rep.attribute_axes(mesh_axis_groups(plan.sharding.mesh))

    failures: List[str] = []
    for c in rep.collectives:
        if not c.resolved:
            failures.append(
                f"{c.op} %{c.name} in %{c.computation} sits under a while "
                f"loop with no known trip count: its {c.out_bytes} B/iter "
                "cannot be budgeted")
    allowed = set(budget["allowed_ops"])
    measured = rep.by_op()
    for op, nbytes in sorted(measured.items()):
        if op not in allowed:
            names = [f"%{c.name}" for c in rep.collectives if c.op == op]
            failures.append(
                f"unbudgeted collective kind '{op}' ({nbytes} B: "
                f"{', '.join(names)}) — the {budget['combine'] or 'dense'} "
                f"combine plan only allows {sorted(allowed)}; an XLA "
                "resharding or a densified combine slipped in")
            continue
        cap = budget["by_op"].get(op, 0.0) * (1.0 + slack_rel) + slack_abs
        if nbytes > cap:
            failures.append(
                f"'{op}' moves {nbytes} B, budget allows "
                f"{budget['by_op'].get(op, 0.0):.0f} B "
                f"(+{slack_rel:.0%}/+{slack_abs:.0f} B slack)")
    return ContractReport(
        plan=repr(plan), budget_by_op=budget["by_op"],
        measured_by_op=measured, by_axis=rep.by_axis(),
        components=budget["components"], failures=failures,
        unresolved_loops=rep.unresolved_loops)


# ---------------------------------------------------------------------------
# memory contract
# ---------------------------------------------------------------------------


@dataclass
class MemoryReport:
    """Peak live bytes of a compiled step vs the analytic budget."""

    plan: str
    measured_bytes: int
    budget_bytes: float
    components: Dict[str, float]
    failures: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures

    def to_dict(self) -> Dict:
        return {
            "plan": self.plan, "ok": self.ok,
            "measured_bytes": self.measured_bytes,
            "budget_bytes": self.budget_bytes,
            "components": self.components,
            "failures": self.failures,
        }


def memory_budget(plan, boxed_params, cfg, batch, *, sub_ids=None) -> Dict[str, float]:
    """Analytic per-device live-byte budget of one round step.

    Component model (all f32 working set, ids s32):

    - ``params_io``: the state tree twice (argument + fresh output; the
      oracle lowers without donation so both are live at the apply).
    - ``batch``: the full round batch + heat vectors (replicated argument).
    - ``tables_scratch``: one f32 copy of every feature table — covers the
      psum combine's densified partial and the apply-side scatter scratch.
    - ``replicas``: the submodel working set, ``k_shard * capacity *
      (row + id)`` with a 4x factor for gradient/delta/optimizer
      temporaries. THIS is the term a dense-replica regression blows
      through: densified replicas cost ``k_shard * V * row`` instead.
    - ``combine``: the cross-shard union gather buffers + a V-sized
      workspace (bitmap / unique-id machinery + heat working copies).
    - ``activations``: 4x the batch bytes (forward + backward residuals of
      the tiny audit models; scale-free w.r.t. V).
    """
    sharding = plan.sharding
    ndev = sharding.num_shards if sharding is not None else 1
    plain = unbox(boxed_params)
    heat_spec = heat_spec_from_axes(boxed_params)
    table_paths = [p for p, _ in sparse_table_paths(heat_spec)]
    tables = [tree_leaf_at(plain, p) for p in table_paths]
    vocab = max((int(t.shape[0]) for t in tables), default=0)
    param_bytes = sum(float(np.prod(l.shape)) * l.dtype.itemsize
                      for l in jax.tree.leaves(plain))
    _, data = split_heat_batch(batch)
    batch_bytes = sum(float(np.prod(np.shape(v))) * np.dtype(
        getattr(v, "dtype", np.float32)).itemsize for v in batch.values())

    fk = tuple(plan.feature_keys)
    row_elems = sum(max(int(np.prod(t.shape[1:])), 1) for t in tables)
    if sub_ids is not None:
        cap = int(sub_ids.shape[-1])
    elif getattr(plan.local, "stacked", False):
        cap = round_capacity(vocab, sum(int(np.prod(data[k].shape[1:]))
                                        for k in fk)) if vocab else 0
    else:
        cap = round_capacity(vocab, sum(int(np.prod(data[k].shape)) // ndev
                                        for k in fk)) if vocab else 0
    if getattr(plan.local, "stacked", False):
        k_real = int(data[fk[0]].shape[0])
        k_shard = -(-k_real // ndev)
    else:
        k_shard = 1

    comps = {
        "params_io": 2.0 * param_bytes,
        "batch": batch_bytes,
        "tables_scratch": sum(float(np.prod(t.shape)) * 4.0 for t in tables),
        "replicas": 4.0 * k_shard * cap * (row_elems * 4.0 + 4.0),
        "combine": float(ndev) * cap * (row_elems * 4.0 + 4.0)
        + float(vocab) * 8.0,
        "activations": 4.0 * batch_bytes,
    }
    return comps


def memory_contract(plan, loss_fn, boxed_params, cfg, batch, *,
                    sub_ids=None, compiled=None, budget: Optional[Dict] = None,
                    slack_rel: float = 0.25,
                    slack_abs: float = float(1 << 20)) -> MemoryReport:
    """Gate a compiled step's peak live bytes against the analytic budget.

    ``measured = argument + output - aliased + temp`` from
    ``compiled.memory_analysis()`` — the executable's own accounting of
    what must be resident at once. ``budget`` defaults to
    :func:`memory_budget` of this plan; the planted-violation tests pass a
    LEANER plan's budget to prove a dense-replica regression trips the gate.
    """
    if compiled is None:
        compiled = lower_round_step(plan, loss_fn, boxed_params, cfg, batch,
                                    sub_ids=sub_ids)
    ma = compiled.memory_analysis()
    measured = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                   - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    comps = memory_budget(plan, boxed_params, cfg, batch,
                          sub_ids=sub_ids) if budget is None else budget
    allowed = sum(comps.values()) * (1.0 + slack_rel) + slack_abs
    failures = []
    if measured > allowed:
        top = max(comps, key=comps.get)
        failures.append(
            f"peak live bytes {measured} exceed the analytic budget "
            f"{sum(comps.values()):.0f} B (+{slack_rel:.0%}/"
            f"+{slack_abs:.0f} B slack; largest budget term '{top}' = "
            f"{comps[top]:.0f} B) — a dense-replica or table-copy "
            "regression")
    return MemoryReport(plan=repr(plan), measured_bytes=measured,
                        budget_bytes=allowed, components=comps,
                        failures=failures)


# ---------------------------------------------------------------------------
# comm-accounting drift
# ---------------------------------------------------------------------------


@dataclass
class DriftReport:
    """HLO-measured combine bytes vs the comm plane's own prediction."""

    plan: str
    predicted_by_op: Dict[str, float]
    measured_by_op: Dict[str, int]
    rel_tol: float
    abs_tol: float
    failures: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures

    def to_dict(self) -> Dict:
        return {
            "plan": self.plan, "ok": self.ok,
            "predicted_by_op": self.predicted_by_op,
            "measured_by_op": self.measured_by_op,
            "rel_tol": self.rel_tol, "abs_tol": self.abs_tol,
            "failures": self.failures,
        }


def comm_drift(plan, loss_fn, boxed_params, cfg, batch, *, sub_ids=None,
               compiled=None, rel_tol: float = 0.10,
               abs_tol: float = 64.0) -> DriftReport:
    """Cross-check HLO collective bytes against ``sharded_combine_bytes``.

    Unlike :func:`collective_contract` (whose budget mirrors the shard
    bodies term by term), this check prices the combine from the
    comm-accounting plane's OWN primitives — ``plan_comm_meta`` +
    ``sharded_combine_bytes`` — so a change that updates the plan compiler
    but forgets the byte accounting (or vice versa) fails here even when
    the contract above still balances. Documented tolerance: 10% relative
    + 64 B absolute per op kind (the absolute term covers the loss and
    sub-row scalar reductions the comm plane does not price).
    """
    budget = round_collective_budget(plan, boxed_params, cfg, batch,
                                     sub_ids=sub_ids)
    meta = plan_comm_meta(boxed_params)
    modes = set(budget["combine"].values())
    if len(modes) != 1:
        raise ValueError(
            f"comm_drift prices one combine mode per plan, got {modes} — "
            "multi-table models with split pick_combine decisions need the "
            "per-table contract (collective_contract) instead")
    mode = modes.pop()
    cap = max(budget["capacity"].values())
    predicted = sharded_combine_bytes(
        meta, budget["vocab"], cap, budget["num_shards"], mode,
        num_tables=len(budget["combine"]),
        count_gather_ids=not budget["stacked"])
    if compiled is None:
        compiled = lower_round_step(plan, loss_fn, boxed_params, cfg, batch,
                                    sub_ids=sub_ids)
    measured = analyze_hlo(compiled.as_text()).by_op()
    failures = []
    for op in sorted(set(predicted) | set(measured)):
        p, m = predicted.get(op, 0.0), measured.get(op, 0)
        if abs(m - p) > rel_tol * p + abs_tol:
            failures.append(
                f"'{op}': comm plane predicts {p:.0f} B, compiled HLO moves "
                f"{m} B (tolerance {rel_tol:.0%} + {abs_tol:.0f} B) — the "
                "byte accounting and the plan compiler have drifted apart")
    return DriftReport(plan=repr(plan), predicted_by_op=predicted,
                       measured_by_op=measured, rel_tol=rel_tol,
                       abs_tol=abs_tol, failures=failures)


# ---------------------------------------------------------------------------
# CLI: the CI gate
# ---------------------------------------------------------------------------


def _audit_matrix(vocab: int, emb: int):
    """Contract + memory + drift over the sharded sparse plan matrix."""
    from repro.configs import FedConfig
    from repro.federated import CohortSharding, resolve_plan
    from repro.launch.mesh import make_cohort_mesh
    from repro.models.recsys import lstm_loss, make_lstm_params

    mesh = make_cohort_mesh()
    params = make_lstm_params(vocab, emb_dim=emb, hidden=8, layers=1,
                              rng=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)

    def cohort_batch(k=3, i=2, b=2, s=6):
        return {
            "tokens": jnp.asarray(rng.integers(-1, vocab, (k, i, b, s)),
                                  jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, (k, i, b)), jnp.int32),
            "heat_vocab": jnp.maximum(jnp.asarray(
                rng.integers(0, 6, vocab), jnp.float32), 0)}

    def flat_batch(b=8, s=8):
        return {
            "tokens": jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, b), jnp.int32),
            "heat_vocab": jnp.maximum(jnp.asarray(
                rng.integers(0, 6, vocab), jnp.float32), 0)}

    results = []
    for mode in ("sparse", "sparse_replicated"):
        for alg in ("fedavg", "fedsubavg"):
            for combine in ("psum", "union"):
                fed = FedConfig(num_clients=16, clients_per_round=3,
                                local_iters=2, lr=0.1, algorithm=alg)
                plan = dataclasses.replace(
                    resolve_plan(mode, fed, correct=(alg == "fedsubavg")),
                    sharding=CohortSharding(mesh, combine=combine))
                batch = flat_batch() if mode == "sparse" else cohort_batch()
                compiled = lower_round_step(plan, lstm_loss, params, fed,
                                            batch)
                con = collective_contract(plan, lstm_loss, params, fed,
                                          batch, compiled=compiled)
                mem = memory_contract(plan, lstm_loss, params, fed, batch,
                                      compiled=compiled)
                drift = comm_drift(plan, lstm_loss, params, fed, batch,
                                   compiled=compiled)
                results.append({
                    "mode": mode, "algorithm": alg, "combine": combine,
                    "contract": con.to_dict(), "memory": mem.to_dict(),
                    "drift": drift.to_dict(),
                    "ok": con.ok and mem.ok and drift.ok,
                })
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="comm & memory oracle over compiled sharded round steps")
    ap.add_argument("--json", default=None,
                    help="write the contract report to this path")
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--emb", type=int, default=8)
    args = ap.parse_args(argv)

    ndev = len(jax.devices())
    if ndev < 2:
        print("hlo_audit: needs a multi-device mesh (run under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8); "
              f"found {ndev} device", file=sys.stderr)
        return 2
    results = _audit_matrix(args.vocab, args.emb)
    report = {"device_count": ndev, "vocab": args.vocab, "emb": args.emb,
              "results": results, "ok": all(r["ok"] for r in results)}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
    failed = [r for r in results if not r["ok"]]
    for r in results:
        tag = f"{r['mode']}/{r['algorithm']}/{r['combine']}"
        status = "OK" if r["ok"] else "FAIL"
        by_op = r["contract"]["measured_by_op"]
        print(f"hlo_audit {status:4s} {tag}: collectives {by_op}, "
              f"peak {r['memory']['measured_bytes']} B")
        for section in ("contract", "memory", "drift"):
            for msg in r[section]["failures"]:
                print(f"  {section}: {msg}", file=sys.stderr)
    if failed:
        print(f"hlo_audit: {len(failed)}/{len(results)} plan contracts "
              "FAILED", file=sys.stderr)
        return 1
    print(f"hlo_audit: all {len(results)} plan contracts hold "
          f"({ndev} devices)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
