"""Compiled-artifact auditor: jaxpr and HLO checks for the sparse plane.

The linter (:mod:`repro.analysis.lint`) reads source; this module reads what
JAX actually built.  Three checks, each pinning a property the repo's perf
work depends on:

``find_dense_intermediates`` / ``assert_no_dense_intermediates``
    Walk a traced function's closed jaxpr (recursively, through pjit /
    scan / cond / shard_map sub-jaxprs) and report every intermediate whose
    leading dimension equals the full vocabulary size.  On a RowSparse
    transport plan nothing between the client gather and the server
    scatter-add should be ``(V, ...)``-shaped — a hit means some step of
    the pipeline silently densified and the O(R/V) transport win is gone.
    The server scatter-add itself *writes* the ``(V, D)`` table, so scatter
    primitives are allowed by default; everything else that *produces* a
    vocab-sized array (``broadcast_in_dim`` zeros from ``to_dense()``,
    dense adds, transposes of the table) is flagged.

``donation_aliased``
    Confirm that a donated argument is actually aliased to an output in the
    lowered HLO, and report *which* input buffers landed where (the
    ``tf.aliasing_output`` attributes on the ``@main`` signature).  Donation
    requests are silently dropped when shapes/dtypes fail to line up; the
    returned :class:`DonationReport` turns "we asked" into a per-buffer
    input->output map plus a dropped count, and is truthy exactly when every
    donated buffer aliased.

``jit_cache_guard``
    Context manager pinning the number of *new* compilations of one or
    more jitted callables.  Sweeping a traced hyperparameter (heat scale,
    int8 rounding key) through a step must not recompile; a static-arg or
    weak-type leak shows up here as a hard failure instead of a silent
    10x slowdown.

Everything here needs jax; import via ``repro.analysis`` lazily so the
linter stays usable in environments without it.
"""
from __future__ import annotations

import contextlib
import re
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "DenseIntermediate",
    "DenseMaterializationError",
    "DonationReport",
    "CompileCountError",
    "find_dense_intermediates",
    "assert_no_dense_intermediates",
    "donation_aliased",
    "jit_cache_guard",
]

# Primitives that legitimately emit a vocab-sized array on a sparse plan:
# the server-side row update writes into the (V, D) table in place.
_DEFAULT_ALLOWED = ("scatter-add", "scatter", "scatter-mul", "scatter-apply")

# Structural (higher-order) primitives: their outputs only thread values
# produced INSIDE their sub-jaxprs — which this walker descends into — so
# counting the eqn output would double-report every legitimate pass-through
# of the carried (V, D) table (e.g. the async engine's event scan carrying
# the server params through cond branches). A genuine densification inside
# a branch is still caught at its own producing equation.
_STRUCTURAL = frozenset({"scan", "while", "cond", "pjit", "closed_call",
                         "custom_jvp_call", "custom_vjp_call", "remat",
                         "checkpoint"})


@dataclass(frozen=True)
class DenseIntermediate:
    """One vocab-sized intermediate found in a jaxpr walk."""

    primitive: str
    shape: tuple
    dtype: str
    path: str          # e.g. "pjit/scan/body"

    def __str__(self) -> str:
        where = self.path or "<top>"
        return f"{self.primitive} -> {self.shape} {self.dtype} at {where}"


class DenseMaterializationError(AssertionError):
    """A RowSparse plan materialised a full-vocab intermediate."""

    def __init__(self, dim0: int, hits: Sequence[DenseIntermediate]):
        self.dim0 = dim0
        self.hits = tuple(hits)
        lines = "\n".join(f"  - {h}" for h in hits)
        super().__init__(
            f"found {len(hits)} dense (V={dim0}, ...) intermediate(s) on a "
            f"sparse-transport plan:\n{lines}"
        )


def _iter_subjaxprs(params: dict) -> Iterable[tuple[str, Any]]:
    """Yield (name, Jaxpr) for every sub-jaxpr in an eqn's params.

    Duck-typed: pjit/scan/remat carry a ClosedJaxpr under 'jaxpr' or
    'call_jaxpr', cond carries a tuple under 'branches', custom_vjp a
    callable-wrapped one we can't see (fine: it retraces into the parent
    when not opaque).
    """
    for key, val in params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for i, v in enumerate(vals):
            inner = getattr(v, "jaxpr", None)  # ClosedJaxpr -> Jaxpr
            if inner is None and hasattr(v, "eqns"):
                inner = v                       # already a raw Jaxpr
            if inner is not None and hasattr(inner, "eqns"):
                name = key if len(vals) == 1 else f"{key}[{i}]"
                yield name, inner


def _walk(jaxpr, dim0: int, min_ndim: int, allowed: frozenset,
          path: str, out: list) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim not in allowed and prim not in _STRUCTURAL:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                shape = getattr(aval, "shape", ())
                dtype = getattr(aval, "dtype", None)
                # only floating-point hits count: the transport payload is
                # float rows, while int/bool (V,)-sized id workspaces (the
                # mark-scatter union machinery) are accepted O(V*4B) cost
                inexact = dtype is not None and jnp.issubdtype(
                    dtype, jnp.inexact)
                if (inexact and len(shape) >= min_ndim and shape
                        and shape[0] == dim0):
                    out.append(DenseIntermediate(
                        primitive=prim,
                        shape=tuple(shape),
                        dtype=str(getattr(aval, "dtype", "?")),
                        path=path,
                    ))
        for name, sub in _iter_subjaxprs(eqn.params):
            sub_path = f"{path}/{prim}:{name}" if path else f"{prim}:{name}"
            _walk(sub, dim0, min_ndim, allowed, sub_path, out)


def find_dense_intermediates(
    fn: Callable,
    *args,
    dim0: int,
    min_ndim: int = 2,
    allowed_primitives: Sequence[str] = _DEFAULT_ALLOWED,
    **kwargs,
) -> list[DenseIntermediate]:
    """Trace ``fn(*args, **kwargs)`` and list intermediates shaped (dim0, ...).

    ``dim0`` is the full vocabulary size V.  Inputs and outputs of the
    traced function are exempt (the server table legitimately enters and
    leaves as ``(V, D)``); only equation *outputs* inside the program
    count, and scatter-family primitives — the in-place table write — are
    allowed by default.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    hits: list[DenseIntermediate] = []
    _walk(closed.jaxpr, dim0, min_ndim, frozenset(allowed_primitives),
          "", hits)
    return hits


def assert_no_dense_intermediates(
    fn: Callable,
    *args,
    dim0: int,
    min_ndim: int = 2,
    allowed_primitives: Sequence[str] = _DEFAULT_ALLOWED,
    **kwargs,
) -> None:
    """Raise :class:`DenseMaterializationError` on any (dim0, ...) hit."""
    hits = find_dense_intermediates(
        fn, *args, dim0=dim0, min_ndim=min_ndim,
        allowed_primitives=allowed_primitives, **kwargs)
    if hits:
        raise DenseMaterializationError(dim0, hits)


@dataclass(frozen=True, eq=False)
class DonationReport:
    """What XLA actually did with a donation request.

    ``aliasing`` maps flattened ``@main`` argument index -> flattened output
    index for every buffer carrying a ``tf.aliasing_output`` attribute in
    the lowered module; ``num_donated`` is the number of input *leaves* the
    donation request covered.  ``dropped`` is the shortfall: requested
    buffers XLA declined to alias (shape/dtype mismatch with every output).

    Truthiness preserves the old boolean API, but strictly: the report is
    truthy only when something aliased AND nothing requested was dropped,
    so ``assert donation_aliased(...)`` now also catches the partial drop
    the old substring check waved through.
    """

    aliasing: Dict[int, int] = field(default_factory=dict)
    num_donated: int = 0

    @property
    def dropped(self) -> int:
        return max(self.num_donated - len(self.aliasing), 0)

    def __bool__(self) -> bool:
        return bool(self.aliasing) and self.dropped == 0

    def __str__(self) -> str:
        pairs = ", ".join(f"%arg{a}->out{o}"
                          for a, o in sorted(self.aliasing.items()))
        return (f"DonationReport(aliased={{{pairs}}}, "
                f"requested={self.num_donated}, dropped={self.dropped})")


_MAIN_ARG_RE = re.compile(r"%arg(\d+)\s*:")
_ALIAS_ATTR_RE = re.compile(r"tf\.aliasing_output\s*=\s*(\d+)")


def donation_aliased(
    fn: Callable,
    *args,
    donate_argnums: Sequence[int] = (0,),
    **kwargs,
) -> DonationReport:
    """Report how jitting ``fn`` with the given donation actually aliased.

    XLA drops donation silently when no output matches a donated input's
    shape/dtype; the only reliable witness is the ``tf.aliasing_output``
    attribute on the ``@main`` signature of the lowered module text.  Each
    attribute is attributed to the nearest preceding ``%argN:`` declaration
    (the attribute dict sits directly after its argument's type, and
    aliasing attributes appear only in the signature).

    Returns a :class:`DonationReport`; its truthiness matches the old bool
    API for the all-or-nothing cases, and ``report.aliasing`` /
    ``report.dropped`` expose the per-buffer outcome — including the
    partially-dropped donation the substring check could not see.
    """
    jitted = jax.jit(fn, donate_argnums=tuple(donate_argnums))
    with warnings.catch_warnings():
        # a partially-usable donation warns at lower time; the report is
        # the structured version of that warning, so keep the audit quiet
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        text = jitted.lower(*args, **kwargs).as_text()
    arg_marks = [(m.start(), int(m.group(1)))
                 for m in _MAIN_ARG_RE.finditer(text)]
    aliasing: Dict[int, int] = {}
    for m in _ALIAS_ATTR_RE.finditer(text):
        owner = None
        for pos, idx in arg_marks:
            if pos >= m.start():
                break
            owner = idx
        if owner is not None:
            aliasing[owner] = int(m.group(1))
    num_donated = sum(len(jax.tree.leaves(args[i]))
                      for i in donate_argnums)
    return DonationReport(aliasing=aliasing, num_donated=num_donated)


class CompileCountError(AssertionError):
    """A jit cache grew more than the guard allows."""


@contextlib.contextmanager
def jit_cache_guard(*fns: Callable, max_new_compiles: int = 1):
    """Pin the number of new compilations of jitted callables in a block.

    ::

        step = jax.jit(round_step)
        with jit_cache_guard(step):          # at most 1 new compile
            for scale in scales:
                state, _ = step(state, batch, scale)

    Each ``fn`` must be a ``jax.jit`` product (it exposes
    ``_cache_size()``).  On exit, any callable whose cache grew by more
    than ``max_new_compiles`` raises :class:`CompileCountError` naming the
    offender and the delta — the signature of a traced value leaking into
    a static argument or a weak-type flip-flop.
    """
    for fn in fns:
        if not hasattr(fn, "_cache_size"):
            raise TypeError(
                f"{fn!r} has no _cache_size(); pass the jax.jit-wrapped "
                "callable itself, not the python function")
    before = [fn._cache_size() for fn in fns]
    yield
    for fn, b in zip(fns, before):
        grew = fn._cache_size() - b
        if grew > max_new_compiles:
            name = getattr(fn, "__name__", repr(fn))
            raise CompileCountError(
                f"{name} compiled {grew} time(s) inside the guard "
                f"(allowed {max_new_compiles}): a sweep that should hit "
                "the jit cache is recompiling per value")
