"""Kernel contract plane: static Pallas VMEM/race/cost auditor (layer 5).

The first four analysis layers stop at the ``pallas_call`` boundary: the
linter reads Python source, the jaxpr auditor reads traced programs, the
sanitizer checks runtime values, the HLO oracle reads compiled collectives —
none of them look INSIDE a Pallas kernel, and that is exactly where this
repo's worst bugs lived (the Megacore ``dimension_semantics`` race in
``union_segsum``; the ``fits_vmem``-vs-kernel block-pick drift). This layer
audits every ``pl.pallas_call`` in ``repro.kernels`` statically, from its
BlockSpecs, grid, scratch shapes and kernel-body jaxpr. Tracing needs no
TPU — only lowering does — so the whole audit runs on the CPU CI runner.

Three contracts per kernel:

- :func:`vmem_contract` — per-program VMEM footprint from the ACTUAL block
  picks in the trace: double-buffered pipeline blocks (index map varies with
  the grid), single-buffered resident blocks (constant index map), scratch,
  and SMEM scalars. Fails if the footprint exceeds the budget
  (``[vmem-budget]``), if the kernel's own ``fits_vmem`` guard disagrees
  with the trace (``[vmem-guard-drift]`` / ``[vmem-guard-underestimate]``),
  or if the guard's ``_block_sizes`` prediction differs from the blocks the
  kernel actually runs (``[block-pick-drift]`` — the PR-2 bug class,
  machine-checked for all kernels).
- :func:`race_contract` — walks the kernel body for cross-program carried
  state: scratch/SMEM accumulators whose reset schedule does not cover a
  grid dim, output blocks revisited by more than one program, and
  ``input_output_aliases``. Every grid dim the body's iteration order
  observably flows across must be declared ``"arbitrary"``; one declared
  ``"parallel"`` is the Megacore corruption bug, reported as
  ``[megacore-race]`` with the offending ref named.
- :func:`cost_model` — analytic bytes-touched and FLOPs per kernel
  invocation from the grid x BlockSpec structure. Operand fetch counts come
  from the grid dims each index map depends on, so an operand re-streamed
  across an independent grid dim (e.g. ``union_segsum`` re-fetching the
  ids/rows stream once per vocab block) shows up as ``restream > 1``. The
  numbers feed ``bench_sparse``'s kernel roofline section (achieved vs
  analytic bandwidth per union backend), gated by ``check_regression.py``.

The per-kernel capture comes from ``repro.kernels.introspect.REGISTRY``,
which also carries each kernel's own guard verdict at the audit shape —
auditor and kernel share the ``_block_sizes`` helpers, so they cannot
drift silently.

CLI (the CI gate)::

    python -m repro.analysis.kernel_audit --json kernel-audit.json

exits non-zero on any contract failure or if a ``pallas_call`` site in
``repro.kernels`` is missing from the registry.

Race analysis, precisely
------------------------
TPU grids iterate row-major (last dim minor). For each ref the kernel
writes, the walk classifies every access: a FULL unconditional write makes
everything after it program-local; a full write guarded by a conjunction of
``program_id(k) == 0`` terms is a *reset* with dim set S; any read or
partial/conditional write before an unconditional full write means the ref
*carries* state between programs. A carried ref's state flows across grid
dim d unless the reset dims S are all strictly minor than d (``S ⊆ {k : k >
d}``): then every segment of constant d-prefix re-runs the reset before
touching the state. Input/output refs only share state across dims their
index map is constant along (or dims involved in a revisit, detected by
evaluating the index map over the dependent grid dims). Unknown constructs
degrade conservatively (flow everywhere).
"""
from __future__ import annotations

import argparse
import itertools
import json
import re
import sys
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.common.hw import HW
from repro.kernels.introspect import REGISTRY, GuardReport, KernelEntry

__all__ = [
    "PallasCapture", "RefInfo", "VmemReport", "RaceReport", "CostReport",
    "KernelReport", "capture_pallas_calls", "vmem_contract", "race_contract",
    "cost_model", "audit_kernel", "audit_all", "registry_coverage", "main",
]


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


# ---------------------------------------------------------------------------
# capture: pallas_call -> structured view
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RefInfo:
    """One kernel-body ref: an input/output block or a scratch buffer."""

    name: str                       # 'args[0]' / 'outputs[0]' / 'scratch[0]'
    kind: str                       # 'input' | 'output' | 'scratch'
    space: str                      # 'vmem' | 'smem'
    block_shape: Tuple[int, ...]    # per-program window (scratch: full shape)
    array_shape: Tuple[int, ...]    # backing array (scratch: == block_shape)
    itemsize: int
    index_deps: frozenset           # grid dims the index map depends on
    index_map: Optional[Callable]   # (grid idx...) -> block idx tuple

    @property
    def block_bytes(self) -> int:
        return _prod(self.block_shape) * self.itemsize

    @property
    def array_bytes(self) -> int:
        return _prod(self.array_shape) * self.itemsize


@dataclass(frozen=True)
class PallasCapture:
    """Everything the contracts need from one traced ``pallas_call``."""

    grid: Tuple[int, ...]
    dimension_semantics: Optional[Tuple[str, ...]]
    refs: Tuple[RefInfo, ...]       # ordered as the kernel body's invars
    jaxpr: Any                      # the kernel body
    input_output_aliases: Tuple[Tuple[int, int], ...]
    num_inputs: int
    num_outputs: int


def _is_literal(a) -> bool:
    return hasattr(a, "val")


def _jaxpr_deps(closed) -> frozenset:
    """Grid dims (invar positions) a closed jaxpr's outputs depend on."""
    jaxpr = closed.jaxpr
    dep: Dict[Any, frozenset] = {
        v: frozenset([i]) for i, v in enumerate(jaxpr.invars)}

    def get(a):
        return frozenset() if _is_literal(a) else dep.get(a, frozenset())

    for eqn in jaxpr.eqns:
        d = frozenset().union(*(get(x) for x in eqn.invars)) \
            if eqn.invars else frozenset()
        for o in eqn.outvars:
            dep[o] = d
    if not jaxpr.outvars:
        return frozenset()
    return frozenset().union(*(get(o) for o in jaxpr.outvars))


def _space_of(aval) -> str:
    ms = getattr(aval, "memory_space", None)
    return "smem" if ms is not None and "smem" in str(ms).lower() else "vmem"


def _norm_shape(shape) -> Tuple[int, ...]:
    # BlockSpec dims mapped away appear as a non-int sentinel; they window
    # a single element
    return tuple(int(b) if isinstance(b, int) else 1 for b in shape)


def _index_map_fn(closed) -> Callable:
    def call(*idx):
        import jax.core as jcore
        out = jcore.eval_jaxpr(closed.jaxpr, closed.consts, *idx)
        return tuple(int(x) for x in out)
    return call


def _captures_from_jaxpr(jaxpr, out: List[PallasCapture]) -> None:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(_capture_from_eqn(eqn))
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for v in vals:
                inner = getattr(v, "jaxpr", None)
                if inner is None and hasattr(v, "eqns"):
                    inner = v
                if inner is not None and hasattr(inner, "eqns"):
                    _captures_from_jaxpr(inner, out)


def _capture_from_eqn(eqn) -> PallasCapture:
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    cp = eqn.params.get("compiler_params") or {}
    sem = (cp.get("mosaic") or {}).get("dimension_semantics")
    sem = tuple(sem) if sem is not None else None
    body = eqn.params["jaxpr"]

    refs: List[RefInfo] = []
    n_in, n_out = int(gm.num_inputs), int(gm.num_outputs)
    for i, bm in enumerate(gm.block_mappings):
        kind = "input" if i < n_in else "output"
        origin = getattr(bm, "origin", "") or (
            f"args[{i}]" if kind == "input" else f"outputs[{i - n_in}]")
        sd = bm.array_shape_dtype
        refs.append(RefInfo(
            name=str(origin), kind=kind,
            space=_space_of(bm.transformed_block_aval),
            block_shape=_norm_shape(bm.block_shape),
            array_shape=tuple(int(s) for s in sd.shape),
            itemsize=int(sd.dtype.itemsize),
            index_deps=_jaxpr_deps(bm.index_map_jaxpr),
            index_map=_index_map_fn(bm.index_map_jaxpr),
        ))
    scratch_vars = body.invars[n_in + n_out:]
    for k, v in enumerate(scratch_vars):
        aval = v.aval
        shape = tuple(int(s) for s in getattr(aval, "shape", ()))
        dtype = getattr(aval, "dtype", None)
        refs.append(RefInfo(
            name=f"scratch[{k}]", kind="scratch", space=_space_of(aval),
            block_shape=shape, array_shape=shape,
            itemsize=int(dtype.itemsize) if dtype is not None else 4,
            index_deps=frozenset(), index_map=None,
        ))
    aliases = tuple(tuple(int(x) for x in pair)
                    for pair in (eqn.params.get("input_output_aliases") or ()))
    return PallasCapture(
        grid=grid, dimension_semantics=sem, refs=tuple(refs), jaxpr=body,
        input_output_aliases=aliases, num_inputs=n_in, num_outputs=n_out)


def capture_pallas_calls(fn: Callable, *args, **kwargs) -> List[PallasCapture]:
    """Trace ``fn(*args, **kwargs)`` and capture every ``pallas_call`` in it.

    Args may be ``jax.ShapeDtypeStruct``s — nothing is executed. Trace with
    ``interpret=False`` so the Mosaic ``dimension_semantics`` are present
    (tracing a compiled-path ``pallas_call`` works on any backend).
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    out: List[PallasCapture] = []
    _captures_from_jaxpr(closed.jaxpr, out)
    return out


# ---------------------------------------------------------------------------
# kernel-body walk: guards + ref access events
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _Event:
    ref: int                        # index into capture.refs
    kind: str                       # 'get' | 'swap' | 'opaque'
    full: bool                      # statically covers the whole ref
    guard: Optional[frozenset]      # {(axis, const), ...}; empty = always;
    #                                 None = condition unknown


def _parse_guard(var, env) -> Optional[frozenset]:
    """Parse a predicate var into ``{(axis, const), ...}`` conjuncts.

    Recognizes conjunctions of ``program_id(axis) == const`` (through
    ``convert_element_type`` casts); anything else is None (unknown).
    """
    if _is_literal(var):
        return None
    eqn = env.get(var)
    if eqn is None:
        return None
    prim = eqn.primitive.name
    if prim == "convert_element_type":
        return _parse_guard(eqn.invars[0], env)
    if prim == "and":
        a = _parse_guard(eqn.invars[0], env)
        b = _parse_guard(eqn.invars[1], env)
        return a | b if a is not None and b is not None else None
    if prim == "eq":
        for x, y in ((eqn.invars[0], eqn.invars[1]),
                     (eqn.invars[1], eqn.invars[0])):
            ax = _program_id_axis(x, env)
            cv = _literal_int(y)
            if ax is not None and cv is not None:
                return frozenset({(ax, cv)})
    return None


def _program_id_axis(var, env) -> Optional[int]:
    if _is_literal(var):
        return None
    eqn = env.get(var)
    if eqn is None:
        return None
    if eqn.primitive.name == "program_id":
        return int(eqn.params["axis"])
    if eqn.primitive.name == "convert_element_type":
        return _program_id_axis(eqn.invars[0], env)
    return None


def _literal_int(var) -> Optional[int]:
    if _is_literal(var):
        try:
            return int(var.val)
        except Exception:
            return None
    return None


def _is_full_write(eqn) -> bool:
    """A swap that statically covers its whole ref: no dynamic index
    operands and a value the size of the ref."""
    if len(eqn.invars) > 2:
        return False
    ref_shape = getattr(eqn.invars[0].aval, "shape", ())
    val_shape = getattr(eqn.invars[1].aval, "shape", ())
    return _prod(val_shape) == _prod(ref_shape)


def _collect_events(jaxpr, env, refmap, guard, events: List[_Event]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "get":
            r = refmap.get(eqn.invars[0])
            if r is not None:
                events.append(_Event(r, "get", False, guard))
        elif prim == "swap":
            r = refmap.get(eqn.invars[0])
            if r is not None:
                events.append(_Event(r, "swap", _is_full_write(eqn), guard))
        elif prim == "cond":
            g = _parse_guard(eqn.invars[0], env)
            branches = eqn.params["branches"]
            for bi, br in enumerate(branches):
                sub = br.jaxpr
                if not sub.eqns:
                    continue
                # branch order: [false, true]; only the true branch runs
                # under the parsed conjunction — anything else is unknown
                if bi == len(branches) - 1 and g is not None and \
                        guard is not None:
                    sub_guard: Optional[frozenset] = guard | g
                else:
                    sub_guard = None
                env_b = dict(env)
                refmap_b = dict(refmap)
                for bv, ov in zip(sub.invars, eqn.invars[1:]):
                    if not _is_literal(ov):
                        if ov in env:
                            env_b[bv] = env[ov]
                        if ov in refmap:
                            refmap_b[bv] = refmap[ov]
                _collect_events(sub, env_b, refmap_b, sub_guard, events)
        else:
            # any other primitive taking a ref operand (run_scoped, loops,
            # DMA...) is opaque to this walk — degrade conservatively
            for iv in eqn.invars:
                if not _is_literal(iv) and iv in refmap:
                    events.append(_Event(refmap[iv], "opaque", False, None))
        for o in eqn.outvars:
            env[o] = eqn


def _ref_events(cap: PallasCapture) -> Dict[int, List[_Event]]:
    """Access events per ref id, in program order, guards resolved.

    Aliased inputs share their output's ref id: they are the same memory.
    """
    alias_of = {i: cap.num_inputs + o for i, o in cap.input_output_aliases}
    refmap = {}
    body = cap.jaxpr
    for i, v in enumerate(body.invars):
        refmap[v] = alias_of.get(i, i)
    env: Dict[Any, Any] = {}
    events: List[_Event] = []
    _collect_events(body, env, refmap, frozenset(), events)
    by_ref: Dict[int, List[_Event]] = {}
    for ev in events:
        by_ref.setdefault(ev.ref, []).append(ev)
    return by_ref


# ---------------------------------------------------------------------------
# contract 1: VMEM budget
# ---------------------------------------------------------------------------


@dataclass
class VmemReport:
    """Structural VMEM footprint vs budget and the kernel's own guard."""

    kernel: str
    structural_bytes: int
    budget_bytes: int
    guard_bytes: Optional[int]
    components: Dict[str, int]
    failures: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures

    def to_dict(self) -> Dict:
        return {"kernel": self.kernel, "ok": self.ok,
                "structural_bytes": self.structural_bytes,
                "budget_bytes": self.budget_bytes,
                "guard_bytes": self.guard_bytes,
                "components": self.components, "failures": self.failures}


def vmem_contract(cap: PallasCapture, *, kernel: str = "kernel",
                  budget: int, guard: Optional[GuardReport] = None
                  ) -> VmemReport:
    """Check the captured footprint against the budget and the guard.

    Footprint model: VMEM pipeline blocks whose index map varies with the
    grid are double-buffered (Mosaic prefetches the next window while the
    current one computes); constant-index-map blocks stay resident (x1);
    scratch and SMEM are single copies. The kernel's own guard must (a)
    accept the audit shape, (b) price at least the structural bytes, and
    (c) predict exactly the block shapes the kernel runs.
    """
    comps: Dict[str, int] = {}
    for r in cap.refs:
        if r.kind == "scratch" or r.space == "smem":
            comps[r.name] = r.block_bytes
        elif r.index_deps:
            comps[r.name] = 2 * r.block_bytes
        else:
            comps[r.name] = r.block_bytes      # grid-constant: resident
    structural = sum(comps.values())

    failures: List[str] = []
    if structural > budget:
        top = max(comps, key=comps.get)
        failures.append(
            f"[vmem-budget] {kernel}: static VMEM footprint {structural} B "
            f"exceeds the {budget} B budget (largest term {top} = "
            f"{comps[top]} B)")
    guard_bytes = None
    if guard is not None:
        guard_bytes = int(guard.footprint)
        if not guard.fits:
            failures.append(
                f"[vmem-guard-drift] {kernel}: its own fits_vmem guard "
                "rejects the audit shape the kernel traces at — guard and "
                "kernel have drifted apart")
        if guard_bytes < structural:
            failures.append(
                f"[vmem-guard-underestimate] {kernel}: fits_vmem prices "
                f"{guard_bytes} B but blocks+scratch alone are {structural} "
                "B — the guard formula undercounts the working set")
        for name, (idx, expected) in sorted(guard.blocks.items()):
            if not 0 <= idx < len(cap.refs):
                failures.append(
                    f"[block-pick-drift] {kernel}: guard names operand "
                    f"'{name}' at index {idx}, but the capture has only "
                    f"{len(cap.refs)} refs")
                continue
            got = cap.refs[idx].block_shape
            if tuple(expected) != got:
                failures.append(
                    f"[block-pick-drift] {kernel}: guard predicts '{name}' "
                    f"block {tuple(expected)}, kernel runs {got}")
    return VmemReport(kernel=kernel, structural_bytes=structural,
                      budget_bytes=int(budget), guard_bytes=guard_bytes,
                      components=comps, failures=failures)


# ---------------------------------------------------------------------------
# contract 2: grid-semantics race detector
# ---------------------------------------------------------------------------


@dataclass
class RaceReport:
    """Grid dims each written ref's state flows across vs the declaration."""

    kernel: str
    grid: Tuple[int, ...]
    dimension_semantics: Optional[Tuple[str, ...]]
    required_by_ref: Dict[str, List[int]]
    required: List[int]
    failures: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures

    def to_dict(self) -> Dict:
        return {"kernel": self.kernel, "ok": self.ok,
                "grid": list(self.grid),
                "dimension_semantics": (
                    list(self.dimension_semantics)
                    if self.dimension_semantics is not None else None),
                "required_by_ref": self.required_by_ref,
                "required": self.required, "failures": self.failures}


def _flow_dims(ngrid: int, reset: Optional[frozenset]) -> frozenset:
    """Dims a carried ref's state flows across given its reset dims.

    Row-major iteration: a reset guarded on dims S re-runs at the start of
    every segment where the dims major to S are constant, so state cannot
    outlive a change of dim d iff every reset dim is strictly minor
    (``S ⊆ {k : k > d}``).
    """
    if not reset:
        return frozenset(range(ngrid))
    return frozenset(
        d for d in range(ngrid)
        if not reset <= frozenset(range(d + 1, ngrid)))


def _revisits(ref: RefInfo, grid: Tuple[int, ...], limit: int = 1 << 16
              ) -> bool:
    """Whether two programs differing in the index map's dims share a block."""
    dims = sorted(ref.index_deps)
    if not dims or ref.index_map is None:
        return False
    if _prod(grid[d] for d in dims) > limit:
        return True                            # too big to check: assume yes
    seen = set()
    for combo in itertools.product(*(range(grid[d]) for d in dims)):
        idx = [0] * len(grid)
        for d, v in zip(dims, combo):
            idx[d] = v
        out = ref.index_map(*idx)
        if out in seen:
            return True
        seen.add(out)
    return False


def _ref_required_dims(ref: RefInfo, events: List[_Event],
                       grid: Tuple[int, ...]) -> frozenset:
    """Grid dims that must be 'arbitrary' on account of this ref."""
    writes = [e for e in events if e.kind != "get"]
    if not writes:
        return frozenset()
    ngrid = len(grid)
    if ref.kind == "scratch":
        shared = frozenset(range(ngrid))       # one buffer for all programs
    else:
        invariant = frozenset(range(ngrid)) - ref.index_deps
        shared = invariant | (ref.index_deps if _revisits(ref, grid)
                              else frozenset())
    if not shared:
        return frozenset()

    init_done = False
    carried = False
    reset: Optional[frozenset] = None
    for ev in events:
        if ev.kind == "swap" and ev.full and ev.guard == frozenset():
            if not carried:
                init_done = True
        elif ev.kind == "swap" and ev.full and ev.guard and \
                all(c == 0 for _, c in ev.guard):
            if reset is None:
                reset = frozenset(ax for ax, _ in ev.guard)
        else:
            if not init_done:
                carried = True
    if carried:
        return _flow_dims(ngrid, reset) & shared
    if ref.kind == "scratch":
        return frozenset()     # private temp: init'd then used per program
    # an output block overwritten whole by several programs: last writer
    # wins, so the shared dims still order the result
    return shared


def race_contract(cap: PallasCapture, *, kernel: str = "kernel") -> RaceReport:
    """Fail any 'parallel' grid dim the kernel body's order flows across."""
    by_ref = _ref_events(cap)
    required_by_ref: Dict[str, List[int]] = {}
    required: set = set()
    for rid, events in sorted(by_ref.items()):
        ref = cap.refs[rid]
        if ref.kind == "input":
            continue                            # read-only memory
        dims = _ref_required_dims(ref, events, cap.grid)
        if dims:
            required_by_ref[ref.name] = sorted(dims)
            required |= dims

    sem = cap.dimension_semantics
    failures: List[str] = []
    if sem is not None and len(sem) != len(cap.grid):
        failures.append(
            f"[megacore-race] {kernel}: {len(sem)} dimension_semantics "
            f"entries for a {len(cap.grid)}-dim grid")
        sem = None
    for d in sorted(required):
        culprits = [n for n, ds in required_by_ref.items() if d in ds]
        if sem is None:
            if cap.dimension_semantics is None:
                failures.append(
                    f"[megacore-race] {kernel}: grid dim {d} carries "
                    f"cross-program state ({', '.join(culprits)}) but no "
                    "dimension_semantics are declared — Mosaic may "
                    "parallelize it")
        elif sem[d] != "arbitrary":
            failures.append(
                f"[megacore-race] {kernel}: grid dim {d} carries "
                f"cross-program state ({', '.join(culprits)}) but is "
                f"declared '{sem[d]}' — Megacore partitioning would "
                "corrupt it")
    return RaceReport(kernel=kernel, grid=cap.grid,
                      dimension_semantics=cap.dimension_semantics,
                      required_by_ref=required_by_ref,
                      required=sorted(required), failures=failures)


# ---------------------------------------------------------------------------
# contract 3: static cost model
# ---------------------------------------------------------------------------

_ZERO_COST = frozenset({
    "get", "swap", "program_id", "iota", "broadcast_in_dim",
    "convert_element_type", "reshape", "transpose", "squeeze",
    "expand_dims", "slice", "dynamic_slice", "dynamic_update_slice",
    "concatenate", "copy", "stop_gradient", "bitcast_convert_type",
})

_REDUCE = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "reduce_xor", "argmax", "argmin", "cumsum", "cumprod",
    "cummax", "cummin", "cumlogsumexp",
})

_ELEMENTWISE = frozenset({
    "add", "sub", "mul", "div", "rem", "pow", "integer_pow", "neg", "abs",
    "sign", "floor", "ceil", "round", "exp", "log", "log1p", "expm1",
    "sqrt", "rsqrt", "tanh", "logistic", "max", "min", "and", "or", "xor",
    "not", "eq", "ne", "lt", "le", "gt", "ge", "select_n", "clamp",
    "is_finite", "erf", "sin", "cos", "square",
})


@dataclass
class CostReport:
    """Analytic per-invocation cost from the grid x BlockSpec structure."""

    kernel: str
    grid: Tuple[int, ...]
    flops: float
    bytes_in: int
    bytes_out: int
    bytes_touched: int
    intensity: float                      # FLOP per byte touched
    hbm_seconds: float                    # bytes_touched / peak HBM bw
    compute_seconds: float                # flops / peak fp32-ish rate
    per_operand: Dict[str, Dict]
    unmodeled: List[str] = field(default_factory=list)

    def to_dict(self) -> Dict:
        return {"kernel": self.kernel, "grid": list(self.grid),
                "flops": self.flops, "bytes_in": self.bytes_in,
                "bytes_out": self.bytes_out,
                "bytes_touched": self.bytes_touched,
                "intensity": self.intensity,
                "hbm_seconds": self.hbm_seconds,
                "compute_seconds": self.compute_seconds,
                "per_operand": self.per_operand,
                "unmodeled": self.unmodeled}


def _fetch_count(deps: frozenset, grid: Tuple[int, ...]) -> int:
    """Block fetches over the whole grid for an operand.

    Row-major order: the window only changes when a dim the index map
    depends on ticks, so consecutive programs share a fetch while the dims
    strictly minor than the most-major dependent dim cycle.
    """
    if not deps:
        return 1
    return _prod(grid[d] for d in range(max(deps) + 1))


def _guard_fraction(guard: Optional[frozenset],
                    grid: Tuple[int, ...]) -> float:
    if guard is None:
        return 1.0
    frac = 1.0
    for ax, _ in guard:
        frac /= max(grid[ax], 1)
    return frac


def _body_flops(jaxpr, env, grid, unmodeled: set) -> float:
    """FLOPs for one program's execution of ``jaxpr`` (guards weighted)."""
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "cond":
            g = _parse_guard(eqn.invars[0], env)
            f = _guard_fraction(g, grid)
            branches = eqn.params["branches"]
            for bi, br in enumerate(branches):
                sub = br.jaxpr
                if not sub.eqns:
                    continue
                w = f if bi == len(branches) - 1 else (
                    1.0 - f if g is not None else 1.0)
                env_b = dict(env)
                for bv, ov in zip(sub.invars, eqn.invars[1:]):
                    if not _is_literal(ov) and ov in env:
                        env_b[bv] = env[ov]
                total += w * _body_flops(sub, env_b, grid, unmodeled)
        elif prim == "dot_general":
            (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
            ls = eqn.invars[0].aval.shape
            rs = eqn.invars[1].aval.shape
            m = _prod(ls[i] for i in range(len(ls))
                      if i not in lc and i not in lb)
            n = _prod(rs[i] for i in range(len(rs))
                      if i not in rc and i not in rb)
            k = _prod(ls[i] for i in lc)
            b = _prod(ls[i] for i in lb)
            total += 2.0 * b * m * n * k
        elif prim in _REDUCE:
            total += float(_prod(eqn.invars[0].aval.shape))
        elif prim in _ELEMENTWISE:
            shape = eqn.outvars[0].aval.shape if eqn.outvars else ()
            total += float(_prod(shape))
        elif prim in _ZERO_COST:
            pass
        else:
            unmodeled.add(prim)
            shape = eqn.outvars[0].aval.shape if eqn.outvars else ()
            total += float(_prod(shape))
        for o in eqn.outvars:
            env[o] = eqn
    return total


def cost_model(cap: PallasCapture, *, kernel: str = "kernel") -> CostReport:
    """Analytic bytes-touched + FLOPs for one invocation of the kernel.

    Bytes: every operand is fetched (and every output written back) once
    per change of its window — ``restream > 1`` means the backing array is
    streamed through VMEM more than once per invocation (e.g. the whole
    ids/rows stream re-fetched for every vocab block). FLOPs: a weighted
    walk of the body (dot_general = 2mnk, reductions/elementwise = 1/elt,
    ``pl.when`` bodies weighted by the fraction of programs that run them)
    times the number of programs.
    """
    grid = cap.grid
    programs = _prod(grid)
    per_op: Dict[str, Dict] = {}
    bytes_in = bytes_out = 0
    for r in cap.refs:
        if r.kind == "scratch":
            continue
        fetches = _fetch_count(r.index_deps, grid)
        moved = fetches * r.block_bytes
        per_op[r.name] = {
            "kind": r.kind, "array_bytes": r.array_bytes,
            "block_bytes": r.block_bytes, "fetches": fetches,
            "fetched_bytes": moved,
            "restream": moved / r.array_bytes if r.array_bytes else 0.0,
        }
        if r.kind == "input":
            bytes_in += moved
        else:
            bytes_out += moved
    unmodeled: set = set()
    flops = programs * _body_flops(cap.jaxpr, {}, grid, unmodeled)
    touched = bytes_in + bytes_out
    return CostReport(
        kernel=kernel, grid=grid, flops=flops, bytes_in=bytes_in,
        bytes_out=bytes_out, bytes_touched=touched,
        intensity=flops / touched if touched else 0.0,
        hbm_seconds=touched / HW["hbm_bandwidth"],
        compute_seconds=flops / HW["peak_flops_bf16"],
        per_operand=per_op, unmodeled=sorted(unmodeled))


# ---------------------------------------------------------------------------
# per-kernel audit + registry coverage
# ---------------------------------------------------------------------------


@dataclass
class KernelReport:
    """All three contracts for one registered kernel."""

    name: str
    grid: Tuple[int, ...]
    vmem: VmemReport
    race: RaceReport
    cost: CostReport
    failures: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not (self.failures or self.vmem.failures or
                    self.race.failures)

    def to_dict(self) -> Dict:
        return {"name": self.name, "ok": self.ok, "grid": list(self.grid),
                "vmem": self.vmem.to_dict(), "race": self.race.to_dict(),
                "cost": self.cost.to_dict(), "failures": self.failures}


def audit_kernel(entry: KernelEntry, *,
                 budget: Optional[int] = None) -> KernelReport:
    """Capture one registered kernel and run all three contracts on it."""
    fn, args = entry.build()
    caps = capture_pallas_calls(fn, *args)
    failures: List[str] = []
    if len(caps) != 1:
        failures.append(
            f"[capture] {entry.name}: expected exactly one pallas_call in "
            f"the audit trace, found {len(caps)}")
    if not caps:
        return KernelReport(
            entry.name, (), VmemReport(entry.name, 0, 0, None, {}),
            RaceReport(entry.name, (), None, {}, []),
            CostReport(entry.name, (), 0.0, 0, 0, 0, 0.0, 0.0, 0.0, {}),
            failures)
    cap = caps[0]
    guard = entry.guard()
    return KernelReport(
        name=entry.name, grid=cap.grid,
        vmem=vmem_contract(cap, kernel=entry.name,
                           budget=budget if budget is not None
                           else entry.budget, guard=guard),
        race=race_contract(cap, kernel=entry.name),
        cost=cost_model(cap, kernel=entry.name),
        failures=failures)


def audit_all(registry=REGISTRY) -> List[KernelReport]:
    return [audit_kernel(e) for e in registry]


def registry_coverage() -> List[str]:
    """Every ``pl.pallas_call`` site in repro.kernels must be registered.

    Counts call sites in the package source (one kernel wrapper = one
    site) and compares against the registry, so a new kernel module cannot
    ship unaudited.
    """
    import pathlib

    import repro.kernels as pkg
    pkg_dir = pathlib.Path(pkg.__file__).parent
    sites: List[str] = []
    for path in sorted(pkg_dir.glob("*.py")):
        text = path.read_text()
        n = len(re.findall(r"\bpl\.pallas_call\s*\(", text))
        sites.extend([path.stem] * n)
    failures = []
    if len(sites) != len(REGISTRY):
        failures.append(
            f"[coverage] repro.kernels has {len(sites)} pallas_call sites "
            f"({', '.join(sites)}) but the audit registry lists "
            f"{len(REGISTRY)} kernels — register the new kernel in "
            "repro.kernels.introspect")
    return failures


# ---------------------------------------------------------------------------
# CLI: the CI gate
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="static Pallas VMEM/race/cost contracts over "
                    "repro.kernels")
    ap.add_argument("--json", default=None,
                    help="write the audit report to this path")
    args = ap.parse_args(argv)

    reports = audit_all()
    coverage = registry_coverage()
    report = {"ok": all(r.ok for r in reports) and not coverage,
              "coverage_failures": coverage,
              "kernels": [r.to_dict() for r in reports]}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)

    for r in reports:
        status = "OK" if r.ok else "FAIL"
        sem = r.race.dimension_semantics
        max_restream = max(
            (v["restream"] for v in r.cost.per_operand.values()),
            default=0.0)
        print(f"kernel_audit {status:4s} {r.name}: grid {r.grid} "
              f"semantics {sem}, vmem {r.vmem.structural_bytes}/"
              f"{r.vmem.budget_bytes} B, carried dims {r.race.required}, "
              f"{r.cost.flops:.3g} FLOP / {r.cost.bytes_touched} B "
              f"(max restream {max_restream:.1f}x)")
        for msg in (r.failures + r.vmem.failures + r.race.failures):
            print(f"  {msg}", file=sys.stderr)
    for msg in coverage:
        print(f"  {msg}", file=sys.stderr)
    if not report["ok"]:
        bad = [r.name for r in reports if not r.ok]
        print(f"kernel_audit: contracts FAILED ({', '.join(bad) or 'coverage'})",
              file=sys.stderr)
        return 1
    print(f"kernel_audit: all {len(reports)} kernel contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
