"""jit-hygiene linter: AST rules distilled from this repo's actual bug history.

Every rule below names a bug class a review sweep (PRs 2-6) caught by hand in
shipped code; the linter makes the catch mechanical before the vocab-sharded
and async engines multiply the number of jitted paths.

Rules
-----
``traced-float``
    ``float(x)`` / ``int(x)`` on a possibly-traced value inside a function
    reachable from ``jax.jit`` / ``shard_map`` / ``pl.pallas_call``. Host
    coercion of a tracer either crashes (ConcretizationTypeError) or — worse —
    silently bakes the value into the compiled program and forces a recompile
    per distinct value.

``host-numpy``
    ``np.*`` called on possibly-traced values in a traced context: host numpy
    forces a device sync per call and falls out of the compiled program.

``static-argnames-array``
    ``static_argnames`` naming a parameter annotated as an array: arrays are
    unhashable jit-cache keys at best, a compile per distinct value at worst.

``pallas-dim-semantics``
    Every ``pl.pallas_call`` must pass explicit ``dimension_semantics``
    (via ``compiler_params``): the silent ``"parallel"`` default corrupts any
    kernel that carries state across a grid dimension under Megacore
    partitioning (the union_segsum SMEM-carry bug class).

``pallas-blockspec-misaligned``
    A ``pl.BlockSpec`` whose literal block shape has a trailing dim pair
    that is not a multiple of the TPU (8, 128) tile (size-1 dims exempt):
    Mosaic pads or re-lays-out misaligned windows, silently wasting VMEM
    and bandwidth. Computed block picks (``_block_sizes`` helpers) are
    exempt — the kernel-audit plane checks those against the guards.

``data-dep-shape``
    ``jnp.unique`` / ``jnp.nonzero`` / ``jnp.flatnonzero`` / ``jnp.argwhere``
    without ``size=`` (or one-argument ``jnp.where``) in a traced context:
    data-dependent output shapes cannot be jitted.

``donated-reuse``
    A buffer passed to a donated argument of a jitted function is read again
    after the call: the donation invalidated it. The safe idiom rebinds the
    holder in the same statement (``self.state, m = step(self.state, ...)``).

``shard-full-aggregate``
    A ``shard_map`` body calls a full (heat-fused) aggregate
    (``aggregate_rowsparse`` / ``sparse_cohort_aggregate``) instead of
    ``aggregate_rowsparse_partial``: each shard holds a PARTIAL cohort, so
    the fused N/n_m heat correction applies per shard and the cross-shard
    combine then sums already-corrected partials — PR 5's double-correction
    bug class.

``shard-missing-psum``
    ``jnp.sum`` / ``jnp.mean`` (or ``.sum()`` / ``.mean()``) inside a
    ``shard_map`` body with no ``psum`` / ``pmean`` in reach: the result
    collapses the SHARD's slice only and silently reports one shard's value
    as the cohort's (PR 5's metrics bug class). Reductions that feed a
    collective — directly or through an assigned name — are exempt;
    deliberately per-shard values (``P(axis)`` out_specs) carry an explained
    suppression.

Traced-context heuristic
------------------------
A function is considered traced when it (a) is decorated with / passed to a
jax tracing entry point (``jit``, ``vmap``, ``grad``, ``value_and_grad``,
``shard_map``, ``pallas_call``, ``scan``, ``cond``, ``while_loop``,
``fori_loop``, ``checkify``, possibly through ``functools.partial``), (b) is
a module-level or nested non-method function whose own body uses ``jnp.*`` /
``lax.*``, or (c) is called (by name) from a traced function. Methods are
presumed host context — the trainer/dataset orchestration layer.

Values are exempt from ``traced-float`` / ``host-numpy`` when they are
statically known at trace time: shape-derived expressions (``.shape`` /
``.ndim`` / ``.size`` / ``.dtype`` / ``len()``), parameters annotated
``int`` / ``float`` / ``bool`` / ``str``, names assigned from static
expressions, module globals, and closures over host-context enclosing scopes.

Allowlist
---------
Append ``# repro-lint: ok <rule>[,<rule>] -- <reason>`` to the offending
line (or the line above it). The reason is mandatory: a suppression without
one is itself reported (``bare-allowlist``), so the lint exits clean only
with zero unexplained suppressions.

Usage
-----
    python -m repro.analysis.lint src/ [--json report.json] [--list-rules]

Exit status 0 iff no violations. Stdlib-only by design: the CI
static-analysis job runs this without jax installed.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import os
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "traced-float": "float()/int() coercion of a possibly-traced value "
                    "inside a jit/shard_map/pallas-reachable function",
    "host-numpy": "host np.* call on possibly-traced values in a traced "
                  "context",
    "static-argnames-array": "static_argnames naming an array-annotated "
                             "parameter",
    "pallas-dim-semantics": "pl.pallas_call without explicit "
                            "dimension_semantics (compiler_params)",
    "pallas-blockspec-misaligned": "pl.BlockSpec literal block shape with "
                                   "trailing dims off the (8, 128) TPU tile",
    "data-dep-shape": "data-dependent output shape (jnp.unique/nonzero/... "
                      "without size=) under jit",
    "donated-reuse": "donated buffer re-referenced after the donating call",
    "shard-full-aggregate": "full heat-fused aggregate called inside a "
                            "shard_map body (partial + combine required)",
    "shard-missing-psum": "per-shard jnp reduction in a shard_map body "
                          "with no psum/pmean in reach",
    "bare-allowlist": "repro-lint suppression without a ' -- reason'",
}

#: names that mark a call target as a jax tracing entry point
_TRACE_ENTRIES = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "shard_map",
    "pallas_call", "scan", "cond", "while_loop", "fori_loop", "checkify",
    "custom_jvp", "custom_vjp", "remat", "checkpoint",
}

#: annotation name tails that mark a parameter as array-valued
_ARRAY_ANNOTATIONS = {"Array", "ndarray", "ArrayLike"}

#: annotation names that mark a parameter as a static scalar
_SCALAR_ANNOTATIONS = {"int", "float", "bool", "str"}

#: builtins whose result is static when every argument is static
_STATIC_BUILTINS = {
    "int", "float", "bool", "str", "len", "min", "max", "abs", "round",
    "sum", "tuple", "list", "sorted", "range", "divmod", "pow", "getattr",
    "isinstance", "hasattr", "type",
}

#: attribute reads that are static regardless of the base value
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize"}

#: jnp callees with data-dependent output shapes unless size= is passed
_DATA_DEP_SHAPE_FNS = {"unique", "nonzero", "flatnonzero", "argwhere"}

_ALLOW_RE = re.compile(
    r"#\s*repro-lint:\s*ok\s+([a-z0-9*,\s-]+?)\s*(?:--\s*(\S.*))?$")


@dataclasses.dataclass
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Suppression:
    rule: str
    path: str
    line: int
    reason: str

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` as a string, or None for non-name expressions."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _name_tail(node: ast.AST) -> Optional[str]:
    """Last component of a (possibly dotted) callee name."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _annotation_names(ann: Optional[ast.AST]) -> Set[str]:
    if ann is None:
        return set()
    return {n.id for n in ast.walk(ann) if isinstance(n, ast.Name)} | {
        n.attr for n in ast.walk(ann) if isinstance(n, ast.Attribute)}


def _target_names(target: ast.AST) -> List[str]:
    """Dotted names bound by an assignment target (tuples flattened)."""
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(_target_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    d = _dotted(target)
    return [d] if d else []


def _own_statements(fn: ast.AST) -> Iterable[ast.AST]:
    """Every node of ``fn``'s own scope (nested def/class bodies excluded)."""
    for stmt in fn.body:
        yield from _walk_scope(stmt)


def _walk_scope(node: ast.AST) -> Iterable[ast.AST]:
    yield node
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        # the nested scope's body belongs to the nested scope; its decorators
        # and defaults still evaluate in ours
        for dec in getattr(node, "decorator_list", []):
            yield from _walk_scope(dec)
        return
    if isinstance(node, ast.Lambda):
        return
    for child in ast.iter_child_nodes(node):
        yield from _walk_scope(child)


def _flat_stmts(body: Sequence[ast.stmt]) -> Iterable[ast.stmt]:
    """Statements of a scope in source order, control-flow bodies flattened,
    nested function/class scopes skipped."""
    for st in body:
        yield st
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            continue
        for attr in ("body", "orelse", "finalbody"):
            sub = getattr(st, attr, None)
            if sub:
                yield from _flat_stmts(sub)
        for handler in getattr(st, "handlers", []) or []:
            yield from _flat_stmts(handler.body)


def _uses_tracer_namespace(fn: ast.AST) -> bool:
    """Does the function's own scope touch ``jnp.*`` / ``lax.*``?

    ``jax.random`` / ``jax.tree`` do not count: they are routine in host
    orchestration (seeding, pytree bookkeeping) and would misclassify it.
    """
    for node in _own_statements(fn):
        if isinstance(node, ast.Attribute):
            d = _dotted(node)
            if d and (d.startswith("jnp.") or d.startswith("lax.")
                      or d.startswith("jax.numpy.") or d.startswith("jax.lax.")):
                return True
    return False


# ---------------------------------------------------------------------------
# per-module analysis
# ---------------------------------------------------------------------------


class _FuncInfo:
    __slots__ = ("node", "parent", "is_method", "traced", "static_names")

    def __init__(self, node, parent, is_method):
        self.node = node
        self.parent = parent          # enclosing _FuncInfo or None (module)
        self.is_method = is_method
        self.traced = False
        self.static_names: Set[str] = set()


class _ModuleIndex(ast.NodeVisitor):
    """Collects the function table + module-global bindings."""

    def __init__(self):
        self.funcs: List[_FuncInfo] = []
        self.by_node: Dict[ast.AST, _FuncInfo] = {}
        self.by_name: Dict[str, List[_FuncInfo]] = {}
        self.globals: Set[str] = set()
        self._stack: List[_FuncInfo] = []
        self._class_depth = 0

    def visit_Module(self, node):
        for st in node.body:
            if isinstance(st, (ast.Import, ast.ImportFrom)):
                for alias in st.names:
                    self.globals.add((alias.asname or alias.name).split(".")[0])
            elif isinstance(st, ast.Assign):
                for t in st.targets:
                    self.globals.update(_target_names(t))
            elif isinstance(st, ast.AnnAssign) and st.target is not None:
                self.globals.update(_target_names(st.target))
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                self.globals.add(st.name)
        self.generic_visit(node)

    def _visit_func(self, node):
        info = _FuncInfo(node, self._stack[-1] if self._stack else None,
                         is_method=self._class_depth > 0 and not self._stack)
        self.funcs.append(info)
        self.by_node[node] = info
        self.by_name.setdefault(node.name, []).append(info)
        self._stack.append(info)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_ClassDef(self, node):
        self._class_depth += 1
        self.generic_visit(node)
        self._class_depth -= 1


def _mark_traced(index: _ModuleIndex, tree: ast.Module) -> None:
    """Seed + propagate the traced-context marking over the function table."""
    # (a) explicit roots: decorators and arguments of tracing entry points
    explicit: Set[str] = set()
    for info in index.funcs:
        for dec in info.node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            tail = _name_tail(target)
            if tail in _TRACE_ENTRIES or tail == "partial":
                inner = None
                if isinstance(dec, ast.Call) and dec.args:
                    inner = _name_tail(dec.args[0])
                if tail in _TRACE_ENTRIES or inner in _TRACE_ENTRIES:
                    info.traced = True
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        tail = _name_tail(node.func)
        if tail not in _TRACE_ENTRIES:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name):
                explicit.add(arg.id)
            elif (isinstance(arg, ast.Call)
                  and _name_tail(arg.func) == "partial" and arg.args
                  and isinstance(arg.args[0], ast.Name)):
                explicit.add(arg.args[0].id)
    for name in explicit:
        for info in index.by_name.get(name, []):
            info.traced = True

    # (b) presumption: non-method functions whose own scope uses jnp/lax
    for info in index.funcs:
        if not info.is_method and _uses_tracer_namespace(info.node):
            info.traced = True

    # (c) downward call-graph propagation (by bare callee name)
    changed = True
    while changed:
        changed = False
        for info in index.funcs:
            if not info.traced:
                continue
            for node in _own_statements(info.node):
                if isinstance(node, ast.Call) and isinstance(node.func,
                                                             ast.Name):
                    for callee in index.by_name.get(node.func.id, []):
                        if not callee.traced and not callee.is_method:
                            callee.traced = True
                            changed = True


# ---------------------------------------------------------------------------
# static-provenance analysis
# ---------------------------------------------------------------------------


def _scope_bindings(fn: ast.AST) -> Set[str]:
    """Every name the function's own scope binds (params + assignments)."""
    out: Set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        out.add(a.arg)
    for node in _own_statements(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                out.update(_target_names(t))
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            out.update(_target_names(node.target))
        elif isinstance(node, ast.For):
            out.update(_target_names(node.target))
        elif isinstance(node, ast.withitem) and node.optional_vars:
            out.update(_target_names(node.optional_vars))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            out.add(node.name)
    return out


def _host_closure_names(info: _FuncInfo, index: _ModuleIndex) -> Set[str]:
    """Names bound by host-context enclosing scopes (static for ``info``)."""
    out: Set[str] = set(index.globals)
    cur = info.parent
    while cur is not None:
        if not cur.traced:
            out.update(_scope_bindings(cur.node))
        cur = cur.parent
    return out


class _StaticScope:
    """Static-provenance tracking for one function scope."""

    def __init__(self, info: _FuncInfo, index: _ModuleIndex):
        self.static: Set[str] = set()
        self.closure = _host_closure_names(info, index)
        fn = info.node
        args = fn.args
        all_args = args.posonlyargs + args.args + args.kwonlyargs
        defaults = list(args.defaults)
        # align positional defaults to the tail of (posonly + args)
        pos = args.posonlyargs + args.args
        defaulted = {a.arg for a, _ in zip(pos[len(pos) - len(defaults):],
                                           defaults)}
        kw_defaulted = {a.arg for a, d in zip(args.kwonlyargs,
                                              args.kw_defaults) if d is not None}
        for a in all_args:
            names = _annotation_names(a.annotation)
            if names & _SCALAR_ANNOTATIONS and not names & _ARRAY_ANNOTATIONS:
                self.static.add(a.arg)
        # parameters with scalar-constant defaults and no annotation are
        # treated as static knobs (block sizes, flags)
        for a, d in zip(pos[len(pos) - len(defaults):], defaults):
            if a.annotation is None and isinstance(d, ast.Constant) \
                    and not isinstance(d.value, (bytes,)):
                self.static.add(a.arg)
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if d is not None and a.annotation is None \
                    and isinstance(d, ast.Constant):
                self.static.add(a.arg)
        del defaulted, kw_defaulted
        # fixpoint over this scope's assignments
        for _ in range(3):
            changed = False
            for node in _own_statements(fn):
                if isinstance(node, ast.Assign):
                    if self.is_static(node.value):
                        for t in node.targets:
                            for n in _target_names(t):
                                if n not in self.static:
                                    self.static.add(n)
                                    changed = True
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    names = _annotation_names(node.annotation)
                    if (names & _SCALAR_ANNOTATIONS
                            or self.is_static(node.value)):
                        for n in _target_names(node.target):
                            if n not in self.static:
                                self.static.add(n)
                                changed = True
                elif isinstance(node, ast.For):
                    if self.is_static(node.iter):
                        for n in _target_names(node.target):
                            if n not in self.static:
                                self.static.add(n)
                                changed = True
                elif isinstance(node, (ast.GeneratorExp, ast.ListComp,
                                       ast.SetComp, ast.DictComp)):
                    for g in node.generators:
                        if self.is_static(g.iter):
                            for n in _target_names(g.target):
                                if n not in self.static:
                                    self.static.add(n)
                                    changed = True
            if not changed:
                break

    def is_static(self, e: ast.AST) -> bool:
        """Is ``e`` statically known at trace time (never a tracer)?"""
        if isinstance(e, ast.Constant):
            return True
        if isinstance(e, ast.Name):
            return e.id in self.static or e.id in self.closure
        if isinstance(e, ast.Attribute):
            if e.attr in _STATIC_ATTRS:
                return True
            return self.is_static(e.value)
        if isinstance(e, ast.Subscript):
            return self.is_static(e.value)
        if isinstance(e, ast.Call):
            tail = _name_tail(e.func)
            root = _dotted(e.func) or ""
            callable_ok = (tail in _STATIC_BUILTINS
                           or root.startswith("np.")
                           or root.startswith("numpy.")
                           or root.startswith("math."))
            if not callable_ok:
                return False
            return all(self.is_static(a) for a in e.args) and all(
                self.is_static(kw.value) for kw in e.keywords)
        if isinstance(e, ast.BinOp):
            return self.is_static(e.left) and self.is_static(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.is_static(e.operand)
        if isinstance(e, ast.BoolOp):
            return all(self.is_static(v) for v in e.values)
        if isinstance(e, ast.Compare):
            return self.is_static(e.left) and all(
                self.is_static(c) for c in e.comparators)
        if isinstance(e, ast.IfExp):
            return (self.is_static(e.test) and self.is_static(e.body)
                    and self.is_static(e.orelse))
        if isinstance(e, (ast.Tuple, ast.List)):
            return all(self.is_static(x) for x in e.elts)
        if isinstance(e, ast.Starred):
            return self.is_static(e.value)
        if isinstance(e, ast.GeneratorExp):
            # sum(... for k in feature_keys)-style reductions over static
            # iterables of static expressions
            return all(self.is_static(g.iter) for g in e.generators) \
                and self.is_static(e.elt)
        return False


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------


def _check_traced_coercions(info: _FuncInfo, index: _ModuleIndex, path: str,
                            out: List[Violation]) -> None:
    scope = _StaticScope(info, index)
    for node in _own_statements(info.node):
        if not isinstance(node, ast.Call):
            continue
        tail = _name_tail(node.func)
        root = _dotted(node.func) or ""
        if isinstance(node.func, ast.Name) and tail in ("float", "int") \
                and len(node.args) == 1 and not node.keywords:
            if not scope.is_static(node.args[0]):
                out.append(Violation(
                    "traced-float", path, node.lineno, node.col_offset,
                    f"{tail}() on a possibly-traced value in "
                    f"{info.node.name}(): use jnp casts, or annotate the "
                    "source as a static scalar"))
        elif root.startswith("np.") or root.startswith("numpy."):
            args = list(node.args) + [kw.value for kw in node.keywords]
            if args and not all(scope.is_static(a) for a in args):
                out.append(Violation(
                    "host-numpy", path, node.lineno, node.col_offset,
                    f"host {root}() on possibly-traced values in "
                    f"{info.node.name}(): use the jnp equivalent"))


def _check_data_dep_shapes(info: _FuncInfo, path: str,
                           out: List[Violation]) -> None:
    for node in _own_statements(info.node):
        if not isinstance(node, ast.Call):
            continue
        root = _dotted(node.func) or ""
        if not (root.startswith("jnp.") or root.startswith("jax.numpy.")):
            continue
        tail = _name_tail(node.func)
        kwargs = {kw.arg for kw in node.keywords}
        if tail in _DATA_DEP_SHAPE_FNS and "size" not in kwargs:
            out.append(Violation(
                "data-dep-shape", path, node.lineno, node.col_offset,
                f"jnp.{tail} without size= in {info.node.name}(): the "
                "output shape is data-dependent and cannot be jitted"))
        elif tail == "where" and len(node.args) == 1 and not kwargs:
            out.append(Violation(
                "data-dep-shape", path, node.lineno, node.col_offset,
                f"one-argument jnp.where in {info.node.name}() is "
                "jnp.nonzero in disguise: pass size= via jnp.nonzero"))


def _check_pallas_semantics(tree: ast.Module, index: _ModuleIndex, path: str,
                            out: List[Violation]) -> None:
    def encloser(node):
        best = None
        for info in index.funcs:
            f = info.node
            if (f.lineno <= node.lineno <= (f.end_lineno or f.lineno)
                    and (best is None or f.lineno > best.node.lineno)):
                best = info
        return best

    def binds_compiler_params(fn: ast.AST) -> bool:
        for n in _own_statements(fn):
            if isinstance(n, ast.Call) and any(
                    kw.arg == "compiler_params" for kw in n.keywords):
                return True
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    if (isinstance(t, ast.Subscript)
                            and isinstance(t.slice, ast.Constant)
                            and t.slice.value == "compiler_params"):
                        return True
        return False

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        tail = _name_tail(node.func)
        if tail == "pallas_call":
            if any(kw.arg == "compiler_params" for kw in node.keywords):
                continue
            info = encloser(node)
            if info is not None and binds_compiler_params(info.node):
                continue
            out.append(Violation(
                "pallas-dim-semantics", path, node.lineno, node.col_offset,
                "pl.pallas_call without compiler_params: pass explicit "
                "dimension_semantics (Megacore partitioning corrupts "
                "grid-carried state under the silent 'parallel' default)"))
        elif tail == "TPUCompilerParams":
            if not any(kw.arg == "dimension_semantics"
                       for kw in node.keywords):
                out.append(Violation(
                    "pallas-dim-semantics", path, node.lineno,
                    node.col_offset,
                    "TPUCompilerParams without dimension_semantics"))
        elif tail and tail.endswith("compiler_params") and tail != \
                "compiler_params":
            # helper wrappers (e.g. _tpu_compiler_params): a bare zero-
            # argument call inherits whatever default the helper bakes in —
            # the call site must state the grid's semantics
            if not node.args and not any(
                    kw.arg in ("semantics", "dimension_semantics")
                    for kw in node.keywords):
                out.append(Violation(
                    "pallas-dim-semantics", path, node.lineno,
                    node.col_offset,
                    f"{tail}() call relies on the helper's default "
                    "dimension_semantics: pass them explicitly per grid"))


def _check_blockspec_alignment(tree: ast.Module, path: str,
                               out: List[Violation]) -> None:
    """pallas-blockspec-misaligned: literal block shapes off the TPU tile.

    Only ALL-literal shapes are judged — a computed dim (``v_blk``, ``hd``)
    means the block pick flows through a ``_block_sizes`` helper, which the
    kernel-audit plane pins against the kernel's guard instead. Size-1 dims
    are exempt: squeezed / leading axes are laid out for free.
    """
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _name_tail(node.func) == "BlockSpec"):
            continue
        shape_node = node.args[0] if node.args else None
        if shape_node is None:
            for kw in node.keywords:
                if kw.arg == "block_shape":
                    shape_node = kw.value
        if not isinstance(shape_node, (ast.Tuple, ast.List)):
            continue
        elts = shape_node.elts
        if not elts or not all(isinstance(e, ast.Constant)
                               and isinstance(e.value, int)
                               for e in elts):
            continue
        dims = [e.value for e in elts]
        bad: List[str] = []
        last = dims[-1]
        if last != 1 and last % 128 != 0:
            bad.append(f"lane dim {last} is not a multiple of 128")
        if len(dims) >= 2:
            sub = dims[-2]
            if sub != 1 and sub % 8 != 0:
                bad.append(f"sublane dim {sub} is not a multiple of 8")
        if bad:
            out.append(Violation(
                "pallas-blockspec-misaligned", path, node.lineno,
                node.col_offset,
                f"pl.BlockSpec block shape {tuple(dims)}: "
                f"{'; '.join(bad)} — TPU tiles are (8, 128), so Mosaic "
                "pads/re-lays-out this window, wasting VMEM and bandwidth"))


def _static_argnames_values(call: ast.Call) -> List[Tuple[str, ast.AST]]:
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            return [(v.value, v)]
        if isinstance(v, (ast.Tuple, ast.List)):
            return [(e.value, e) for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def _check_static_argnames(tree: ast.Module, index: _ModuleIndex, path: str,
                           out: List[Violation]) -> None:
    def annotated_array_params(fn: ast.AST) -> Set[str]:
        bad = set()
        args = fn.args
        for a in args.posonlyargs + args.args + args.kwonlyargs:
            names = _annotation_names(a.annotation)
            if names & _ARRAY_ANNOTATIONS:
                bad.add(a.arg)
        return bad

    # decorator form: @functools.partial(jax.jit, static_argnames=...) / the
    # call form jax.jit(f, static_argnames=...) with f a module function
    for info in index.funcs:
        fn = info.node
        bad = annotated_array_params(fn)
        if not bad:
            continue
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call):
                for name, node in _static_argnames_values(dec):
                    if name in bad:
                        out.append(Violation(
                            "static-argnames-array", path, node.lineno,
                            node.col_offset,
                            f"static_argnames={name!r} on {fn.name}() names "
                            "an array-annotated parameter: arrays are not "
                            "hashable jit-cache keys"))
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _name_tail(node.func) == "jit"):
            continue
        names = _static_argnames_values(node)
        if not names or not node.args or not isinstance(node.args[0], ast.Name):
            continue
        for target in index.by_name.get(node.args[0].id, []):
            bad = annotated_array_params(target.node)
            for name, vnode in names:
                if name in bad:
                    out.append(Violation(
                        "static-argnames-array", path, vnode.lineno,
                        vnode.col_offset,
                        f"static_argnames={name!r} on "
                        f"{target.node.name}() names an array-annotated "
                        "parameter: arrays are not hashable jit-cache keys"))


def _donating_call(node: ast.Call) -> Optional[Set[int]]:
    """Donated positional indices if ``node`` constructs a donated callable."""
    for kw in node.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)) and all(
                    isinstance(e, ast.Constant) for e in v.elts):
                idx = {e.value for e in v.elts if isinstance(e.value, int)}
                return idx if idx else None    # empty literal: no donation
            return {0}                         # non-literal: assume arg 0
    return None


def _check_donated_reuse(tree: ast.Module, index: _ModuleIndex, path: str,
                         out: List[Violation]) -> None:
    donated: Dict[str, Set[int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            idx = _donating_call(node.value)
            if idx:
                for t in node.targets:
                    for name in _target_names(t):
                        donated[name] = idx
    if not donated:
        return

    for info in index.funcs:
        active: Dict[str, Tuple[int, int]] = {}   # dotted name -> call pos
        for st in _flat_stmts(info.node.body):
            if active:
                for n in ast.walk(st):
                    if isinstance(n, (ast.Name, ast.Attribute)) \
                            and isinstance(getattr(n, "ctx", None), ast.Load):
                        d = _dotted(n)
                        if d in active:
                            line, _ = active.pop(d)
                            out.append(Violation(
                                "donated-reuse", path, n.lineno, n.col_offset,
                                f"{d!r} was donated at line {line} and is "
                                "re-referenced here: the donation "
                                "invalidated the buffer — rebind it in the "
                                "donating statement"))
            targets: List[str] = []
            if isinstance(st, ast.Assign):
                for t in st.targets:
                    targets.extend(_target_names(t))
            elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
                targets.extend(_target_names(st.target))
            for name in targets:
                active.pop(name, None)
            for n in ast.walk(st):
                if isinstance(n, ast.Call):
                    callee = _dotted(n.func)
                    if callee in donated:
                        for i in donated[callee]:
                            if i < len(n.args):
                                d = _dotted(n.args[i])
                                if d and d not in targets:
                                    active[d] = (n.lineno, n.col_offset)


#: full (heat-fused) aggregates that must never run per shard: inside a
#: shard_map body each shard sees a PARTIAL cohort, so the fused N/n_m heat
#: correction would apply per shard and then be summed across shards
_SHARD_BANNED_AGGREGATES = {"aggregate_rowsparse", "sparse_cohort_aggregate"}

#: collective callees that legitimately consume a per-shard reduction
_COLLECTIVE_CALLS = {"psum", "pmean", "pmax", "pmin", "all_gather",
                     "all_to_all", "ppermute", "psum_scatter"}

#: reduction callee tails that collapse a per-shard axis
_REDUCTION_TAILS = {"sum", "mean"}


def _walk_shard_scope(root: ast.AST) -> Iterable[ast.AST]:
    """Own scope of a function/lambda, DESCENDING into lambdas.

    Unlike :func:`_walk_scope`, lambda bodies are included: a shard_map body
    is routinely ``lambda p, d, c: body(p, d, None, c)`` and the reference
    to ``body`` lives inside the lambda. Nested def/class scopes are still
    excluded — they are marked as their own shard scopes when referenced.
    """
    stack = [root.body] if isinstance(root, ast.Lambda) else list(root.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _shard_scopes(index: _ModuleIndex, tree: ast.Module) -> List[ast.AST]:
    """Scopes that execute inside a ``shard_map`` body.

    Roots: the callable passed to ``shard_map`` (first positional argument,
    possibly a lambda or a ``partial``). Propagation: any module function a
    shard scope references by name joins the set, to fixpoint — the body
    helpers (``run_local``, ``agg_leaf``-style tree_map callbacks) execute
    under the same mesh axis. Within-module only, so a sparse-plane module
    that merely DEFINES combine helpers is never marked.
    """
    scopes: List[ast.AST] = []
    seen: Set[ast.AST] = set()
    work: List[ast.AST] = []
    names: Set[str] = set()
    done: Set[str] = set()

    def add_scope(node: ast.AST) -> None:
        if node not in seen:
            seen.add(node)
            work.append(node)

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _name_tail(node.func) == "shard_map"):
            continue
        cand = node.args[0] if node.args else None
        if cand is None:
            for kw in node.keywords:
                if kw.arg in ("f", "fun"):
                    cand = kw.value
        if isinstance(cand, ast.Lambda):
            add_scope(cand)
        elif isinstance(cand, ast.Call) and _name_tail(cand.func) == "partial" \
                and cand.args:
            t = _name_tail(cand.args[0])
            if t:
                names.add(t)
        elif cand is not None:
            t = _name_tail(cand)
            if t:
                names.add(t)

    while work or names - done:
        for name in sorted(names - done):
            done.add(name)
            for info in index.by_name.get(name, []):
                add_scope(info.node)
        while work:
            scope = work.pop()
            scopes.append(scope)
            for sub in _walk_shard_scope(scope):
                if isinstance(sub, ast.Name) \
                        and isinstance(sub.ctx, ast.Load) \
                        and sub.id in index.by_name:
                    names.add(sub.id)
    return scopes


def _check_shard_hygiene(tree: ast.Module, index: _ModuleIndex, path: str,
                         out: List[Violation]) -> None:
    """shard-full-aggregate + shard-missing-psum over every shard scope."""
    for scope in _shard_scopes(index, tree):
        sname = getattr(scope, "name", "<lambda>")
        nodes = list(_walk_shard_scope(scope))
        # reductions nested under a collective call are combined on the spot
        exempt: Set[ast.AST] = set()
        fed: Set[str] = set()     # names a collective consumes later
        for n in nodes:
            if isinstance(n, ast.Call) \
                    and _name_tail(n.func) in _COLLECTIVE_CALLS:
                exempt.update(ast.walk(n))
                for a in list(n.args) + [kw.value for kw in n.keywords]:
                    fed.update(s.id for s in ast.walk(a)
                               if isinstance(s, ast.Name))
        # reductions whose assigned name feeds a collective elsewhere in the
        # scope are the two-statement combine idiom
        for n in nodes:
            if isinstance(n, ast.Assign):
                tnames: List[str] = []
                for t in n.targets:
                    tnames.extend(_target_names(t))
                if any(t in fed for t in tnames):
                    exempt.update(ast.walk(n.value))
        for n in nodes:
            if not isinstance(n, ast.Call):
                continue
            tail = _name_tail(n.func)
            if tail in _SHARD_BANNED_AGGREGATES:
                out.append(Violation(
                    "shard-full-aggregate", path, n.lineno, n.col_offset,
                    f"{tail}() inside the shard_map body {sname}(): each "
                    "shard holds a PARTIAL cohort, so the fused heat "
                    "correction applies per shard and the cross-shard "
                    "combine sums already-corrected partials — use "
                    "aggregate_rowsparse_partial + "
                    "combine_rowsparse_partials"))
                continue
            root = _dotted(n.func) or ""
            is_reduction = (tail in _REDUCTION_TAILS and (
                root.startswith("jnp.") or root.startswith("jax.numpy.")
                or (isinstance(n.func, ast.Attribute)
                    and not root.startswith("np.")
                    and not root.startswith("numpy."))))
            if not is_reduction or n in exempt:
                continue
            if any(kw.arg == "axis_name" for kw in n.keywords):
                continue
            out.append(Violation(
                "shard-missing-psum", path, n.lineno, n.col_offset,
                f"{tail}() reduction in the shard_map body {sname}() with "
                "no psum/pmean in reach: the result collapses this SHARD's "
                "slice only — combine it over the mesh axis "
                "(jax.lax.psum/pmean), or suppress with the per-shard "
                "intent explained"))


# ---------------------------------------------------------------------------
# allowlist + driver
# ---------------------------------------------------------------------------


def _collect_allowlist(source: str, path: str):
    """line -> (rules, reason); plus bare-suppression violations.

    A suppression comment covers its own line and — when the comment block
    stands alone — every following comment-only continuation line plus the
    first code line after it, so multi-line explanations stay legal.
    """
    allow: Dict[int, Tuple[Set[str], Optional[str]]] = {}
    bare: List[Violation] = []
    lines = source.splitlines()
    for i, line in enumerate(lines, start=1):
        m = _ALLOW_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        # the reason may continue onto following comment-only lines
        reason = m.group(2)
        j = i
        while j < len(lines) and lines[j].strip().startswith("#"):
            if reason is None:
                cont = lines[j].strip().lstrip("#").strip()
                if cont.startswith("--"):
                    cont = cont[2:].strip()
                reason = cont or None
            j += 1
        unknown = {r for r in rules if r != "*" and r not in RULES}
        if unknown:
            bare.append(Violation(
                "bare-allowlist", path, i, 0,
                f"repro-lint suppression names unknown rule(s) "
                f"{sorted(unknown)}"))
        if not reason:
            bare.append(Violation(
                "bare-allowlist", path, i, 0,
                "repro-lint suppression without a ' -- reason': every "
                "allowlisted line must explain itself"))
        entry = (rules, reason)
        allow[i] = entry
        # comment-only suppression: extend through the block to the first
        # code line it annotates
        if line.strip().startswith("#"):
            for k in range(i + 1, j + 2):
                allow.setdefault(k, entry)
    return allow, bare


def lint_source(source: str, path: str):
    """Lint one module's source. Returns (violations, suppressions)."""
    allow, bare = _collect_allowlist(source, path)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Violation("traced-float", path, e.lineno or 0, 0,
                          f"syntax error: {e.msg}")], []
    index = _ModuleIndex()
    index.visit(tree)
    _mark_traced(index, tree)

    raw: List[Violation] = []
    for info in index.funcs:
        if info.traced:
            _check_traced_coercions(info, index, path, raw)
            _check_data_dep_shapes(info, path, raw)
    _check_pallas_semantics(tree, index, path, raw)
    _check_blockspec_alignment(tree, path, raw)
    _check_static_argnames(tree, index, path, raw)
    _check_donated_reuse(tree, index, path, raw)
    _check_shard_hygiene(tree, index, path, raw)

    violations: List[Violation] = list(bare)
    suppressions: List[Suppression] = []
    for v in raw:
        hit = None
        for line in (v.line, v.line - 1):
            entry = allow.get(line)
            if entry and ("*" in entry[0] or v.rule in entry[0]):
                hit = entry
                break
        if hit and hit[1]:
            suppressions.append(Suppression(v.rule, path, v.line, hit[1]))
        elif hit:                      # suppressed but unexplained: already a
            continue                   # bare-allowlist violation on that line
        else:
            violations.append(v)
    return violations, suppressions


def iter_python_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if not d.startswith(".") and d != "__pycache__")
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def lint_paths(paths: Sequence[str]):
    """Lint every .py file under ``paths``; returns (violations,
    suppressions, files_scanned)."""
    violations: List[Violation] = []
    suppressions: List[Suppression] = []
    count = 0
    for path in iter_python_files(paths):
        count += 1
        with open(path, encoding="utf-8") as f:
            source = f.read()
        v, s = lint_source(source, path)
        violations.extend(v)
        suppressions.extend(s)
    return violations, suppressions, count


def report_dict(violations, suppressions, files_scanned: int,
                paths: Sequence[str]) -> Dict[str, object]:
    return {
        "tool": "repro.analysis.lint",
        "version": 1,
        "paths": list(paths),
        "files_scanned": files_scanned,
        "rules": dict(RULES),
        "ok": not violations,
        "violations": [v.as_dict() for v in violations],
        "suppressions": [s.as_dict() for s in suppressions],
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jit-hygiene linter (see module docstring for the rules)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the machine-readable report to PATH")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, desc in RULES.items():
            print(f"{name}: {desc}")
        return 0

    paths = args.paths or ["src"]
    violations, suppressions, count = lint_paths(paths)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report_dict(violations, suppressions, count, paths),
                      f, indent=2)
    if not args.quiet:
        for v in violations:
            print(f"{v.path}:{v.line}:{v.col}: [{v.rule}] {v.message}",
                  file=sys.stderr)
        status = "clean" if not violations else f"{len(violations)} violation(s)"
        print(f"repro-lint: {count} file(s), {status}, "
              f"{len(suppressions)} explained suppression(s)")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
