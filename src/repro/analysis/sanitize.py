"""Runtime sanitizer: checkify-backed RowSparse contract checks.

The sparse plane's invariants (ids sorted, pads trailing, bounds, zeroed
pad rows, largest-first capacity drops) are enforced by construction in
:mod:`repro.sparse` — and silently wrong the moment a caller hand-builds a
``RowSparse`` or re-orders ids.  This module makes the contract *checkable
in-jit*: each ``check_*`` function emits ``checkify.check`` predicates that
compile away unless the caller functionalises them, and
:func:`checked_jit` is the one-stop wrapper that functionalises + jits +
throws.

Wired into the round plane behind ``RoundPlan(debug_checks=True)``:
off by default (zero cost — the checks are simply not traced), and when on
the compiled program is *numerically identical* (the parity tests pin
bit-identical losses/params/RNG), it just also validates its inputs.

``checkify.check`` calls require functionalisation; calling a
check-emitting function under plain ``jax.jit`` raises. Always go through
:func:`checked_jit` (or ``checkify.checkify`` yourself).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import checkify

from repro.sparse.rowsparse import RowSparse, membership

__all__ = [
    "checked_jit",
    "check_union_ids",
    "check_rowsparse",
    "check_drop_order",
    "check_capacity",
]


def checked_jit(fn: Callable, **jit_kwargs) -> Callable:
    """``jax.jit`` + checkify functionalisation + eager throw.

    Returns a callable with ``fn``'s signature whose compiled body carries
    the user checks; any failed predicate raises
    ``jax.experimental.checkify.JaxRuntimeError`` at the call site.  The
    underlying jitted function is exposed for cache inspection
    (``wrapper._cache_size``) so ``jit_cache_guard`` still works.

    Do not re-wrap the result in ``jax.jit`` — it is already compiled, and
    an outer jit would trip on the check effects.
    """
    checked = checkify.checkify(fn, errors=checkify.user_checks)
    jitted = jax.jit(checked, **jit_kwargs)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        err, out = jitted(*args, **kwargs)
        err.throw()
        return out

    wrapper._checked = jitted
    wrapper._cache_size = jitted._cache_size
    return wrapper


def _pad_mask(ids):
    return ids < 0


def check_union_ids(ids, vocab: int, *, name: str = "ids") -> None:
    """Assert the ``unique_ids_padded`` contract on ``ids`` (last axis).

    - pads are exactly ``-1`` and trailing,
    - real ids strictly ascending (union ids are unique),
    - real ids in ``[0, vocab)``.

    Broadcasts over leading (cohort / stacked) axes.
    """
    pad = _pad_mask(ids)
    checkify.check(
        jnp.all(jnp.where(pad, ids == -1, True)),
        f"{name}: negative id that is not the -1 pad sentinel")
    # pads trailing <=> padness is monotone non-decreasing along the slot axis
    checkify.check(
        jnp.all(pad[..., 1:] >= pad[..., :-1]),
        f"{name}: -1 pad slot precedes a real id (pads must be trailing)")
    both_real = (~pad[..., 1:]) & (~pad[..., :-1])
    checkify.check(
        jnp.all(jnp.where(both_real, ids[..., 1:] > ids[..., :-1], True)),
        f"{name}: ids not strictly ascending (must be sorted and unique)")
    checkify.check(
        jnp.all(jnp.where(~pad, ids < vocab, True)),
        f"{name}: id out of range (>= vocab)")


def check_rowsparse(rs: RowSparse, *, name: str = "delta") -> None:
    """Assert the full RowSparse leaf contract: id contract + zeroed pads."""
    check_union_ids(rs.ids, rs.num_rows, name=f"{name}.ids")
    pad = _pad_mask(rs.ids)
    pad = pad.reshape(pad.shape + (1,) * (rs.rows.ndim - rs.ids.ndim))
    checkify.check(
        jnp.all(jnp.where(pad, rs.rows == 0, True)),
        f"{name}.rows: non-zero payload in a -1 pad slot")


def check_drop_order(ids, tokens, *, name: str = "ids") -> None:
    """Assert capacity drops were largest-first.

    ``ids`` is an unbatched ``unique_ids_padded`` union of ``tokens``.  A
    non-negative token absent from the union is legal only when the union
    is full *and* the token is larger than every kept id — the smallest-
    kept / largest-dropped ordering the comm accounting prices.
    """
    member = membership(tokens, ids)
    real = ids >= 0
    full = jnp.all(real)
    kept_max = jnp.max(jnp.where(real, ids, -1))
    ok = member | (full & (tokens > kept_max)) | (tokens < 0)
    checkify.check(
        jnp.all(ok),
        f"{name}: dropped id smaller than a kept id (drops must be "
        "largest-first) or missing without the union being full")


def check_capacity(capacity: int, vocab: int, *, name: str = "capacity") -> None:
    """Static (trace-time) check: capacity is lane-aligned or the full vocab.

    The Pallas scatter paths block the slot axis in multiples of 8; an
    unaligned capacity silently pads inside the kernel and skews the comm
    accounting. Raises ``ValueError`` immediately — no checkify needed,
    capacity is static.
    """
    capacity = int(capacity)
    if capacity != int(vocab) and capacity % 8 != 0:
        raise ValueError(
            f"{name}={capacity} is neither a multiple of 8 nor the full "
            f"vocab ({vocab}): the kernel slot axis requires lane-aligned "
            "capacity buckets")
