"""Flat-key npz checkpointing for boxed parameter pytrees.

Leaves are stored under their tree path; Param logical axes go to a JSON
sidecar so a restored checkpoint can be re-sharded under any mesh.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.logical import Param, is_param


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_param)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(path: str, params, step: int = 0, extra: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, _ = _flatten_with_paths(params)
    def to_np(x):
        x = jnp.asarray(x)
        if x.dtype == jnp.bfloat16:
            # numpy can't serialise bf16; store losslessly as f32 and cast
            # back to the template dtype on restore
            x = x.astype(jnp.float32)
        # repro-lint: ok host-numpy -- checkpoint serialisation runs on
        # concrete host arrays, never under jit
        return np.asarray(x)

    arrays, axes = {}, {}
    for key, leaf in flat:
        if is_param(leaf):
            arrays[key] = to_np(leaf.value)
            axes[key] = list(leaf.axes)
        else:
            arrays[key] = to_np(leaf)
            axes[key] = None
    np.savez(path + ".npz", **{k: v for k, v in arrays.items()})
    with open(path + ".meta.json", "w") as f:
        json.dump({"step": step, "axes": axes, "extra": extra or {}}, f)


def load_checkpoint(path: str, template) -> Any:
    data = np.load(path + ".npz")
    with open(path + ".meta.json") as f:
        meta = json.load(f)
    flat, treedef = _flatten_with_paths(template)
    leaves = []
    for key, leaf in flat:
        arr = jnp.asarray(data[key])
        if is_param(leaf):
            leaves.append(Param(arr.astype(leaf.value.dtype), leaf.axes))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
