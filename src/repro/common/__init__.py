from repro.common.pytree import (  # noqa: F401
    tree_add,
    tree_sub,
    tree_scale,
    tree_zeros_like,
    tree_dot,
    tree_norm,
    tree_size,
    tree_bytes,
    cast_tree,
)
