"""Single source of the target-hardware constants (TPU v5e per chip/core).

Every analytic performance model in the repo reads THIS dict — the LLM
roofline (``benchmarks/roofline.py``), the mesh/dry-run plane
(``repro.launch.mesh`` re-exports it unchanged), and the kernel cost model
(``repro.analysis.kernel_audit``). Two models quoting different peak
numbers would make their "fraction of roofline" columns incomparable, so
the constants live in exactly one place and a test pins every consumer to
the same object.
"""
from __future__ import annotations

HW = {
    # TPU v5e per-chip constants used by the roofline analyses
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bandwidth": 819e9,        # B/s
    "ici_bandwidth": 50e9,         # B/s per link
    "hbm_bytes": 16 * 2**30,
    # per-core VMEM capacity; kernels budget against a fraction of this
    # (pipeline buffers + compiler scratch need headroom)
    "vmem_bytes": 16 * 1024 * 1024,
}
