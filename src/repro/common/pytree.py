"""Small pytree arithmetic helpers used across the framework.

The framework is deliberately dependency-light (no optax/flax in the container),
so the handful of tree ops the optimizers and aggregators need live here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_size(a) -> int:
    """Total number of scalar parameters in the tree."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def cast_tree(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a)


def tree_path_keys(path) -> tuple:
    """``tree_flatten_with_path`` key path -> plain (key | index | name) tuple."""
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(p.key)
        elif hasattr(p, "idx"):
            out.append(p.idx)
        else:
            out.append(p.name)
    return tuple(out)
