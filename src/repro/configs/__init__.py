from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    FedConfig,
    ModelConfig,
    ShapeConfig,
    all_arch_ids,
    get_config,
    get_smoke_config,
)
