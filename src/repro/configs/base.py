"""Configuration system.

``ModelConfig`` describes one architecture; every assigned architecture gets a
module ``repro/configs/<id>.py`` exporting ``CONFIG`` (the full published
configuration, exercised only abstractly via the dry-run) and ``smoke_config()``
(a reduced variant of the same family for CPU tests).

``ShapeConfig`` describes the four assigned input shapes; ``FedConfig`` the
federated-optimization hyperparameters (Algorithm 1 of the paper).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    source: str = ""                 # citation for the configuration

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_token_chunk: int = 0         # >0: scan dispatch in token chunks (§Perf)

    # --- attention details ---
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 -> full causal attention

    # --- SSM / recurrent ---
    ssm_state: int = 0               # Mamba2 state dim per head
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    block_pattern: Tuple[str, ...] = ()   # xLSTM: e.g. ('m','m','s',...)

    # --- hybrid (zamba2-style) ---
    attn_every: int = 0              # shared attention block every k SSM layers

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # fixed encoder length (1500 for whisper)
    cross_attention: bool = False

    # --- modality frontend carve-out ---
    frontend: str = "none"           # none | audio_frames | vision_patches
    num_patches: int = 0             # VLM: patch embeddings provided per example
    mrope: bool = False              # qwen2-vl multi-dimensional RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # eligibility for the long_500k decode shape (sub-quadratic path exists)
    sub_quadratic: bool = False

    # attention implementation: "mea" (chunked memory-efficient jnp, the Pallas
    # oracle) or "naive"; the Pallas kernel is selected on TPU at runtime.
    attn_impl: str = "mea"
    query_chunk: int = 1024
    kv_chunk: int = 1024
    # two-level remat: scan G groups of L/G layers, checkpointing both levels.
    # Residual memory ~ (G + L/G) * activation instead of L * activation.
    remat_groups: int = 0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.family not in ("ssm",) or any(b == "a" for b in self.block_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter counting (used for MODEL_FLOPS = 6*N*D roofline term)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts by group: total and active-per-token."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d            # wq, wk, wv, wo
        if self.family == "ssm":
            attn = 0
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        if self.is_moe:
            ffn_one = 3 * d * ff                     # gated mlp
            ffn_total = self.num_experts * ffn_one + d * self.num_experts  # + router
            ffn_active = self.experts_per_token * ffn_one + d * self.num_experts
        else:
            ffn_total = ffn_active = 3 * d * ff if ff > 0 else 0
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            e = self.ssm_expand
            n = max(self.ssm_state, 1)
            h = self.ssm_heads or max(1, (e * d) // 64)
            # in_proj (z,x,B,C,dt) + conv + out_proj, mamba2-style
            ssm = d * (2 * e * d + 2 * n * h + h) + e * d * self.ssm_conv_width + e * d * d
        per_layer_total = attn + ffn_total + ssm + 2 * d
        per_layer_active = attn + ffn_active + ssm + 2 * d
        enc = 0
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * d * d + 3 * d * ff + 2 * d)
        total = emb + head + L * per_layer_total + enc
        active = emb + head + L * per_layer_active + enc
        return {
            "embedding": emb,
            "lm_head": head,
            "per_layer_total": per_layer_total,
            "per_layer_active": per_layer_active,
            "encoder": enc,
            "total": total,
            "active": active,
        }


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Federated configuration (paper Algorithm 1)
# ---------------------------------------------------------------------------

#: server-side algorithms (repro.core.algorithms re-exports this tuple)
SERVER_ALGORITHMS = ("fedavg", "fedprox", "scaffold", "fedadam", "fedsubavg",
                     "central")
#: heat estimators (paper App. F)
HEAT_ESTIMATORS = ("exact", "secure_agg", "randomized_response")
#: sparse local-training replica layouts (see ``FedConfig.sparse_local``)
SPARSE_LOCAL_MODES = ("auto", "replicated", "sparse_replicated")


@dataclass(frozen=True)
class FedConfig:
    num_clients: int = 100           # N
    clients_per_round: int = 10      # K
    local_iters: int = 1             # I
    local_batch: int = 8
    microbatches: int = 1            # grad-accumulation steps per round (fedsgd)
    lr: float = 0.1                  # gamma
    server_lr: float = 1.0
    algorithm: str = "fedsubavg"     # fedavg|fedprox|scaffold|fedadam|fedsubavg|central
    prox_mu: float = 0.01            # FedProx proximal coefficient
    server_beta1: float = 0.9        # FedAdam
    server_beta2: float = 0.99
    server_eps: float = 1e-3
    weighted: bool = False           # App. D.4 weighted generalisation
    heat_estimator: str = "exact"    # exact | secure_agg | randomized_response
    rr_flip_prob: float = 0.1        # randomized-response flip probability
    seed: int = 0

    # --- sparse submodel update plane (repro.sparse) ---
    sparse: bool = False             # row-sparse client deltas + sparse server agg
    sparse_topk: int = 0             # >0: per-client top-k row sparsification
    sparse_int8: bool = False        # int8 row payloads (unbiased stochastic round)
    # how sparse local training replicates the model across the cohort:
    #   "sparse_replicated"  each client's replica is its gathered submodel
    #                        (K * capacity * D feature-table HBM; the paper's
    #                        download-a-submodel protocol)
    #   "replicated"         K full dense replicas + post-hoc row-sparse encode
    #   "auto"               sparse_replicated whenever the model has axis-0
    #                        feature tables spanning the dataset's id space,
    #                        dense replicas otherwise
    sparse_local: str = "auto"

    def __post_init__(self):
        """Reject invalid configurations at construction time.

        Every check here used to fail deep inside tracing (or silently do the
        wrong thing); failing in ``FedConfig(...)`` with an actionable message
        is the only place the user still has the call site in hand.
        """
        if self.algorithm not in SERVER_ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {self.algorithm!r}: expected one of "
                f"{SERVER_ALGORITHMS}")
        if self.heat_estimator not in HEAT_ESTIMATORS:
            raise ValueError(
                f"unknown heat_estimator {self.heat_estimator!r}: expected "
                f"one of {HEAT_ESTIMATORS}")
        if self.sparse_local not in SPARSE_LOCAL_MODES:
            raise ValueError(
                f"unknown sparse_local mode {self.sparse_local!r}: expected "
                f"one of {SPARSE_LOCAL_MODES}")
        if self.sparse_topk < 0:
            raise ValueError(
                f"sparse_topk must be >= 0 (0 disables top-k), got "
                f"{self.sparse_topk}")
        if self.microbatches > 1 and self.sparse:
            raise ValueError(
                "microbatches > 1 does not compose with sparse=True: the "
                "sparse plane computes one fused cohort gradient per round; "
                "set microbatches=1 or sparse=False")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "mixtral_8x22b",
    "whisper_large_v3",
    "llama4_maverick_400b_a17b",
    "mistral_large_123b",
    "qwen3_32b",
    "qwen2_5_14b",
    "zamba2_1_2b",
    "qwen2_vl_7b",
    "deepseek_67b",
    "xlstm_350m",
)

# ids also accepted with dashes/dots, e.g. "mixtral-8x22b"
def _canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(name)}")
    return mod.smoke_config()


def all_arch_ids():
    return ARCH_IDS
