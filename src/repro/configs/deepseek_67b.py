"""DeepSeek 67B — dense llama-architecture decoder. [arXiv:2401.02954]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10_000.0,
    sub_quadratic=False,
    source="arXiv:2401.02954",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        query_chunk=32,
        kv_chunk=32,
    )
