"""Llama-4 Maverick 400B-A17B — MoE 128 experts top-1, early-fusion multimodal.

[hf:meta-llama/Llama-4-Scout-17B-16E]. Early fusion: image patch embeddings are
interleaved into the token stream (vision encoder stubbed per the carve-out).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    frontend="vision_patches",     # early fusion: patch embeds join the stream
    num_patches=256,
    sub_quadratic=False,           # full-attention config here
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="llama4-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=4,
        experts_per_token=1,
        num_patches=8,
        query_chunk=32,
        kv_chunk=32,
    )
