"""Mistral Large 123B — dense decoder. [hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    sub_quadratic=False,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="mistral-large-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        query_chunk=32,
        kv_chunk=32,
    )
