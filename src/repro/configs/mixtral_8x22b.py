"""Mixtral 8x22B — MoE, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088] (Mixtral of Experts; SWA per the assignment spec, window 4096
as in Mistral-7B from which the architecture descends).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    sub_quadratic=True,            # SWA -> eligible for long_500k
    source="arXiv:2401.04088",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="mixtral-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        sliding_window=32,
        query_chunk=32,
        kv_chunk=32,
    )
