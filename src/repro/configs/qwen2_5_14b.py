"""Qwen2.5-14B — dense decoder with GQA and QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    sub_quadratic=False,
    source="hf:Qwen/Qwen2.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2.5-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        query_chunk=32,
        kv_chunk=32,
    )
