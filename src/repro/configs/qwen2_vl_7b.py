"""Qwen2-VL-7B — VLM decoder backbone with M-RoPE. [arXiv:2409.12191]

The ViT vision encoder + projector is a STUB per the carve-out: ``input_specs``
provides precomputed patch embeddings (dynamic resolution -> num_patches per
example) plus 3D M-RoPE position ids (temporal, height, width).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),   # sums to head_dim//2
    frontend="vision_patches",
    num_patches=1024,
    sub_quadratic=False,
    source="arXiv:2409.12191",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-vl-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        mrope_sections=(4, 6, 6),
        d_ff=256,
        vocab_size=512,
        num_patches=8,
        query_chunk=32,
        kv_chunk=32,
    )
