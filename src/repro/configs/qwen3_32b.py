"""Qwen3-32B — dense decoder with QK-norm and GQA. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,                  # explicit head_dim (qwen3 style, != d_model/heads)
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    sub_quadratic=False,
    source="hf:Qwen/Qwen3-8B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        query_chunk=32,
        kv_chunk=32,
    )
