"""Whisper large-v3 — encoder-decoder audio model (transformer backbone only).

[arXiv:2212.04356]. The mel-spectrogram + conv feature extractor is a STUB per
the assignment carve-out: ``input_specs`` provides precomputed frame embeddings
of shape (batch, encoder_seq, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,                 # decoder layers
    encoder_layers=32,
    encoder_seq=1500,              # 30 s of audio at 50 Hz after conv stride
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,               # MHA (GQA kv=20 == heads)
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    cross_attention=True,
    frontend="audio_frames",
    rope_theta=10_000.0,           # whisper uses learned/sinusoidal; rope stands in
    sub_quadratic=False,
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-smoke",
        num_layers=2,
        encoder_layers=2,
        encoder_seq=64,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        query_chunk=32,
        kv_chunk=32,
    )
