"""xLSTM-350M — sLSTM + mLSTM recurrent blocks. [arXiv:2405.04517]

xLSTM[7:1] block ratio: one sLSTM block per 8 layers, the rest mLSTM. d_ff=0:
the up/down projections live inside the xLSTM blocks (expand factor 2), no
separate FFN, matching the paper's block design.
"""
from repro.configs.base import ModelConfig

_PATTERN = tuple("s" if i % 8 == 4 else "m" for i in range(24))

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    ssm_heads=4,
    ssm_expand=2,
    block_pattern=_PATTERN,
    sub_quadratic=True,
    source="arXiv:2405.04517",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-smoke",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        ssm_heads=4,
        vocab_size=512,
        block_pattern=("m", "s", "m", "m"),
        query_chunk=32,
        kv_chunk=32,
    )
