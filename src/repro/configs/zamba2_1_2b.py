"""Zamba2-1.2B — hybrid: Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

38 Mamba2 layers with a single *shared* attention+MLP block applied every 6
layers (zamba2's shared-transformer design: one set of attention weights reused
at each insertion point).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=64,                  # expand*d_model / head_dim(64) = 4096/64
    ssm_expand=2,
    attn_every=6,
    sub_quadratic=True,
    source="arXiv:2411.15242",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-smoke",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        ssm_state=16,
        ssm_heads=8,
        attn_every=2,
        query_chunk=32,
        kv_chunk=32,
    )
