"""The paper's primary contribution: heat-corrected federated submodel averaging."""
from repro.core.heat import (  # noqa: F401
    HeatStats,
    client_indicator,
    compute_heat_exact,
    estimate_heat_randomized_response,
    estimate_heat_secure_agg,
    heat_correction_factors,
)
from repro.core.aggregate import (  # noqa: F401
    HeatSpec,
    correct_update_tree,
    cohort_mean,
    cohort_sum,
)
from repro.core.algorithms import (  # noqa: F401
    ServerState,
    make_server_algorithm,
    SERVER_ALGORITHMS,
)
from repro.core.preconditioner import (  # noqa: F401
    condition_number,
    preconditioned_hessian,
)
