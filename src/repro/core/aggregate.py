"""Pytree-aware aggregation with per-leaf heat semantics.

A model's parameter tree mixes *feature-keyed* leaves (embedding tables, LM
heads, per-expert FFN stacks) whose rows have individual heat counts, and
*dense* leaves touched by every participating client. ``HeatSpec`` tags each
leaf with the name of its feature space (or None for dense); the FedSubAvg
correction is then a per-leaf broadcasted multiply — zero extra collectives
when the leaf and its heat vector are co-sharded.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.heat import heat_correction_factors
from repro.sharding.logical import boxed_like, is_param, unbox

Array = jax.Array


@dataclass(frozen=True)
class HeatSpec:
    """Maps parameter-tree leaves to feature spaces.

    ``leaf_spaces`` is a pytree with the same structure as the parameter tree;
    each leaf is either ``None`` (dense parameter) or a tuple
    ``(space_name, row_axis)`` saying: axis ``row_axis`` of this leaf is keyed
    by feature space ``space_name`` (e.g. ("vocab", 0) for an embedding table
    of shape (V, d), or ("expert", 0) for stacked expert weights (E, ...)).
    """

    leaf_spaces: Any

    @staticmethod
    def dense_like(params) -> "HeatSpec":
        return HeatSpec(jax.tree.map(lambda _: None, params))


def _broadcast_factor(factors: Array, leaf: Array, row_axis: int) -> Array:
    shape = [1] * leaf.ndim
    shape[row_axis] = leaf.shape[row_axis]
    return factors.reshape(shape)


def correct_dense_leaf(leaf: Array, space, heat_counts: Dict[str, Array],
                       total: float) -> Array:
    """Broadcast ``N / n_m`` onto one dense leaf tagged ``(space, axis)``.

    Identity for untagged leaves or spaces without stats. The single source
    of the dense-broadcast correction — shared by ``correct_update_tree``
    and the sparse plane's dense-leaf branches, so the two planes cannot
    drift apart.
    """
    if space is None or space[0] not in heat_counts:
        return leaf
    name, axis = space
    factors = heat_correction_factors(heat_counts[name], total).astype(leaf.dtype)
    return leaf * _broadcast_factor(factors, leaf, axis)


def correct_update_tree(
    update,
    heat_spec: HeatSpec,
    heat_counts: Dict[str, Array],
    total: float,
) -> Any:
    """Apply the FedSubAvg correction ``N / n_m`` leaf-wise.

    ``heat_counts[space]`` is the per-row count vector for that feature space.
    Dense leaves pass through unchanged (their count is N by definition —
    factor 1). This is Algorithm 1 line 9's scaling, vectorised over the tree.

    Accepts boxed (Param) or plain trees; boxing is preserved.
    """
    boxed = any(is_param(l) for l in jax.tree.leaves(update, is_leaf=is_param))
    plain = unbox(update) if boxed else update

    def fix(leaf, space):
        return correct_dense_leaf(leaf, space, heat_counts, total)

    out = jax.tree.map(fix, plain, heat_spec.leaf_spaces, is_leaf=lambda x: x is None)
    return boxed_like(out, update) if boxed else out


def cohort_sum(deltas):
    """Sum of per-client update trees stacked on axis 0."""
    return jax.tree.map(lambda d: d.sum(axis=0), deltas)


def cohort_mean(deltas):
    return jax.tree.map(lambda d: d.mean(axis=0), deltas)


def masked_cohort_mean(deltas, involvement):
    """Mean over only the clients that involve each row (submodel semantics).

    ``involvement``: (K, rows) 0/1 — client k touched row r. Used by the exact
    (non-expectation) form of submodel averaging in tests: the average of the
    local updates of the clients who involve the parameter.
    """

    def f(d):
        # d: (K, rows, ...) ; involvement broadcast over trailing dims
        inv = involvement.reshape(involvement.shape + (1,) * (d.ndim - 2))
        num = (d * inv).sum(axis=0)
        den = jnp.maximum(inv.sum(axis=0), 1.0)
        return num / den

    return jax.tree.map(f, deltas)
