"""Server-side federated optimization algorithms.

The client side (local SGD, prox terms) lives in ``repro.federated.client``;
this module owns what the cloud server does with the aggregated cohort update:

    FedAvg     X <- X + eta * mean_i(Delta_i)
    FedSubAvg  X_m <- X_m + eta * (N / n_m) * mean_i(Delta_i,m)        (Alg. 1 l.9)
    FedProx    server-side identical to FedAvg (prox term is local)
    Scaffold   the paper's server approximation (App. D.2, eq. 47):
               Delta_glob <- (1 - K/N) Delta_glob + (K/N) mean_i(Delta_i)
    FedAdam    server Adam over the pseudo-gradient -mean_i(Delta_i) (Reddi et al.)

All are expressed as (init, apply) pairs over parameter pytrees so they jit and
shard identically; FedSubAvg's correction is the only one that consults heat.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_add, tree_scale, tree_zeros_like
from repro.configs.base import SERVER_ALGORITHMS, FedConfig  # noqa: F401 (re-export)
from repro.core.aggregate import HeatSpec, correct_update_tree


class ServerState(NamedTuple):
    params: Any
    opt: Any                 # algorithm-specific slots (momenta, control delta)
    rounds: jax.Array        # scalar int32


@dataclass(frozen=True)
class ServerAlgorithm:
    name: str
    init: Callable[[Any], ServerState]
    apply: Callable[[ServerState, Any], ServerState]   # (state, cohort_mean_delta)


def _base_init(params) -> ServerState:
    return ServerState(params=params, opt=(), rounds=jnp.zeros((), jnp.int32))


def make_server_algorithm(
    cfg: FedConfig,
    heat_spec: Optional[HeatSpec] = None,
    heat_counts: Optional[Dict[str, jax.Array]] = None,
    total: Optional[float] = None,
) -> ServerAlgorithm:
    name = cfg.algorithm
    eta = cfg.server_lr

    if name in ("fedavg", "fedprox", "central"):

        def apply(state: ServerState, delta) -> ServerState:
            new = tree_add(state.params, tree_scale(delta, eta))
            return ServerState(new, state.opt, state.rounds + 1)

        return ServerAlgorithm(name, _base_init, apply)

    if name == "fedsubavg":
        if heat_spec is None or heat_counts is None or total is None:
            raise ValueError("fedsubavg requires heat_spec, heat_counts and total N")

        def apply(state: ServerState, delta) -> ServerState:
            corrected = correct_update_tree(delta, heat_spec, heat_counts, total)
            new = tree_add(state.params, tree_scale(corrected, eta))
            return ServerState(new, state.opt, state.rounds + 1)

        return ServerAlgorithm(name, _base_init, apply)

    if name == "scaffold":
        frac = cfg.clients_per_round / cfg.num_clients

        def init(params) -> ServerState:
            return ServerState(params, tree_zeros_like(params), jnp.zeros((), jnp.int32))

        def apply(state: ServerState, delta) -> ServerState:
            momentum = jax.tree.map(
                lambda g, d: (1.0 - frac) * g + frac * d, state.opt, delta
            )
            new = tree_add(state.params, tree_scale(momentum, eta))
            return ServerState(new, momentum, state.rounds + 1)

        return ServerAlgorithm(name, init, apply)

    if name == "fedadam":
        b1, b2, eps = cfg.server_beta1, cfg.server_beta2, cfg.server_eps

        def init(params) -> ServerState:
            opt = (tree_zeros_like(params), tree_zeros_like(params))
            return ServerState(params, opt, jnp.zeros((), jnp.int32))

        def apply(state: ServerState, delta) -> ServerState:
            m0, v0 = state.opt
            t = state.rounds + 1
            m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d, m0, delta)
            v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) * d * d, v0, delta)
            tf = t.astype(jnp.float32)
            mh = tree_scale(m, 1.0 / (1 - b1**tf))
            vh = tree_scale(v, 1.0 / (1 - b2**tf))
            step = jax.tree.map(lambda m_, v_: eta * m_ / (jnp.sqrt(v_) + eps), mh, vh)
            return ServerState(tree_add(state.params, step), (m, v), t)

        return ServerAlgorithm(name, init, apply)

    raise ValueError(f"unknown server algorithm: {name!r}")
