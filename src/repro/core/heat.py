"""Feature/parameter heat statistics (paper §2) and private estimation (App. F).

"Heat" of a feature m is ``n_m``: the number of clients whose local data involve
m. The paper's correction multiplies parameter m's aggregated update by
``N / n_m`` (weighted generalisation: ``sum_i w_i / sum_{j: m in S(j)} w_j``,
App. D.4). Heat is *static* over training — computed once from dataset
statistics, optionally under local differential privacy via randomized response
or exactly via secure aggregation (App. F).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Exact heat
# ---------------------------------------------------------------------------


def client_indicator(feature_ids, num_features: int) -> np.ndarray:
    """0/1 vector: does this client involve feature m? (the App. F vector)."""
    v = np.zeros((num_features,), dtype=np.int64)
    ids = np.asarray(feature_ids).reshape(-1)
    ids = ids[(ids >= 0) & (ids < num_features)]
    v[np.unique(ids)] = 1
    return v


def compute_heat_exact(
    client_feature_ids: Sequence, num_features: int, weights: Optional[Sequence[float]] = None
) -> np.ndarray:
    """n_m for every feature; weighted variant returns sum of involving weights."""
    out = np.zeros((num_features,), dtype=np.float64)
    for i, ids in enumerate(client_feature_ids):
        ind = client_indicator(ids, num_features)
        w = 1.0 if weights is None else float(weights[i])
        out += w * ind
    return out


# ---------------------------------------------------------------------------
# Private estimation (Appendix F)
# ---------------------------------------------------------------------------


def estimate_heat_secure_agg(indicators: np.ndarray, rng: Optional[np.random.Generator] = None,
                             modulus: int = 1 << 32,
                             return_masked: bool = False):
    """Secure-aggregation simulation: pairwise additive masks that cancel.

    Each client i adds masks ``m_{ij}`` for j>i and subtracts ``m_{ji}`` for
    j<i (mod ``modulus``); the server sums the masked vectors and the masks
    cancel,
    recovering the exact heat without seeing any individual indicator. This
    simulates the Bonawitz et al. protocol's arithmetic; the crypto key
    agreement is out of scope (there is no adversary inside a simulation).

    ``rng`` selects the mask stream: its entropy is folded into every pair's
    seed, so different generators mask the per-client vectors differently
    (what the simulated server sees changes) while the unmasked sum — the
    return value — is exact either way. ``rng=None`` keeps the documented
    legacy stream, pair seeds ``SeedSequence((i, j))`` — bit-identical across
    processes and pinned by test. ``return_masked=True`` additionally returns
    the per-client masked vectors (the server's actual inputs).

    ``modulus`` must be a power of two (at most 2**63): the per-client
    vectors are reduced mod ``modulus`` as each mask is applied, but the
    final server sum across clients accumulates unreduced in uint64 and is
    reduced once — congruent mod ``modulus`` iff ``modulus`` divides 2**64
    — and the modulus itself must stay uint64-representable. It
    must also exceed the client count, or the true heat of a hot feature
    (up to N for 0/1 indicators) would itself wrap mod the ring size.
    """
    if modulus <= 0 or modulus & (modulus - 1) or modulus > (1 << 63):
        raise ValueError(
            f"modulus must be a power of two <= 2**63, got {modulus}: the "
            "uint64 wraparound arithmetic is only congruent mod a divisor "
            "of 2**64")
    n, m = indicators.shape
    if modulus <= n:
        raise ValueError(
            f"modulus {modulus} must exceed the client count {n}: the true "
            "heat reaches n for a feature every client holds and would wrap")
    # one entropy draw folds the caller's generator into every pair seed;
    # both endpoints of a pair still derive the SAME mask, so cancellation
    # (and hence exactness) is unaffected
    salt = (None if rng is None
            else (int(rng.integers(0, 1 << 63, dtype=np.uint64)),))
    # per-client masked vectors; both endpoints of a pair share the mask
    # derived from SeedSequence((*salt, min(i,j), max(i,j))) — a stable
    # function of the pair (unlike Python's per-process-salted hash()), so
    # runs reproduce bit-identically across processes. Each pair mask is
    # generated exactly once and applied with opposite signs to its two
    # endpoints (the old O(N^2) loop re-derived every mask from both sides);
    # the final server sum is one vectorised reduction. All arithmetic is mod
    # `modulus` carried in uint64 (modulus divides 2^64 — validated above —
    # so wraparound preserves the residue), hence this is bit-identical to
    # the per-client accumulation it replaces.
    vecs = indicators.astype(np.uint64) % modulus
    for i in range(n):
        for j in range(i + 1, n):
            seed = (i, j) if salt is None else salt + (i, j)
            pair_rng = np.random.default_rng(np.random.SeedSequence(seed))
            mask = pair_rng.integers(0, modulus, size=m, dtype=np.uint64)
            vecs[i] = (vecs[i] + mask) % modulus
            vecs[j] = (vecs[j] - mask) % modulus
    acc = vecs.sum(axis=0, dtype=np.uint64)
    est = (acc % modulus).astype(np.float64)
    return (est, vecs) if return_masked else est


def estimate_heat_randomized_response(
    indicators: np.ndarray, flip_prob: float,
    rng: Optional[np.random.Generator] = None,
    weights: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Unbiased heat estimate under randomized response (Warner 1965).

    Each client reports its true bit with prob ``1 - p`` and the flipped bit
    with prob ``p``. If ``c`` is the count of reported ones over N clients,
    ``(c - p*N) / (1 - 2p)`` is unbiased for the true count.

    With ``weights`` (App. D.4 composed with App. F): the server sums
    ``w_i * reported_i`` — the weighting never touches raw client bits, so
    the local privacy guarantee is unchanged. ``E[sum w_i r_i] =
    (1-2p) * sum w_i ind_i + p * W`` with ``W = sum w_i``, hence
    ``(c_w - p*W) / (1 - 2p)`` is unbiased for the weighted heat.
    """
    assert 0.0 <= flip_prob < 0.5
    rng = rng or np.random.default_rng(0)
    n, m = indicators.shape
    flips = rng.random((n, m)) < flip_prob
    reported = np.where(flips, 1 - indicators, indicators)
    if weights is None:
        c = reported.sum(axis=0).astype(np.float64)
        return (c - flip_prob * n) / (1.0 - 2.0 * flip_prob)
    w = np.asarray(weights, np.float64)
    c_w = (w[:, None] * reported).sum(axis=0)
    return (c_w - flip_prob * w.sum()) / (1.0 - 2.0 * flip_prob)


# ---------------------------------------------------------------------------
# Correction factors
# ---------------------------------------------------------------------------


def clamp_heat_estimate(est, total: float, min_count: float = 1.0) -> np.ndarray:
    """Clamp a PRIVATE heat estimate into ``[min_count, total]``.

    Randomized response is unbiased but noisy: a genuinely hot feature can
    draw an estimate <= 0, and the correction gates (``counts > 0`` in
    :func:`heat_correction_factors`, ``h > 0`` in its gathered twin
    ``repro.sparse.aggregate.heat_factor_at``) would then zero that row's
    aggregated update entirely — a silently dropped hot row. Any feature a
    client involves has true heat in ``[1, N]``, so the estimate is clamped
    there before it reaches either gate. Exact estimators must NOT be
    clamped: their zero genuinely means cold, and factor 0 is the documented
    inf-avoiding behavior.
    """
    return np.clip(np.asarray(est, np.float64), min_count, total)


def heat_correction_factors(counts, total, min_count: float = 1.0) -> Array:
    """FedSubAvg per-row correction ``N / n_m``.

    Rows no client involves (n_m = 0) receive factor 0 — they never get a
    non-zero update anyway, and 0 avoids inf propagation. Estimated heat
    (randomized response) can dip below 1; it is clamped to ``min_count``.
    """
    counts = jnp.asarray(counts, dtype=jnp.float32)
    safe = jnp.maximum(counts, min_count)
    factors = jnp.asarray(total, jnp.float32) / safe
    return jnp.where(counts > 0, factors, 0.0)


@dataclass(frozen=True)
class HeatStats:
    """Container binding a feature space to its heat counts."""

    counts: np.ndarray       # (num_features,) float
    total: float             # N (or sum of weights in the weighted case)
    name: str = "vocab"

    @property
    def n_min(self) -> float:
        nz = self.counts[self.counts > 0]
        return float(nz.min()) if nz.size else 0.0

    @property
    def n_max(self) -> float:
        return float(self.counts.max()) if self.counts.size else 0.0

    def dispersion(self) -> float:
        """Parameter heat dispersion n_max / n_min (paper §2)."""
        nmin = self.n_min
        return float("inf") if nmin == 0 else self.n_max / nmin

    def correction(self) -> Array:
        return heat_correction_factors(self.counts, self.total)

    def coverage(self) -> float:
        """Fraction of features involved by at least one client."""
        return float((self.counts > 0).mean())
