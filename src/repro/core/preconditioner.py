"""Conditioning analysis utilities (paper §4, Theorems 1-2).

FedSubAvg is a static diagonal preconditioner ``D = diag(N/n_m)``; optimizing
``f`` with FedSubAvg approximates GD on ``f_hat(Xh) = f(D^{1/2} Xh)``, whose
Hessian is ``D^{1/2} H D^{1/2}``. These helpers measure both condition numbers
on small problems so the theorems can be verified empirically (tests +
``benchmarks/bench_conditioning.py``).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def condition_number(h: jax.Array, eps: float = 0.0) -> float:
    """kappa(H) = sigma_max / sigma_min via SVD (H need not be PSD)."""
    s = jnp.linalg.svd(h, compute_uv=False)
    smin = jnp.maximum(s[-1], eps)
    # repro-lint: ok traced-float -- host analysis helper (tests/benches);
    # the device sync is the point of returning a Python float
    return float(s[0] / smin)


def preconditioned_hessian(h: jax.Array, counts, total: float) -> jax.Array:
    """D^{1/2} H D^{1/2} with D = diag(total / counts); zero-count rows get 0."""
    counts = jnp.asarray(counts, jnp.float32)
    d_half = jnp.where(counts > 0, jnp.sqrt(total / jnp.maximum(counts, 1.0)), 0.0)
    return h * d_half[:, None] * d_half[None, :]


def hessian_of(loss: Callable, x: jax.Array) -> jax.Array:
    return jax.hessian(loss)(x)


def measured_dispersion_bound(h: jax.Array, counts, rho2: float) -> float:
    """Theorem-1 floor: kappa(H) >= n_max (rho1 - alpha(rho1+rho2)) / (n_min rho2).

    Returns n_max/n_min, the Theta() driver of the bound, for comparison
    against the measured condition number.
    """
    c = np.asarray(counts, dtype=np.float64)
    nz = c[c > 0]
    return float(nz.max() / nz.min()) if nz.size else float("inf")
