"""Submodel extraction and alignment (paper §2, "Model Structure and Submodel").

A client's submodel is the dense layers plus the embedding rows for its local
feature ids. These helpers implement the download/upload key-value view:

    download:  rows = table[ids]                      (gather)
    upload:    table_update[ids] += row_updates       (scatter-add, aligned)

Index sets are fixed-size padded arrays (jit-friendly); padding uses id = -1
which gathers row 0 but is masked out of scatters.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class IndexSet(NamedTuple):
    ids: Array      # (max_ids,) int32, padded with -1
    mask: Array     # (max_ids,) float32 1.0 for real ids


def index_set_from_tokens(tokens: Array, max_ids: int) -> IndexSet:
    """Client-side S(i) extraction: unique feature ids in the local data.

    jnp.unique is not jittable with dynamic size, so we use the standard
    fixed-size trick: sort + compare-neighbours, then pack valid uniques
    leftwards with a scatter over their rank.
    """
    flat = jnp.sort(tokens.reshape(-1))
    first = jnp.concatenate([jnp.ones((1,), bool), flat[1:] != flat[:-1]])
    rank = jnp.cumsum(first) - 1                       # position among uniques
    ids = jnp.full((max_ids,), -1, dtype=jnp.int32)
    ok = first & (rank < max_ids)
    ids = ids.at[jnp.where(ok, rank, max_ids)].set(
        jnp.where(ok, flat.astype(jnp.int32), -1), mode="drop"
    )
    mask = (ids >= 0).astype(jnp.float32)
    return IndexSet(ids=ids, mask=mask)


def gather_rows(table: Array, index_set: IndexSet) -> Array:
    """Download step: fetch the submodel's embedding rows (padding -> zeros)."""
    rows = table[jnp.maximum(index_set.ids, 0)]
    return rows * index_set.mask[:, None].astype(rows.dtype)


def scatter_row_updates(num_rows: int, index_set: IndexSet, row_updates: Array) -> Array:
    """Upload step: align row updates back into full-table coordinates."""
    upd = row_updates * index_set.mask[:, None].astype(row_updates.dtype)
    out = jnp.zeros((num_rows, row_updates.shape[-1]), dtype=row_updates.dtype)
    return out.at[jnp.maximum(index_set.ids, 0)].add(upd, mode="drop") * 1.0


def involvement_matrix(ids_batch: Array, num_rows: int) -> Array:
    """(K, num_rows) 0/1: which cohort client involves which row."""

    def one(ids):
        v = jnp.zeros((num_rows,), jnp.float32)
        return v.at[jnp.maximum(ids, 0)].max(jnp.where(ids >= 0, 1.0, 0.0), mode="drop")

    return jax.vmap(one)(ids_batch)


def count_token_rows(tokens: Array, num_rows: int) -> Array:
    """Per-row token occurrence counts for a batch (not heat; used by kernels)."""
    flat = tokens.reshape(-1)
    out = jnp.zeros((num_rows,), jnp.float32)
    return out.at[jnp.maximum(flat, 0)].add(jnp.where(flat >= 0, 1.0, 0.0), mode="drop")
