from repro.data.synthetic import (  # noqa: F401
    FederatedDataset,
    make_movielens_like,
    make_sent140_like,
    make_amazon_like,
    make_lm_federated,
    DATASETS,
)
from repro.data.batching import sample_cohort_batch, pooled_batches  # noqa: F401
