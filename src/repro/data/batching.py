"""Cohort batching: turn a FederatedDataset + sampled client ids into the
stacked minibatch tensors the jitted round step consumes.

For a round with K clients, I local iterations and local batch B, the cohort
batch has leaves (K, I, B, ...): client k's I minibatches sampled (with
replacement, as in the paper's mini-batch SGD) from its local data.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.data.synthetic import FederatedDataset


def sample_cohort_batch(ds: FederatedDataset, client_ids: np.ndarray,
                        local_iters: int, local_batch: int,
                        rng: np.random.Generator) -> Dict[str, np.ndarray]:
    k = len(client_ids)
    out = {key: [] for key in ds.client_data}
    out["sample_mask"] = []
    for c in client_ids:
        n = int(ds.sample_counts[c])
        idx = rng.integers(0, max(n, 1), size=(local_iters, local_batch))
        for key, arr in ds.client_data.items():
            out[key].append(arr[c][idx])
        out["sample_mask"].append(np.ones((local_iters, local_batch), np.float32)
                                  * (n > 0))
    return {key: np.stack(v) for key, v in out.items()}


def pooled_batches(ds: FederatedDataset, iters: int, batch: int,
                   rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """CentralSGD batches: sample from the pooled training set (I, B, ...)."""
    # flatten valid samples
    valid = []
    for c in range(ds.num_clients):
        n = int(ds.sample_counts[c])
        valid.extend((c, j) for j in range(n))
    valid = np.array(valid)
    pick = valid[rng.integers(0, len(valid), size=iters * batch)]
    out = {}
    for key, arr in ds.client_data.items():
        out[key] = arr[pick[:, 0], pick[:, 1]].reshape(iters, batch, *arr.shape[2:])
    out["sample_mask"] = np.ones((iters, batch), np.float32)
    return out
