"""Synthetic federated datasets with controlled feature-heat dispersion.

The container has no internet access, so MovieLens / Sent140 / Amazon are
reproduced as *statistically matched* synthetics: client counts, samples per
client and — the paper's key variable — feature heat dispersion follow
Table 1's regime via Zipf-distributed feature popularity. Labels come from a
planted (learnable) latent model so optimization curves are meaningful.

Every generator returns a ``FederatedDataset`` with padded per-client arrays
(jit-friendly), the exact per-feature heat, and a pooled test split.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core.heat import HeatStats


@dataclass
class FederatedDataset:
    name: str
    task: str                       # lr | lstm | din | lm
    num_clients: int
    num_features: int
    client_data: Dict[str, np.ndarray]    # leaves (N, max_samples, ...)
    sample_counts: np.ndarray             # (N,)
    heat: HeatStats
    test_data: Dict[str, np.ndarray]
    feature_key: str = "features"         # which leaf carries feature ids

    def stats(self) -> Dict:
        return {
            "clients": self.num_clients,
            "samples": int(self.sample_counts.sum()),
            "samples_per_client": float(self.sample_counts.mean()),
            "dispersion": self.heat.dispersion(),
            "coverage": self.heat.coverage(),
        }


def _zipf_probs(n: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** a
    return p / p.sum()


def _pad_stack(rows, max_len, fill=0):
    out = np.full((len(rows), max_len) + rows[0].shape[1:], fill, dtype=rows[0].dtype)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r[:max_len]
    return out


def _heat_from_ids(per_client_ids, num_features) -> HeatStats:
    counts = np.zeros(num_features, np.float64)
    for ids in per_client_ids:
        u = np.unique(ids[ids >= 0])
        counts[u] += 1
    return HeatStats(counts=counts, total=float(len(per_client_ids)))


# ---------------------------------------------------------------------------
# MovieLens-like: LR over one-hot(gender, age, movie, gender x movie, age x movie)
# ---------------------------------------------------------------------------


def make_movielens_like(num_clients: int = 300, num_items: int = 200,
                        mean_samples: int = 40, zipf_a: float = 1.2,
                        seed: int = 0, test_frac: float = 0.2) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    m = num_items
    num_features = 9 + 10 * m       # 2 gender + 7 age + M + 2M + 7M
    pop = _zipf_probs(m, zipf_a)

    q = rng.normal(0, 1.2, m)                       # movie quality
    g_aff = rng.normal(0, 0.5, (2, m))              # gender x movie affinity
    a_aff = rng.normal(0, 0.5, (7, m))              # age x movie affinity

    feats, labels, counts = [], [], []
    test_feats, test_labels = [], []
    for i in range(num_clients):
        g = rng.integers(0, 2)
        a = rng.integers(0, 7)
        n = max(5, int(rng.poisson(mean_samples)))
        movies = rng.choice(m, size=n, p=pop)
        logit = q[movies] + g_aff[g, movies] + a_aff[a, movies] + rng.normal(0, 0.5, n)
        lab = (logit > 0).astype(np.int32)
        f = np.stack([
            np.full(n, g),
            np.full(n, 2 + a),
            9 + movies,
            9 + m + g * m + movies,
            9 + 3 * m + a * m + movies,
        ], axis=1).astype(np.int32)
        n_test = max(1, int(n * test_frac))
        test_feats.append(f[:n_test])
        test_labels.append(lab[:n_test])
        feats.append(f[n_test:])
        labels.append(lab[n_test:])
        counts.append(n - n_test)

    max_len = max(counts)
    data = {
        "features": _pad_stack(feats, max_len, fill=-1),
        "label": _pad_stack(labels, max_len, fill=0),
    }
    heat = _heat_from_ids([f.reshape(-1) for f in feats], num_features)
    return FederatedDataset(
        name="movielens_like", task="lr", num_clients=num_clients,
        num_features=num_features, client_data=data,
        sample_counts=np.array(counts), heat=heat,
        test_data={"features": np.concatenate(test_feats),
                   "label": np.concatenate(test_labels)},
    )


# ---------------------------------------------------------------------------
# Sent140-like: LSTM over Zipf token streams
# ---------------------------------------------------------------------------


def make_sent140_like(num_clients: int = 200, vocab: int = 2000, seq_len: int = 24,
                      mean_samples: int = 30, zipf_a: float = 1.1,
                      seed: int = 0, test_frac: float = 0.2) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    pop = _zipf_probs(vocab, zipf_a)
    sentiment = rng.normal(0, 1.0, vocab)           # planted word polarity

    toks, labels, counts, t_toks, t_labels = [], [], [], [], []
    for i in range(num_clients):
        n = max(5, int(rng.poisson(mean_samples)))
        # each client skews towards a personal topic slice of the vocab
        boost = np.zeros(vocab)
        topic = rng.choice(vocab, size=20, p=pop)
        boost[topic] += 3.0
        p = pop * np.exp(boost * 0.2)
        p /= p.sum()
        lens = rng.integers(6, seq_len + 1, n)
        seqs = np.full((n, seq_len), -1, np.int32)
        lab = np.zeros(n, np.int32)
        for j in range(n):
            s = rng.choice(vocab, size=lens[j], p=p)
            seqs[j, : lens[j]] = s
            score = sentiment[s].mean() + rng.normal(0, 0.3)
            lab[j] = int(score > 0)
        n_test = max(1, int(n * test_frac))
        t_toks.append(seqs[:n_test]); t_labels.append(lab[:n_test])
        toks.append(seqs[n_test:]); labels.append(lab[n_test:])
        counts.append(n - n_test)

    max_len = max(counts)
    data = {
        "tokens": _pad_stack(toks, max_len, fill=-1),
        "label": _pad_stack(labels, max_len, fill=0),
    }
    heat = _heat_from_ids([t.reshape(-1) for t in toks], vocab)
    return FederatedDataset(
        name="sent140_like", task="lstm", num_clients=num_clients,
        num_features=vocab, client_data=data, sample_counts=np.array(counts),
        heat=heat,
        test_data={"tokens": np.concatenate(t_toks), "label": np.concatenate(t_labels)},
        feature_key="tokens",
    )


# ---------------------------------------------------------------------------
# Amazon/Alibaba-like: DIN CTR with behaviour histories
# ---------------------------------------------------------------------------


def make_amazon_like(num_clients: int = 250, num_items: int = 500, hist_len: int = 10,
                     mean_samples: int = 40, zipf_a: float = 1.05, emb_rank: int = 8,
                     seed: int = 0, test_frac: float = 0.2) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    pop = _zipf_probs(num_items, zipf_a)
    item_vec = rng.normal(0, 1.0 / np.sqrt(emb_rank), (num_items, emb_rank))

    hists, targets, labels, counts = [], [], [], []
    t_h, t_t, t_l = [], [], []
    for i in range(num_clients):
        u = rng.normal(0, 1.0, emb_rank)
        n = max(5, int(rng.poisson(mean_samples)))
        # user's interest pool
        aff = item_vec @ u
        p = pop * np.exp(aff - aff.max())
        p = p / p.sum()
        hist = np.full((n, hist_len), -1, np.int32)
        tgt = rng.choice(num_items, size=n, p=0.5 * pop + 0.5 * p)
        lab = np.zeros(n, np.int32)
        for j in range(n):
            hl = rng.integers(3, hist_len + 1)
            h = rng.choice(num_items, size=hl, p=p)
            hist[j, :hl] = h
            match = item_vec[h] @ item_vec[tgt[j]]
            lab[j] = int(u @ item_vec[tgt[j]] + match.mean() + rng.normal(0, 0.4) > 0)
        n_test = max(1, int(n * test_frac))
        t_h.append(hist[:n_test]); t_t.append(tgt[:n_test]); t_l.append(lab[:n_test])
        hists.append(hist[n_test:]); targets.append(tgt[n_test:].astype(np.int32))
        labels.append(lab[n_test:]); counts.append(n - n_test)

    max_len = max(counts)
    data = {
        "hist": _pad_stack(hists, max_len, fill=-1),
        "target": _pad_stack(targets, max_len, fill=0),
        "label": _pad_stack(labels, max_len, fill=0),
    }
    ids = [np.concatenate([h.reshape(-1), t]) for h, t in zip(hists, targets)]
    heat = _heat_from_ids(ids, num_items)
    return FederatedDataset(
        name="amazon_like", task="din", num_clients=num_clients,
        num_features=num_items, client_data=data, sample_counts=np.array(counts),
        heat=heat,
        test_data={"hist": np.concatenate(t_h), "target": np.concatenate(t_t),
                   "label": np.concatenate(t_l)},
        feature_key="hist",
    )


def make_alibaba_like(**kw) -> FederatedDataset:
    """Alibaba-industrial-like: same DIN task, higher dispersion + more clients."""
    kw.setdefault("num_clients", 500)
    kw.setdefault("num_items", 1500)
    kw.setdefault("zipf_a", 1.35)
    kw.setdefault("seed", 1)
    ds = make_amazon_like(**kw)
    ds.name = "alibaba_like"
    return ds


# ---------------------------------------------------------------------------
# Federated LM corpus (for the LLM-scale federated examples)
# ---------------------------------------------------------------------------


def make_lm_federated(num_clients: int = 64, vocab: int = 512, seq_len: int = 64,
                      samples_per_client: int = 4, zipf_a: float = 1.2,
                      seed: int = 0) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    pop = _zipf_probs(vocab, zipf_a)
    toks, counts = [], []
    for i in range(num_clients):
        boost = np.zeros(vocab)
        topic = rng.choice(vocab, size=16, p=pop)
        boost[topic] = 2.0
        p = pop * np.exp(boost)
        p /= p.sum()
        seqs = rng.choice(vocab, size=(samples_per_client, seq_len), p=p).astype(np.int32)
        toks.append(seqs)
        counts.append(samples_per_client)
    data = {"tokens": np.stack(toks)}
    heat = _heat_from_ids([t.reshape(-1) for t in toks], vocab)
    return FederatedDataset(
        name="lm_federated", task="lm", num_clients=num_clients,
        num_features=vocab, client_data=data, sample_counts=np.array(counts),
        heat=heat, test_data={"tokens": np.concatenate(toks)[:64]},
        feature_key="tokens",
    )


DATASETS = {
    "movielens": make_movielens_like,
    "sent140": make_sent140_like,
    "amazon": make_amazon_like,
    "alibaba": make_alibaba_like,
    "lm": make_lm_federated,
}
