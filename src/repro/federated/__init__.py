from repro.federated.arrivals import (  # noqa: F401
    ArrivalSim,
    EventSchedule,
)
from repro.federated.async_engine import (  # noqa: F401
    AsyncEngine,
    AsyncState,
    BufferedAsyncServerUpdate,
    build_async_engine,
    staleness_weight,
)
from repro.federated.client import (  # noqa: F401
    cohort_submodel_deltas,
    make_local_trainer,
    make_submodel_local_trainer,
)
from repro.federated.metrics import comm_summary  # noqa: F401
from repro.federated.plan import (  # noqa: F401
    CohortSharding,
    DenseTransport,
    FedSgdLocal,
    ReplicatedLocal,
    RoundPlan,
    RowSparseTransport,
    ServerUpdate,
    SubmodelReplicatedLocal,
    build_round_step,
    plan_comm_meta,
    plan_from_config,
    resolve_plan,
    split_heat_batch,
)
from repro.federated.server import (  # noqa: F401
    FederatedTrainer,
    RoundRecord,
    count_sub_ids,
    derive_sub_ids,
    pow2_capacity,
)
from repro.federated.simulation import (  # noqa: F401
    heat_spec_from_axes,
    make_round_step,
    round_capacity,
    sparse_table_paths,
)

#: the public API surface (pinned by tests/test_plan.py)
__all__ = [
    # plan strategies + compiler (the one dispatch system)
    "RoundPlan",
    "CohortSharding",
    "FedSgdLocal",
    "ReplicatedLocal",
    "SubmodelReplicatedLocal",
    "DenseTransport",
    "RowSparseTransport",
    "ServerUpdate",
    "build_round_step",
    "resolve_plan",
    "plan_from_config",
    "plan_comm_meta",
    "split_heat_batch",
    # entry points
    "make_round_step",
    "FederatedTrainer",
    # client-side local training
    "cohort_submodel_deltas",
    "make_local_trainer",
    "make_submodel_local_trainer",
    # server bookkeeping + sub-id derivation
    "RoundRecord",
    "comm_summary",
    "count_sub_ids",
    "derive_sub_ids",
    "pow2_capacity",
    # heat/sparse metadata helpers
    "heat_spec_from_axes",
    "round_capacity",
    "sparse_table_paths",
    # buffered-async engine (event-stream rounds)
    "ArrivalSim",
    "EventSchedule",
    "AsyncEngine",
    "AsyncState",
    "BufferedAsyncServerUpdate",
    "build_async_engine",
    "staleness_weight",
]
