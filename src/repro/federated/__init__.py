from repro.federated.client import (  # noqa: F401
    cohort_submodel_deltas,
    make_local_trainer,
    make_submodel_local_trainer,
)
from repro.federated.metrics import comm_summary  # noqa: F401
from repro.federated.server import (  # noqa: F401
    FederatedTrainer,
    RoundRecord,
    count_sub_ids,
    derive_sub_ids,
    pow2_capacity,
)
from repro.federated.simulation import (  # noqa: F401
    heat_spec_from_axes,
    make_round_step,
    round_capacity,
    sparse_table_paths,
)
