from repro.federated.client import make_local_trainer  # noqa: F401
from repro.federated.metrics import comm_summary  # noqa: F401
from repro.federated.server import FederatedTrainer  # noqa: F401
from repro.federated.simulation import (  # noqa: F401
    heat_spec_from_axes,
    make_round_step,
    sparse_table_paths,
)
