"""Deterministic client-arrival simulation for the buffered-async engine.

The paper's setting — millions of intermittently-available clients — is
exactly where stragglers dominate a barrier engine's wall clock. To study
FedSubAvg under asynchrony *reproducibly*, arrival timing is not sampled at
run time: :class:`ArrivalSim` draws every client's round-trip delay from a
seeded host RNG and **compiles the whole run into a static event stream**
(:class:`EventSchedule`) before anything touches a device. Each scheduled
client task contributes two events:

``DISPATCH``
    The server hands the client the *current* parameters; the client's local
    delta is computed against them and parked in a bounded in-flight slot.
``ARRIVAL``
    The delta reaches the server and joins the aggregation buffer; every
    ``buffer_size`` arrivals the buffer fires one staleness-weighted apply.

Because the event order is fixed host-side, everything timing-derived is
static: the server version at any event is ``arrivals_so_far //
buffer_size``, so each arrival's **staleness** (versions elapsed since its
dispatch), the **fire** flags, the greedy in-flight **slot** assignment and
the per-event in-flight count are all plain numpy columns of the schedule —
the jitted engine scans them as data, with no data-dependent shapes and no
host round-trips.

Delays are measured in dispatch-wave units (the server dispatches one
K-client wave per time unit). ``delay="zero"`` collapses the stream to the
synchronous order — K dispatches then K arrivals per wave — which is the
degenerate case the parity tests pin against ``run_rounds``. The modeled
makespans (:meth:`EventSchedule.barrier_makespan` /
:meth:`EventSchedule.async_makespan`) are seed-deterministic, so the bench
regression gate can pin the async-vs-barrier simulated-throughput ratio.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

#: event kinds (the ``kind`` column of an EventSchedule)
DISPATCH = 0
ARRIVAL = 1

DELAY_DISTRIBUTIONS = ("zero", "exponential", "lognormal")


@dataclass(frozen=True)
class ArrivalSim:
    """Seeded arrival-process generator; ``compile`` produces the schedule.

    ``num_rounds`` dispatch waves of K clients each (K is supplied at
    compile time so one sim can schedule different cohort sizes).

    ``delay`` ∈ {"zero", "exponential", "lognormal"}: per-task round-trip
    delay in wave units. ``delay_scale`` is the exponential mean / lognormal
    median; ``lognormal_sigma`` sets the log-normal tail weight (σ ≳ 1 is
    genuinely heavy-tailed).

    Straggler injection: ``straggler_frac`` of tasks (drawn without
    replacement), plus any explicit ``straggler_tasks``, get their delay
    multiplied by ``straggler_factor``. Dropout injection: ``dropout_frac``
    of tasks, plus ``dropout_tasks``, never dispatch and never arrive —
    their updates simply do not exist, which under FedSubAvg must leave
    their private rows exactly untouched.

    Draw order is fixed (delays, then stragglers, then dropouts), so equal
    seeds give bit-identical schedules.
    """

    num_rounds: int
    delay: str = "zero"
    delay_scale: float = 0.5
    lognormal_sigma: float = 1.0
    straggler_frac: float = 0.0
    straggler_factor: float = 10.0
    dropout_frac: float = 0.0
    straggler_tasks: Tuple[int, ...] = ()
    dropout_tasks: Tuple[int, ...] = ()
    seed: int = 0

    def __post_init__(self):
        if self.num_rounds < 1:
            raise ValueError(f"num_rounds must be >= 1, got {self.num_rounds}")
        if self.delay not in DELAY_DISTRIBUTIONS:
            raise ValueError(f"unknown delay distribution {self.delay!r}: "
                             f"expected one of {DELAY_DISTRIBUTIONS}")
        if self.delay_scale <= 0.0:
            raise ValueError(f"delay_scale must be > 0, got {self.delay_scale}")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError(f"straggler_frac out of [0, 1]: "
                             f"{self.straggler_frac}")
        if self.straggler_factor < 1.0:
            raise ValueError(f"straggler_factor must be >= 1, got "
                             f"{self.straggler_factor}")
        if not 0.0 <= self.dropout_frac <= 1.0:
            raise ValueError(f"dropout_frac out of [0, 1]: {self.dropout_frac}")

    # ------------------------------------------------------------------
    def compile(self, clients_per_round: int,
                buffer_size: int) -> "EventSchedule":
        """Draw delays and compile the padded event stream.

        Task ``t`` is client slot ``t % K`` of wave ``t // K`` — the same
        order ``FederatedTrainer`` samples cohorts in, so the trainer can
        stack all waves' data once and index it by the schedule's ``task``
        column. Events are ordered by ``(time, kind, task)``: dispatches
        precede arrivals at equal times, which is what makes the zero-delay
        stream reproduce the synchronous engine's per-wave order exactly.
        """
        k = int(clients_per_round)
        m = int(buffer_size)
        if k < 1:
            raise ValueError(f"clients_per_round must be >= 1, got {k}")
        if m < 1:
            raise ValueError(f"buffer_size must be >= 1, got {m}")
        num_tasks = self.num_rounds * k
        rng = np.random.default_rng(self.seed)

        if self.delay == "zero":
            delays = np.zeros(num_tasks)
        elif self.delay == "exponential":
            delays = rng.exponential(self.delay_scale, size=num_tasks)
        else:  # lognormal: median delay_scale, tail weight lognormal_sigma
            delays = rng.lognormal(mean=math.log(self.delay_scale),
                                   sigma=self.lognormal_sigma,
                                   size=num_tasks)

        stragglers = set(int(t) for t in self.straggler_tasks)
        n_strag = int(math.floor(self.straggler_frac * num_tasks))
        if n_strag:
            stragglers.update(
                int(t) for t in rng.choice(num_tasks, size=n_strag,
                                           replace=False))
        for t in stragglers:
            if not 0 <= t < num_tasks:
                raise ValueError(f"straggler task {t} out of range "
                                 f"[0, {num_tasks})")
            delays[t] *= self.straggler_factor

        dropped = np.zeros(num_tasks, bool)
        n_drop = int(math.floor(self.dropout_frac * num_tasks))
        if n_drop:
            dropped[rng.choice(num_tasks, size=n_drop, replace=False)] = True
        for t in self.dropout_tasks:
            if not 0 <= int(t) < num_tasks:
                raise ValueError(f"dropout task {t} out of range "
                                 f"[0, {num_tasks})")
            dropped[int(t)] = True

        waves = np.arange(num_tasks) // k
        dispatch_time = waves.astype(np.float64)
        arrival_time = np.where(dropped, np.inf, dispatch_time + delays)

        live = np.flatnonzero(~dropped)
        ev_time = np.concatenate([dispatch_time[live], arrival_time[live]])
        ev_kind = np.concatenate([np.full(live.size, DISPATCH, np.int32),
                                  np.full(live.size, ARRIVAL, np.int32)])
        ev_task = np.concatenate([live, live]).astype(np.int32)
        order = np.lexsort((ev_task, ev_kind, ev_time))
        ev_time, ev_kind, ev_task = (ev_time[order], ev_kind[order],
                                     ev_task[order])

        # sweep: greedy slot allocation + static staleness / fire / in-flight
        n_events = ev_kind.size
        slot = np.zeros(n_events, np.int32)
        staleness = np.zeros(n_events, np.int32)
        fire = np.zeros(n_events, bool)
        inflight = np.zeros(n_events, np.int32)
        slot_of = np.full(num_tasks, -1, np.int32)
        dispatch_version = np.zeros(num_tasks, np.int64)
        arrival_tasks = []
        free_slots: list = []
        allocated = 0
        arrivals = 0
        live_now = 0
        for e in range(n_events):
            t = int(ev_task[e])
            if ev_kind[e] == DISPATCH:
                if free_slots:
                    s = heapq.heappop(free_slots)
                else:
                    s = allocated
                    allocated += 1
                slot_of[t] = s
                dispatch_version[t] = arrivals // m
                live_now += 1
            else:
                s = int(slot_of[t])
                heapq.heappush(free_slots, s)
                staleness[e] = arrivals // m - dispatch_version[t]
                fire[e] = (arrivals + 1) % m == 0
                arrivals += 1
                arrival_tasks.append(t)
                live_now -= 1
            slot[e] = s
            inflight[e] = live_now

        return EventSchedule(
            kind=ev_kind, task=ev_task, slot=slot, staleness=staleness,
            fire=fire, inflight=inflight,
            dispatch_time=dispatch_time, arrival_time=arrival_time,
            dropped=dropped,
            arrival_tasks=np.asarray(arrival_tasks, np.int32),
            num_slots=max(allocated, 1), num_tasks=num_tasks,
            num_arrivals=arrivals, num_fires=arrivals // m,
            clients_per_round=k, num_rounds=self.num_rounds, buffer_size=m)


@dataclass
class EventSchedule:
    """A compiled arrival schedule: static event columns + timing model.

    Per-event columns (length ``num_events``): ``kind`` (DISPATCH/ARRIVAL),
    ``task`` (index into the trainer's stacked task data), ``slot``
    (in-flight store position), ``staleness`` (server versions between the
    task's dispatch and this arrival; 0 on dispatches), ``fire`` (this
    arrival completes a buffer of ``buffer_size``) and ``inflight``
    (dispatched-but-unarrived count after the event).

    Trailing arrivals that never complete a buffer (``num_arrivals %
    buffer_size``) are absorbed but never applied — the honest buffered
    semantics; ``num_fires`` counts the applies that actually happen.
    """

    kind: np.ndarray
    task: np.ndarray
    slot: np.ndarray
    staleness: np.ndarray
    fire: np.ndarray
    inflight: np.ndarray
    dispatch_time: np.ndarray   # (num_tasks,) wave-unit dispatch instants
    arrival_time: np.ndarray    # (num_tasks,) arrival instants (inf: dropped)
    dropped: np.ndarray         # (num_tasks,) bool
    arrival_tasks: np.ndarray   # (num_arrivals,) task ids in arrival order
    num_slots: int
    num_tasks: int
    num_arrivals: int
    num_fires: int
    clients_per_round: int
    num_rounds: int
    buffer_size: int

    @property
    def num_events(self) -> int:
        return int(self.kind.size)

    def event_arrays(self) -> Dict[str, np.ndarray]:
        """The scan-ready event columns (what the async engine consumes)."""
        return {"kind": self.kind, "task": self.task, "slot": self.slot,
                "staleness": self.staleness, "fire": self.fire,
                "inflight": self.inflight}

    def slice_events(self, lo: int, hi: int) -> Dict[str, np.ndarray]:
        """Event columns for the half-open range ``[lo, hi)``.

        The engine's :class:`~repro.federated.async_engine.AsyncState`
        carries everything between events, so scanning ``[0, e)`` then
        ``[e, E)`` is bit-identical to one ``[0, E)`` scan — the contract
        the mid-run checkpoint/restore test pins.
        """
        return {k: v[lo:hi] for k, v in self.event_arrays().items()}

    # -- modeled (simulated-time) throughput --------------------------------
    def barrier_makespan(self) -> float:
        """Simulated time a synchronous barrier engine needs for all waves.

        Rounds serialize: each wave costs one dispatch-cadence unit plus the
        slowest *participating* client's delay (dropouts are generously
        assumed to be timed out at no cost — the barrier engine's best
        case). Deterministic given the sim's seed.
        """
        total = 0.0
        for r in range(self.num_rounds):
            tasks = np.arange(r * self.clients_per_round,
                              (r + 1) * self.clients_per_round)
            live = tasks[~self.dropped[tasks]]
            worst = (float((self.arrival_time[live]
                            - self.dispatch_time[live]).max())
                     if live.size else 0.0)
            total += 1.0 + worst
        return total

    def async_makespan(self) -> float:
        """Simulated time the buffered-async engine needs to absorb all
        arrivals: waves dispatch at unit cadence regardless of completion,
        so the makespan is the last arrival instant (plus the final wave's
        cadence unit)."""
        live = ~self.dropped
        if not live.any():
            return 0.0
        return float((self.arrival_time[live] + 1.0).max())

    def sim_speedup(self) -> float:
        """Barrier-over-async simulated-makespan ratio (>1: async absorbs
        clients faster). Both engines process the same arrival count, so
        the clients-per-sim-unit ratio reduces to the makespan ratio."""
        a = self.async_makespan()
        return self.barrier_makespan() / a if a > 0.0 else 1.0
