"""Buffered-asynchronous federated engine on the sparse plane.

FedBuff-style server semantics for the paper's protocol: instead of a
barrier over a K-client cohort, the server absorbs per-client RowSparse
deltas *as they arrive* into a bounded buffer and fires one
staleness-weighted aggregate + apply every ``buffer_size`` arrivals. The
whole run is a single in-jit ``lax.scan`` over the static event stream a
:class:`~repro.federated.arrivals.ArrivalSim` compiled host-side:

``DISPATCH`` event
    Run the client's local training against the server's *current*
    parameters (the honest asynchronous semantics — by the time the delta
    arrives, the server may have moved on) and park the compressed delta in
    the event's pre-assigned in-flight slot, together with its monitoring
    loss and telemetry scalars. Slots are bounded by the schedule's maximum
    overlap, and their RowSparse leaves keep the sparse plane's O(R·D)
    memory — never O(V·D) per in-flight client.

``ARRIVAL`` event
    Move the slot's delta into the aggregation buffer at position
    ``buf_count``, scaled by the pluggable staleness weight ``w(s)``
    (constant, or polynomial ``1/(1+s)^a``); every ``buffer_size = M``-th
    arrival additionally **fires**: the buffered stack goes through the
    exact same fused ``sparse_cohort_aggregate`` scale path the synchronous
    engine uses (cohort mean ``1/M`` + FedSubAvg heat correction ``N/n_m``
    in one pass over the non-zeros) and the stateless ``X += eta * update``
    apply advances the server one version.

Heat under asynchrony: ``heat="static"`` feeds the exact per-feature counts
(the synchronous contract, needed for the degeneracy pin); ``heat="ema"``
replaces them with a streaming estimate — an exponential moving average over
per-arrival feature indicators, clamped into ``[1, N]`` exactly like the
randomized-response estimator — feeding the same correction factors.

Degeneracy contract (pinned by tests/test_async.py): a zero-delay schedule
with ``buffer_size == clients_per_round``, constant staleness weights and
static heat replays the synchronous ``run_rounds`` engine event-for-event —
same losses, same parameters, same RNG stream — because every wave becomes
K dispatches at the same server version followed by K arrivals whose buffer
is bitwise the synchronous cohort stack (the constant weight multiply is
statically skipped).

What deliberately does NOT compose (each rejection is pinned):
``CohortSharding`` (the event stream is inherently sequential — each
arrival may advance the server before the next dispatch, so there is no
cohort axis to shard), ``DenseTransport`` (the bounded slot/buffer stores
are the sparse plane's memory win), int8 transport (the per-round
stochastic-rounding key stream has no per-event analogue yet), stateful
server algorithms (scaffold/fedadam state is defined per barrier round) and
``FedSgdLocal`` (one pooled gradient has no per-client arrival).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.algorithms import ServerState
from repro.federated.client import (make_local_trainer,
                                    make_submodel_local_trainer)
from repro.federated.plan import (FedSgdLocal, ReplicatedLocal, RoundPlan,
                                  SubmodelReplicatedLocal, _apply_plain,
                                  heat_spec_from_axes, sparse_table_paths)
from repro.federated.arrivals import DISPATCH
from repro.sharding.logical import boxed_like, unbox
from repro.sparse.aggregate import sparse_cohort_aggregate
from repro.sparse.compress import compress_delta_tree
from repro.sparse.encode import (encode_delta_tree, pin_labels,
                                 sparse_eligible, tree_leaf_at)
from repro.sparse.rowsparse import PAD_ID, RowSparse, is_rowsparse
from repro.telemetry.round import (HEAT_BUCKETS, STALENESS_BUCKETS,
                                   RoundTelemetry, drop_stats, heat_histogram,
                                   staleness_histogram, tree_agg_rows,
                                   tree_sq_sum)

Array = jax.Array

STALENESS_SCHEMES = ("constant", "polynomial")
HEAT_MODES = ("static", "ema")
#: stateless applies only: scaffold/fedadam server state is defined per
#: barrier round and has no buffered-async analogue here
ASYNC_ALGORITHMS = ("fedavg", "fedprox", "fedsubavg")


def staleness_weight(staleness: Array, scheme: str = "polynomial",
                     alpha: float = 0.5) -> Array:
    """Pluggable staleness weight ``w(s)``.

    ``constant``: ``w(s) = 1`` (zero-staleness weighting — the buffer fire
    is then the uniform ``1/M`` mean, FedBuff's unnormalised form).
    ``polynomial``: ``w(s) = 1 / (1 + s)^alpha`` — stale deltas are damped,
    ``w(0) = 1`` always, so the two schemes agree on a fresh buffer.
    """
    s = jnp.asarray(staleness, jnp.float32)
    if scheme == "constant":
        return jnp.ones_like(s)
    if scheme == "polynomial":
        return (1.0 + s) ** (-float(alpha))
    raise ValueError(f"unknown staleness scheme {scheme!r}: expected one of "
                     f"{STALENESS_SCHEMES}")


@dataclass(frozen=True)
class BufferedAsyncServerUpdate:
    """The buffered-async ServerUpdate slot of a :class:`RoundPlan`.

    ``algorithm``: stateless applies only (fedavg / fedprox / fedsubavg);
    the FedSubAvg heat correction is fused iff ``algorithm == "fedsubavg"``,
    exactly as :class:`~repro.federated.plan.ServerUpdate`.
    ``buffer_size``: arrivals per server apply (FedBuff's M).
    ``staleness`` / ``staleness_alpha``: the weight ``w(s)`` applied to each
    buffered delta (see :func:`staleness_weight`).
    ``heat`` / ``heat_beta``: exact static counts vs the streaming EMA over
    arrival indicators (``p <- (1 - beta) p + beta * 1[feature in arrival]``,
    corrected counts ``clip(N * p, 1, N)``).
    """

    algorithm: str = "fedsubavg"
    buffer_size: int = 8
    staleness: str = "constant"
    staleness_alpha: float = 0.5
    heat: str = "static"
    heat_beta: float = 0.05

    def __post_init__(self):
        if self.algorithm not in ASYNC_ALGORITHMS:
            raise ValueError(
                f"unknown/unsupported async server algorithm "
                f"{self.algorithm!r}: the buffered-async engine supports the "
                f"stateless applies {ASYNC_ALGORITHMS} (scaffold/fedadam "
                "server state is defined per barrier round)")
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got "
                             f"{self.buffer_size}")
        if self.staleness not in STALENESS_SCHEMES:
            raise ValueError(f"unknown staleness scheme {self.staleness!r}: "
                             f"expected one of {STALENESS_SCHEMES}")
        if self.staleness_alpha < 0.0:
            raise ValueError(f"staleness_alpha must be >= 0, got "
                             f"{self.staleness_alpha}")
        if self.heat not in HEAT_MODES:
            raise ValueError(f"unknown heat mode {self.heat!r}: expected one "
                             f"of {HEAT_MODES}")
        if not 0.0 < self.heat_beta <= 1.0:
            raise ValueError(f"heat_beta out of (0, 1]: {self.heat_beta}")

    @property
    def correct(self) -> bool:
        return self.algorithm == "fedsubavg"

    @property
    def stateless(self) -> bool:
        return True


class AsyncState(NamedTuple):
    """Everything the event scan carries — and everything a mid-run
    checkpoint needs: scanning ``events[:e]`` then ``events[e:]`` from a
    saved/restored AsyncState is bit-identical to one uninterrupted scan.

    ``slots``: the in-flight delta store (pytree; RowSparse leaves
    ``(S, R)`` ids / ``(S, R, ...)`` rows, dense leaves ``(S, ...)``).
    ``buffer``: the aggregation buffer (same layout with leading ``M``),
    rows already staleness-weighted. The ``slot_*`` / ``buf_*`` scalars
    carry each delta's monitoring loss and telemetry stats (zeros when
    telemetry is off — one state structure either way). ``heat_ema`` is the
    streaming heat estimate ``p`` in [0, 1] per feature (``None`` under
    static heat).
    """

    server: ServerState
    slots: Any
    slot_loss: Array            # (S,) f32
    slot_pre_sq: Array          # (S,) f32: pre-compression squared L2
    slot_post_sq: Array         # (S,) f32
    slot_drop: Array            # (S,) i32: capacity-dropped distinct ids
    slot_mass: Array            # (S,) f32
    buffer: Any
    buf_loss: Array             # (M,) f32
    buf_staleness: Array        # (M,) i32
    buf_pre_sq: Array           # (M,) f32
    buf_post_sq: Array          # (M,) f32
    buf_drop: Array             # (M,) i32
    buf_mass: Array             # (M,) f32
    buf_count: Array            # () i32: filled buffer positions
    heat_ema: Any               # (V,) f32 | None
    arrivals: Array             # () i32: total arrivals absorbed


class AsyncEngine(NamedTuple):
    """A compiled buffered-async engine: ``init`` builds the scan state,
    ``run`` is the jittable event loop, ``server`` echoes the plan slot."""

    init: Callable
    run: Callable
    server: BufferedAsyncServerUpdate


def build_async_engine(plan: RoundPlan, loss_fn: Callable,
                       boxed_params_template, cfg: FedConfig, *,
                       heat_counts: Optional[Dict] = None,
                       total: Optional[float] = None,
                       telemetry: bool = False) -> AsyncEngine:
    """Compile a buffered-async plan into its event-scan engine.

    ``plan.server`` must be a :class:`BufferedAsyncServerUpdate`; the local
    step and transport are the unchanged RoundPlan strategies (replicated
    locals on the RowSparse transport, optional top-k). ``heat_counts`` /
    ``total`` bake the heat statistics exactly as ``build_round_step`` does;
    ``heat="ema"`` uses them as the EMA warm start.

    ``engine.run(state, events, tasks, sub_ids, feats=None)`` scans the
    event columns (``EventSchedule.event_arrays()``) over the stacked task
    data (leaves ``(T, I, B, ...)``) and per-task sub-ids ``(T, capacity)``;
    ``feats`` is the raw ``(T, M)`` feature-id stack (telemetry drop stats
    only). Returns ``(state, metrics)`` with per-event ``loss`` / ``fired``
    / ``version`` / ``buf_fill`` columns (filter by the schedule's static
    fire mask host-side) and, when ``telemetry``, a per-event stacked
    :class:`RoundTelemetry` whose async fields are live.

    ``engine.init(server_state, num_slots=..., capacity=...)`` builds the
    :class:`AsyncState`; ``capacity`` is the (pre-top-k) sub-id capacity.
    """
    local, transport, server = plan.local, plan.transport, plan.server
    if not isinstance(server, BufferedAsyncServerUpdate):
        raise TypeError(
            f"build_async_engine needs a BufferedAsyncServerUpdate server "
            f"slot, got {type(server).__name__} — the synchronous "
            "ServerUpdate compiles through build_round_step")
    if plan.sharding is not None:
        raise ValueError(
            "CohortSharding does not compose with the buffered-async "
            "engine: the event stream is inherently sequential (each "
            "arrival may advance the server before the next dispatch), so "
            "there is no cohort axis to shard — run the synchronous "
            "engine on the mesh, or the async engine unsharded")
    if not transport.sparse:
        raise ValueError(
            "the buffered-async engine runs the sparse plane only: the "
            "bounded in-flight slot store is O(R*D) per client because "
            "deltas stay RowSparse — use RowSparseTransport")
    if transport.int8:
        raise ValueError(
            "int8 transport does not compose with the buffered-async "
            "engine yet: the stochastic-rounding noise is keyed per "
            "synchronous round and has no per-event stream that would "
            "reproduce it")
    if isinstance(local, FedSgdLocal):
        raise ValueError(
            "FedSgdLocal pools the cohort into one fused gradient — there "
            "is no per-client delta to buffer; use ReplicatedLocal or "
            "SubmodelReplicatedLocal")
    if not isinstance(local, (ReplicatedLocal, SubmodelReplicatedLocal)):
        raise TypeError(f"unknown LocalStep: {local!r}")
    if plan.debug_checks:
        raise ValueError(
            "debug_checks (checkify) is not threaded through the async "
            "event scan yet — build the plan with debug_checks=False")

    feature_keys = tuple(plan.feature_keys)
    heat_spec = heat_spec_from_axes(boxed_params_template)
    paths = sparse_table_paths(heat_spec)
    table_paths = [p for p, _ in paths]
    if not table_paths:
        raise ValueError("the buffered-async engine needs at least one "
                         "axis-0 feature table (nothing rides the sparse "
                         "plane otherwise)")
    plain_template = unbox(boxed_params_template)
    vocabs = sorted({int(tree_leaf_at(plain_template, p).shape[0])
                     for p in table_paths})
    vocab = vocabs[-1]
    if isinstance(local, SubmodelReplicatedLocal) and len(vocabs) != 1:
        raise ValueError(
            f"submodel-replica feature tables disagree on vocab: {vocabs}")
    heat_space = paths[0][1][0]
    if server.heat == "ema":
        spaces = {s[0] for _, s in paths}
        if len(spaces) != 1 or len(vocabs) != 1:
            raise ValueError(
                "heat='ema' streams one indicator EMA over a single shared "
                f"feature-id space; found spaces {sorted(spaces)} over "
                f"vocabs {vocabs}")
    if (server.correct or server.heat == "ema") and heat_counts is None:
        raise ValueError(
            "the FedSubAvg correction (and the EMA warm start) need baked "
            "heat_counts — pass heat_counts/total as build_round_step does")
    n_total = float(cfg.num_clients if total is None else total)
    eta = cfg.server_lr
    m_buf = int(server.buffer_size)
    beta = float(server.heat_beta)
    weighted = server.staleness != "constant"   # static skip: the constant
    # weight multiplies by exactly 1.0, and skipping it keeps the zero-delay
    # buffer bitwise identical to the synchronous cohort stack

    # ---- per-client local delta + monitoring loss ------------------------
    if isinstance(local, SubmodelReplicatedLocal):
        local_train = make_submodel_local_trainer(
            loss_fn, cfg, table_paths, feature_keys, prox_mu=local.prox_mu)

        def client_delta(params, data, ids):
            data = pin_labels(data, feature_keys[0])
            delta = local_train(params, data, ids)
            first = jax.tree.map(lambda x: x[0], data)
            return delta, loss_fn(params, first)
    else:
        dense_train = make_local_trainer(loss_fn, cfg, prox_mu=local.prox_mu)

        def client_delta(params, data, ids):
            delta = encode_delta_tree(dense_train(params, data), heat_spec,
                                      ids)
            first = jax.tree.map(lambda x: x[0], data)
            return delta, loss_fn(params, first)

    # ---- bounded stores ---------------------------------------------------
    def _store_template(n: int, cap: int):
        def mk(leaf, space):
            if sparse_eligible(space):
                return RowSparse(
                    jnp.full((n, cap), PAD_ID, jnp.int32),
                    jnp.zeros((n, cap) + tuple(leaf.shape[1:]), leaf.dtype),
                    int(leaf.shape[0]))
            return jnp.zeros((n,) + tuple(leaf.shape), leaf.dtype)

        return jax.tree.map(mk, plain_template, heat_spec.leaf_spaces,
                            is_leaf=lambda x: x is None)

    def _store(store, idx, val):
        return jax.tree.map(lambda s, v: s.at[idx].set(v.astype(s.dtype)),
                            store, val)

    def _load(store, idx):
        return jax.tree.map(lambda s: s[idx], store)

    def _wscale(tree, w):
        def f(leaf):
            if is_rowsparse(leaf):
                return RowSparse(leaf.ids,
                                 leaf.rows * w.astype(leaf.rows.dtype),
                                 leaf.num_rows)
            return leaf * w.astype(leaf.dtype)

        return jax.tree.map(f, tree, is_leaf=is_rowsparse)

    # ---- streaming heat ---------------------------------------------------
    def _ema_update(p, ids):
        safe = jnp.where(ids >= 0, ids, vocab)
        ind = jnp.zeros((vocab,), jnp.float32).at[safe].set(1.0, mode="drop")
        return (1.0 - beta) * p + beta * ind

    def _fire_counts(st) -> Dict:
        if server.heat == "ema":
            # clamp into [1, N], like clamp_heat_estimate: an EMA that
            # decays a genuinely hot feature toward 0 must not hit the
            # h > 0 gate and silently zero that row's update
            return {heat_space: jnp.clip(st.heat_ema * n_total, 1.0,
                                         n_total)}
        return heat_counts if heat_counts is not None else {}

    # ---- telemetry assembly ----------------------------------------------
    def _tel_zero():
        return RoundTelemetry(
            dropped_ids=jnp.zeros((), jnp.int32),
            dropped_mass=jnp.zeros((), jnp.float32),
            dropped_per_client=jnp.zeros((m_buf,), jnp.int32),
            union_size=jnp.zeros((), jnp.int32),
            agg_rows=jnp.zeros((), jnp.int32),
            shard_union_sizes=None,
            delta_norm_pre=jnp.zeros((), jnp.float32),
            delta_norm_post=jnp.zeros((), jnp.float32),
            heat_hist=jnp.zeros((HEAT_BUCKETS,), jnp.float32),
            density=jnp.zeros((), jnp.float32),
            staleness_hist=jnp.zeros((STALENESS_BUCKETS,), jnp.float32),
            buffer_occupancy=jnp.zeros((), jnp.int32))

    def _tel_fire(st, agg, counts, inflight):
        union = None
        for leaf in jax.tree.leaves(agg, is_leaf=is_rowsparse):
            if is_rowsparse(leaf):
                union = leaf.ids
                break
        union_size = (union >= 0).sum(dtype=jnp.int32)
        hv = counts.get(heat_space) if counts else None
        hist = (heat_histogram(hv, union) if hv is not None
                else jnp.zeros((HEAT_BUCKETS,), jnp.float32))
        agg_rows = tree_agg_rows(agg)
        return RoundTelemetry(
            dropped_ids=st.buf_drop.sum(dtype=jnp.int32),
            dropped_mass=st.buf_mass.sum(),
            dropped_per_client=st.buf_drop,
            union_size=union_size,
            agg_rows=(agg_rows if agg_rows is not None
                      else jnp.zeros((), jnp.int32)),
            shard_union_sizes=None,
            delta_norm_pre=jnp.sqrt(st.buf_pre_sq.sum()),
            delta_norm_post=jnp.sqrt(st.buf_post_sq.sum()),
            heat_hist=hist,
            density=union_size.astype(jnp.float32) / vocab,
            staleness_hist=staleness_histogram(st.buf_staleness),
            buffer_occupancy=inflight.astype(jnp.int32))

    def _ys(st, loss, fired, tel):
        out = {"loss": loss, "fired": fired,
               "version": st.server.rounds.astype(jnp.int32),
               "buf_fill": st.buf_count}
        if telemetry:
            out["telemetry"] = tel
        return out

    zf = lambda: jnp.zeros((), jnp.float32)            # noqa: E731

    # ---- init -------------------------------------------------------------
    def init(server_state: ServerState, *, num_slots: int, capacity: int,
             heat_ema=None) -> AsyncState:
        slot_cap = (min(int(transport.topk), int(capacity))
                    if transport.topk else int(capacity))
        p = None
        if server.heat == "ema":
            if heat_ema is not None:
                p = jnp.asarray(heat_ema, jnp.float32)
            else:
                p = jnp.clip(
                    jnp.asarray(heat_counts[heat_space], jnp.float32)
                    / n_total, 0.0, 1.0)
        s, m = int(num_slots), m_buf
        return AsyncState(
            server=server_state._replace(
                rounds=jnp.asarray(server_state.rounds, jnp.int32)),
            slots=_store_template(s, slot_cap),
            slot_loss=jnp.zeros((s,), jnp.float32),
            slot_pre_sq=jnp.zeros((s,), jnp.float32),
            slot_post_sq=jnp.zeros((s,), jnp.float32),
            slot_drop=jnp.zeros((s,), jnp.int32),
            slot_mass=jnp.zeros((s,), jnp.float32),
            buffer=_store_template(m, slot_cap),
            buf_loss=jnp.zeros((m,), jnp.float32),
            buf_staleness=jnp.zeros((m,), jnp.int32),
            buf_pre_sq=jnp.zeros((m,), jnp.float32),
            buf_post_sq=jnp.zeros((m,), jnp.float32),
            buf_drop=jnp.zeros((m,), jnp.int32),
            buf_mass=jnp.zeros((m,), jnp.float32),
            buf_count=jnp.zeros((), jnp.int32),
            heat_ema=p,
            arrivals=jnp.zeros((), jnp.int32))

    # ---- the event scan ---------------------------------------------------
    def run(state: AsyncState, events: Dict[str, Array], tasks: Dict,
            sub_ids: Array, feats: Optional[Array] = None):
        def event_step(st, ev):
            task, slot = ev["task"], ev["slot"]
            data = jax.tree.map(lambda x: x[task], tasks)
            ids = sub_ids[task]

            def do_dispatch(st):
                delta, loss = client_delta(st.server.params, data, ids)
                delta_c = (compress_delta_tree(delta, topk=transport.topk)
                           if transport.topk else delta)
                st = st._replace(
                    slots=_store(st.slots, slot, delta_c),
                    slot_loss=st.slot_loss.at[slot].set(loss))
                if telemetry:
                    if feats is not None:
                        dr, ms = drop_stats(feats[task], ids, vocab)
                    else:
                        dr, ms = jnp.zeros((), jnp.int32), zf()
                    st = st._replace(
                        slot_pre_sq=st.slot_pre_sq.at[slot].set(
                            tree_sq_sum(delta)),
                        slot_post_sq=st.slot_post_sq.at[slot].set(
                            tree_sq_sum(delta_c)),
                        slot_drop=st.slot_drop.at[slot].set(
                            dr.astype(jnp.int32)),
                        slot_mass=st.slot_mass.at[slot].set(ms))
                return st, _ys(st, zf(), jnp.zeros((), bool),
                               _tel_zero() if telemetry else None)

            def do_fire(st):
                counts = _fire_counts(st)
                agg = sparse_cohort_aggregate(
                    st.buffer, heat_spec, counts, n_total, m_buf,
                    correct=server.correct,
                    union_backend=transport.union_backend)
                plain = unbox(st.server.params)
                new_plain = _apply_plain(plain, agg, eta)
                srv = ServerState(
                    boxed_like(new_plain, st.server.params),
                    st.server.opt, st.server.rounds + 1)
                loss = st.buf_loss.mean()
                tel = (_tel_fire(st, agg, counts, ev["inflight"])
                       if telemetry else None)
                st = st._replace(server=srv,
                                 buf_count=jnp.zeros((), jnp.int32))
                return st, _ys(st, loss, jnp.ones((), bool), tel)

            def no_fire(st):
                return st, _ys(st, zf(), jnp.zeros((), bool),
                               _tel_zero() if telemetry else None)

            def do_arrival(st):
                d = _load(st.slots, slot)
                if weighted:
                    d = _wscale(d, staleness_weight(
                        ev["staleness"], server.staleness,
                        server.staleness_alpha))
                pos = st.buf_count
                st = st._replace(
                    buffer=_store(st.buffer, pos, d),
                    buf_loss=st.buf_loss.at[pos].set(st.slot_loss[slot]),
                    buf_staleness=st.buf_staleness.at[pos].set(
                        ev["staleness"].astype(jnp.int32)),
                    buf_count=pos + 1,
                    arrivals=st.arrivals + 1)
                if telemetry:
                    st = st._replace(
                        buf_pre_sq=st.buf_pre_sq.at[pos].set(
                            st.slot_pre_sq[slot]),
                        buf_post_sq=st.buf_post_sq.at[pos].set(
                            st.slot_post_sq[slot]),
                        buf_drop=st.buf_drop.at[pos].set(
                            st.slot_drop[slot]),
                        buf_mass=st.buf_mass.at[pos].set(
                            st.slot_mass[slot]))
                if server.heat == "ema":
                    st = st._replace(heat_ema=_ema_update(st.heat_ema, ids))
                return jax.lax.cond(ev["fire"], do_fire, no_fire, st)

            return jax.lax.cond(ev["kind"] == DISPATCH, do_dispatch,
                                do_arrival, st)

        events = {k: jnp.asarray(v) for k, v in events.items()}
        return jax.lax.scan(event_step, state, events)

    return AsyncEngine(init=init, run=run, server=server)
