"""Client-side local training (Algorithm 1 lines 12-18).

A client downloads the (sub)model, runs ``I`` iterations of mini-batch SGD
and uploads the delta. Submodel semantics are automatic under autodiff: rows
of feature-keyed tables the client never touches get exactly-zero gradient,
so its delta is supported on S(i) — the paper's "the local gradient of
X_{S\\S(i)} will always be zero".

Algorithm hooks:
    fedprox  — adds (mu/2)||x - x_global||^2 to the local objective
    scaffold — paper's App. D.2 server-side approximation needs no client state
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.pytree import tree_add, tree_dot, tree_scale, tree_sub
from repro.configs.base import FedConfig
from repro.sharding.logical import is_param
from repro.sparse.encode import (gather_submodel_tree, remap_feature_batch,
                                 submodel_delta_tree, tree_leaf_at)


def _local_sgd_delta(loss_fn: Callable, cfg: FedConfig, params0, batches,
                     prox_mu: Optional[float] = None):
    """I steps of mini-batch SGD from ``params0``; returns the delta.

    The single local-training loop both replica layouts share: ``params0``
    is the downloaded model — full dense parameters or a gathered submodel —
    and also the FedProx prox anchor. ``batches`` leaves are (I, B, ...).
    ``prox_mu`` overrides the proximal coefficient; ``None`` derives it from
    the config (``cfg.prox_mu`` iff ``cfg.algorithm == "fedprox"``), so
    RoundPlan compositions can turn a FedProx-style local objective on or off
    independently of the server algorithm string.
    """
    prox = (cfg.prox_mu if cfg.algorithm == "fedprox" else 0.0) \
        if prox_mu is None else float(prox_mu)

    def objective(p, batch):
        l = loss_fn(p, batch)
        if prox > 0.0:
            diff = tree_sub(p, params0)
            l = l + 0.5 * prox * tree_dot(diff, diff)
        return l

    def step(p, batch):
        g = jax.grad(objective)(p, batch)
        return tree_add(p, tree_scale(g, -cfg.lr)), None

    p_final, _ = lax.scan(step, params0, batches)
    return tree_sub(p_final, params0)


def make_local_trainer(loss_fn: Callable, cfg: FedConfig,
                       prox_mu: Optional[float] = None) -> Callable:
    """Returns local_train(global_params, client_batches) -> delta.

    ``client_batches`` leaves are (I, B, ...): the client's I minibatches.
    """

    def local_train(global_params, client_batches):
        return _local_sgd_delta(loss_fn, cfg, global_params, client_batches,
                                prox_mu=prox_mu)

    return local_train


def cohort_deltas(local_train: Callable, global_params, cohort_batches):
    """vmap local training over the cohort; leaves (K, I, B, ...) -> (K, ...)."""
    return jax.vmap(local_train, in_axes=(None, 0))(global_params, cohort_batches)


def make_submodel_local_trainer(loss_fn: Callable, cfg: FedConfig,
                                table_paths: Sequence[Sequence],
                                feature_keys: Sequence[str],
                                prox_mu: Optional[float] = None) -> Callable:
    """Returns local_train(global_params, client_batches, sub_ids) -> delta.

    The paper's protocol made literal: a client's replica is its *submodel*
    only. Each feature-keyed table at ``table_paths`` is gathered at the
    client's ``sub_ids`` into a ``(capacity, ...)`` row table, every
    ``client_batches[k]`` for k in ``feature_keys`` is remapped to row slots,
    and the I local SGD steps run on the gathered rows plus the dense leaves
    — replica HBM is O(capacity * D) per feature table, never O(V * D). The
    delta comes back with ``RowSparse`` leaves at the table paths, already in
    wire format for the sparse server plane (no post-hoc encode).

    Exact vs dense-replica local training whenever the model consumes the
    tables only through lookups by those feature keys (the paper's §3.1
    observation: the local gradient outside S(i) is always zero, so rows
    outside ``sub_ids`` never move). FedProx stays exact too: untouched rows
    keep ``p == x_global`` for the whole local run, so their prox gradient is
    identically zero.
    """

    def local_train(global_params, client_batches, sub_ids):
        num_rows = []
        for path in table_paths:
            leaf = tree_leaf_at(global_params, path)
            num_rows.append((leaf.value if is_param(leaf) else leaf).shape[0])
        sub_params = gather_submodel_tree(global_params, table_paths, sub_ids)
        batches = remap_feature_batch(client_batches, feature_keys, sub_ids)
        delta = _local_sgd_delta(loss_fn, cfg, sub_params, batches,
                                 prox_mu=prox_mu)
        return submodel_delta_tree(delta, table_paths, sub_ids, num_rows)

    return local_train


def cohort_submodel_deltas(local_train: Callable, global_params,
                           cohort_batches, sub_ids):
    """vmap submodel local training over the cohort.

    ``sub_ids``: (K, capacity) per-client submodel ids. Returns the per-client
    update stack with RowSparse leaves (ids (K, R), rows (K, R, ...)) at the
    feature-table paths and dense (K, ...) leaves elsewhere — exactly the
    input ``sparse_cohort_aggregate`` consumes.
    """
    return jax.vmap(local_train, in_axes=(None, 0, 0))(
        global_params, cohort_batches, sub_ids)
