"""Client-side local training (Algorithm 1 lines 12-18).

A client downloads the (sub)model, runs ``I`` iterations of mini-batch SGD
and uploads the delta. Submodel semantics are automatic under autodiff: rows
of feature-keyed tables the client never touches get exactly-zero gradient,
so its delta is supported on S(i) — the paper's "the local gradient of
X_{S\\S(i)} will always be zero".

Algorithm hooks:
    fedprox  — adds (mu/2)||x - x_global||^2 to the local objective
    scaffold — paper's App. D.2 server-side approximation needs no client state
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.pytree import tree_add, tree_dot, tree_scale, tree_sub
from repro.configs.base import FedConfig


def make_local_trainer(loss_fn: Callable, cfg: FedConfig) -> Callable:
    """Returns local_train(global_params, client_batches) -> delta.

    ``client_batches`` leaves are (I, B, ...): the client's I minibatches.
    """
    prox = cfg.prox_mu if cfg.algorithm == "fedprox" else 0.0

    def local_train(global_params, client_batches):
        def objective(p, batch):
            l = loss_fn(p, batch)
            if prox > 0.0:
                diff = tree_sub(p, global_params)
                l = l + 0.5 * prox * tree_dot(diff, diff)
            return l

        def step(p, batch):
            g = jax.grad(objective)(p, batch)
            return tree_add(p, tree_scale(g, -cfg.lr)), None

        p_final, _ = lax.scan(step, global_params, client_batches)
        return tree_sub(p_final, global_params)

    return local_train


def cohort_deltas(local_train: Callable, global_params, cohort_batches):
    """vmap local training over the cohort; leaves (K, I, B, ...) -> (K, ...)."""
    return jax.vmap(local_train, in_axes=(None, 0))(global_params, cohort_batches)
