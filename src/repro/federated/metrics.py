"""Evaluation metrics: binary accuracy, AUC (rank statistic, as the paper
plots test AUC for the CTR tasks), and comm-cost summaries for the sparse
submodel update plane."""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney AUC; 0.5 when degenerate."""
    labels = np.asarray(labels).astype(bool)
    scores = np.asarray(scores, dtype=np.float64)
    pos, neg = scores[labels], scores[~labels]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    order = np.argsort(np.concatenate([pos, neg]), kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(order) + 1)
    # average ranks for ties
    allv = np.concatenate([pos, neg])
    sortv = allv[order]
    i = 0
    while i < len(sortv):
        j = i
        while j + 1 < len(sortv) and sortv[j + 1] == sortv[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = ranks[order[i:j + 1]].mean()
        i = j + 1
    r_pos = ranks[: len(pos)].sum()
    return float((r_pos - len(pos) * (len(pos) + 1) / 2) / (len(pos) * len(neg)))


def accuracy(labels: np.ndarray, scores: np.ndarray) -> float:
    return float(((scores > 0) == (np.asarray(labels) > 0.5)).mean())


def comm_summary(comm_log: Sequence) -> Dict[str, float]:
    """Totals over a list of ``repro.sparse.comm.CommStats`` rounds.

    ``up_ratio`` / ``down_ratio`` are dense-baseline over sparse-plane bytes:
    > 1 means the sparse plane saved wire traffic.
    """
    if not comm_log:
        return {"rounds": 0, "bytes_up_sparse": 0.0, "bytes_up_dense": 0.0,
                "bytes_down_sparse": 0.0, "bytes_down_dense": 0.0,
                "mean_density": 1.0, "up_ratio": 1.0, "down_ratio": 1.0}
    up_s = sum(c.bytes_up_sparse for c in comm_log)
    up_d = sum(c.bytes_up_dense for c in comm_log)
    dn_s = sum(c.bytes_down_sparse for c in comm_log)
    dn_d = sum(c.bytes_down_dense for c in comm_log)
    return {
        "rounds": len(comm_log),
        "bytes_up_sparse": up_s, "bytes_up_dense": up_d,
        "bytes_down_sparse": dn_s, "bytes_down_dense": dn_d,
        "mean_density": float(np.mean([c.density for c in comm_log])),
        "up_ratio": up_d / max(up_s, 1.0),
        "down_ratio": dn_d / max(dn_s, 1.0),
    }
