"""Evaluation metrics: binary accuracy and AUC (rank statistic, as the paper
plots test AUC for the CTR tasks)."""
from __future__ import annotations

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney AUC; 0.5 when degenerate."""
    labels = np.asarray(labels).astype(bool)
    scores = np.asarray(scores, dtype=np.float64)
    pos, neg = scores[labels], scores[~labels]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    order = np.argsort(np.concatenate([pos, neg]), kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(order) + 1)
    # average ranks for ties
    allv = np.concatenate([pos, neg])
    sortv = allv[order]
    i = 0
    while i < len(sortv):
        j = i
        while j + 1 < len(sortv) and sortv[j + 1] == sortv[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = ranks[order[i:j + 1]].mean()
        i = j + 1
    r_pos = ranks[: len(pos)].sum()
    return float((r_pos - len(pos) * (len(pos) + 1) / 2) / (len(pos) * len(neg)))


def accuracy(labels: np.ndarray, scores: np.ndarray) -> float:
    return float(((scores > 0) == (np.asarray(labels) > 0.5)).mean())
