"""Evaluation metrics: binary accuracy, AUC (rank statistic, as the paper
plots test AUC for the CTR tasks), and comm-cost summaries for the sparse
submodel update plane."""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney AUC; NaN when the eval labels are single-class.

    A single-class label vector has no pos/neg pairs to rank, so AUC is
    undefined — returning a plausible-looking 0.5 used to let a broken eval
    split (or a degenerate sampler) masquerade as a coin-flip model in the
    round logs. NaN is unmissable and propagates through round averaging.

    Tied ranks are averaged fully vectorised: a value group occupying sorted
    ranks ``start..end`` has average rank ``end - (count - 1) / 2``, computed
    straight from ``np.unique`` group counts. (The old per-group Python loop
    was O(n^2) on heavily tied score vectors — the common case early in
    training, when a barely-moved model emits near-constant logits.)
    """
    labels = np.asarray(labels).astype(bool)
    scores = np.asarray(scores, dtype=np.float64)
    pos, neg = scores[labels], scores[~labels]
    if len(pos) == 0 or len(neg) == 0:
        return float("nan")
    allv = np.concatenate([pos, neg])
    _, inv, cnt = np.unique(allv, return_inverse=True, return_counts=True)
    end = np.cumsum(cnt)                       # 1-indexed last rank per group
    ranks = (end - (cnt - 1) / 2.0)[inv]       # average rank of each element
    r_pos = ranks[: len(pos)].sum()
    return float((r_pos - len(pos) * (len(pos) + 1) / 2) / (len(pos) * len(neg)))


def accuracy(labels: np.ndarray, scores: np.ndarray) -> float:
    return float(((scores > 0) == (np.asarray(labels) > 0.5)).mean())


def telemetry_summary(telemetry_log: Sequence[Dict]) -> Dict[str, object]:
    """Aggregate a trainer's per-round telemetry events.

    ``telemetry_log`` holds the host-side round events collected by
    ``FederatedTrainer`` (dicts with the ``repro.telemetry.round.
    RoundTelemetry`` fields). Drop accounting totals over rounds; union
    size and density average; the heat histogram sums bucket-wise — the
    run-level view of the paper's hot/cold split.
    """
    if not telemetry_log:
        return {"rounds": 0, "dropped_ids": 0, "dropped_mass": 0.0,
                "mean_union_size": 0.0, "mean_density": 0.0, "heat_hist": []}
    drops = sum(int(e.get("dropped_ids") or 0) for e in telemetry_log)
    mass = sum(float(e.get("dropped_mass") or 0.0) for e in telemetry_log)
    unions = [float(e.get("union_size") or 0) for e in telemetry_log]
    dens = [float(e.get("density") or 0.0) for e in telemetry_log]
    hists = [e["heat_hist"] for e in telemetry_log if e.get("heat_hist")]
    hist = (np.sum(np.asarray(hists, dtype=np.float64), axis=0).tolist()
            if hists else [])
    return {"rounds": len(telemetry_log), "dropped_ids": drops,
            "dropped_mass": mass, "mean_union_size": float(np.mean(unions)),
            "mean_density": float(np.mean(dens)), "heat_hist": hist}


def comm_summary(comm_log: Sequence) -> Dict[str, float]:
    """Totals over a list of ``repro.sparse.comm.CommStats`` rounds.

    ``up_ratio`` / ``down_ratio`` are dense-baseline over sparse-plane bytes:
    > 1 means the sparse plane saved wire traffic.
    """
    if not comm_log:
        return {"rounds": 0, "bytes_up_sparse": 0.0, "bytes_up_dense": 0.0,
                "bytes_down_sparse": 0.0, "bytes_down_dense": 0.0,
                "mean_density": 1.0, "up_ratio": 1.0, "down_ratio": 1.0}
    up_s = sum(c.bytes_up_sparse for c in comm_log)
    up_d = sum(c.bytes_up_dense for c in comm_log)
    dn_s = sum(c.bytes_down_sparse for c in comm_log)
    dn_d = sum(c.bytes_down_dense for c in comm_log)
    return {
        "rounds": len(comm_log),
        "bytes_up_sparse": up_s, "bytes_up_dense": up_d,
        "bytes_down_sparse": dn_s, "bytes_down_dense": dn_d,
        "mean_density": float(np.mean([c.density for c in comm_log])),
        "up_ratio": up_d / max(up_s, 1.0),
        "down_ratio": dn_d / max(dn_s, 1.0),
    }
