"""Composable execution plans for one federated round.

The paper's FedSubAvg protocol (Ding et al., NeurIPS 2022) is ONE server
update behind many execution layouts. A :class:`RoundPlan` names a layout as
three orthogonal strategy choices instead of a mode string:

``LocalStep`` — how the cohort produces update deltas:
    :class:`FedSgdLocal`              I = 1 on the pooled cohort batch
                                      (optionally microbatched); the cohort
                                      mean is one gradient.
    :class:`ReplicatedLocal`          true I > 1 local SGD on per-client
                                      DENSE model replicas (vmap).
    :class:`SubmodelReplicatedLocal`  I > 1 local SGD on per-client
                                      gathered SUBMODEL replicas — the
                                      paper's download-a-submodel protocol;
                                      deltas are born RowSparse.

``Transport`` — what ships between clients and server (and what one round
costs in bytes — the transport owns comm accounting):
    :class:`DenseTransport`           full dense update trees.
    :class:`RowSparseTransport`       row-sparse ``(ids, rows)`` updates with
                                      optional top-k row selection, int8
                                      stochastic-rounding quantisation, and a
                                      union-backend choice for the server
                                      segment-sum.

``ServerUpdate`` — the heat correction plus the algorithm that applies the
aggregated update: plain (fedavg / fedprox / fedsubavg) or the stateful
server optimizers (scaffold / fedadam), reusing
``repro.core.algorithms.make_server_algorithm`` slots.

``CohortSharding`` — the optional fourth strategy, orthogonal to the other
three: split the cohort axis over a device mesh. ``build_round_step`` wraps
the local phase in ``shard_map``; each shard runs its K/dev clients and a
per-shard partial aggregation, a cross-device combine produces the global
update, and the (replicated) server apply is identical on every shard —
exact vs the single-device step to 1e-5 under the same RNG stream.

:func:`build_round_step` compiles a plan into the single jitted round step
both entry points run: ``make_round_step`` (mode strings are thin aliases via
:func:`resolve_plan`) and ``FederatedTrainer`` (``FedConfig`` flags resolve
via :func:`plan_from_config`, or pass ``plan=`` explicitly). One dispatch
system, two entry points — and compositions no mode string ever expressed
(top-k/int8 under the simulation's sparse path, submodel-replica local
training against a dense server transport) fall out for free.

Shared concerns that were once copy-pasted per mode branch live here (or in
the module that owns them) exactly once: heat-batch splitting
(:func:`split_heat_batch`), CE-label pinning (``repro.sparse.encode.
pin_labels``), sub-id derivation, loss/density metrics, boxed/unboxed
plumbing, and compression (``repro.sparse.compress.compress_delta_tree``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_path_keys, tree_scale
from repro.configs.base import SERVER_ALGORITHMS, FedConfig
from repro.core.aggregate import HeatSpec, correct_dense_leaf, correct_update_tree
from repro.core.algorithms import ServerState, make_server_algorithm
from repro.federated.client import (cohort_deltas, cohort_submodel_deltas,
                                    make_local_trainer,
                                    make_submodel_local_trainer)
from repro.analysis import sanitize
from repro.sharding.logical import axes_tree, boxed_like, unbox
from repro.sparse.aggregate import (aggregate_rowsparse_partial,
                                    apply_rowsparse,
                                    combine_rowsparse_partials,
                                    correct_rowsparse, pick_combine,
                                    sparse_cohort_aggregate)
from repro.sparse.comm import CommMeta, CommStats, model_comm_meta, round_comm_stats
from repro.sparse.compress import compress_delta_tree
from repro.sparse.encode import (DEFAULT_SPARSE_SPACES, batch_union_ids,
                                 decode_delta_tree, encode_delta_tree,
                                 flat_feature_ids, pin_labels, sparse_eligible,
                                 stacked_feature_ids, submodel_value_and_grad,
                                 tree_leaf_at)
from repro.sparse.rowsparse import (RowSparse, count_unique_ids, is_rowsparse,
                                    unique_ids_padded)
from repro.telemetry.round import (HEAT_BUCKETS, RoundTelemetry, drop_stats,
                                   heat_histogram, tree_agg_rows, tree_sq_sum,
                                   union_ids_vec)

Array = jax.Array

#: round-plan server algorithms ("central" is not a federated round)
PLAN_ALGORITHMS = tuple(a for a in SERVER_ALGORITHMS if a != "central")


# ---------------------------------------------------------------------------
# heat-spec derivation (moved here from simulation.py; re-exported there)
# ---------------------------------------------------------------------------


def heat_spec_from_axes(boxed_params,
                        spaces: Dict[str, str] = None) -> HeatSpec:
    """Derive the HeatSpec from Param logical axes.

    spaces maps logical axis name -> heat space name; default:
    "vocab" axis -> "vocab" space, "experts" axis -> "expert" space.
    """
    spaces = spaces or {"vocab": "vocab", "experts": "expert"}
    axes = axes_tree(boxed_params)

    def is_axes(x):
        return x is None or (isinstance(x, tuple)
                             and all(e is None or isinstance(e, str) for e in x))

    def leaf_space(ax):
        if ax is None:
            return None
        for i, name in enumerate(ax):
            if name in spaces:
                return (spaces[name], i)
        return None

    return HeatSpec(jax.tree.map(leaf_space, axes, is_leaf=is_axes))


def _is_space(x) -> bool:
    return x is None or (isinstance(x, tuple) and len(x) == 2
                         and isinstance(x[0], str) and isinstance(x[1], int))


def sparse_table_paths(heat_spec: HeatSpec, spaces=None):
    """Paths of the leaves that ride the sparse plane (axis-0 feature tables)."""
    if spaces is None:
        spaces = DEFAULT_SPARSE_SPACES
    flat, _ = jax.tree_util.tree_flatten_with_path(heat_spec.leaf_spaces,
                                                   is_leaf=_is_space)
    return [(tree_path_keys(path), space) for path, space in flat
            if sparse_eligible(space, spaces)]


def round_capacity(vocab: int, ids_size: int, align: int = 8) -> int:
    """Union-id capacity for one sparse round step.

    ``min(vocab, ids_size)`` rounded up to a multiple of ``align`` for tiling,
    then clamped back to ``vocab`` — the rounding must never allocate union
    slots past the feature table (e.g. V=50257 would otherwise get 50264
    slots, gathering rows that don't exist in the table's id space).
    """
    cap = min(int(vocab), int(ids_size))
    cap += (-cap) % align
    return min(cap, int(vocab))


def split_heat_batch(batch: Dict) -> Tuple[Dict, Dict]:
    """Split a round batch into its static heat vectors and the cohort data.

    ``heat_*`` entries (``heat_vocab``, ``heat_expert``, ...) ride along the
    batch on the simulation entry point; the trainer bakes heat statically
    and its batches simply carry no such keys.
    """
    heat = {k: v for k, v in batch.items() if k.startswith("heat_")}
    data = {k: v for k, v in batch.items() if not k.startswith("heat_")}
    return heat, data


# ---------------------------------------------------------------------------
# strategy objects
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FedSgdLocal:
    """I = 1: the cohort-mean delta is one gradient of the pooled batch.

    ``microbatches > 1`` splits the batch for gradient accumulation (dense
    transport only — the sparse plane computes one fused cohort gradient).
    Data layout: flat ``(B, ...)`` leaves. FedProx is a no-op here: a single
    step taken AT the prox anchor has identically zero prox gradient.
    """

    microbatches: int = 1
    stacked = False


@dataclass(frozen=True)
class ReplicatedLocal:
    """True I > 1 local SGD on per-client DENSE replicas under vmap.

    Data layout: ``(K, I, B, ...)`` leaves. ``prox_mu`` overrides the FedProx
    proximal coefficient (``None`` derives it from the config: active iff
    ``cfg.algorithm == "fedprox"``). Memory: K full model replicas.
    """

    prox_mu: Optional[float] = None
    stacked = True


@dataclass(frozen=True)
class SubmodelReplicatedLocal:
    """I > 1 local SGD on per-client gathered SUBMODEL replicas.

    The paper's protocol made literal: each client's replica is its gathered
    ``(capacity, D)`` feature rows plus the dense leaves; deltas are born
    RowSparse on the client's sub-ids. Memory: K * capacity * D feature-table
    HBM instead of the K * V * D dense-replica wall. Data layout and
    ``prox_mu`` as :class:`ReplicatedLocal`.
    """

    prox_mu: Optional[float] = None
    stacked = True


LocalStep = Union[FedSgdLocal, ReplicatedLocal, SubmodelReplicatedLocal]


@dataclass(frozen=True)
class DenseTransport:
    """Full dense update trees ship both ways (the classic FL layout)."""

    sparse = False

    def round_comm(self, rnd: int, meta: CommMeta, valid_counts: np.ndarray,
                   num_features: int, capacity: Optional[int] = None,
                   submodel_downlink: bool = False,
                   local_iters: int = 1) -> Optional[CommStats]:
        """Dense rounds have no sparse-plane pricing to log."""
        return None


@dataclass(frozen=True)
class RowSparseTransport:
    """Row-sparse ``(ids, rows)`` updates — the paper's submodel wire format.

    ``topk``: keep only the k largest-L2 delta rows per client (0 = off).
    ``int8``: unbiased stochastic-rounding int8 row payloads.
    ``union_backend``: server segment-sum backend (``"auto"``/``"bitmap"``/
    ``"sort"``/``"pallas"`` — see ``repro.sparse.aggregate``).
    """

    topk: int = 0
    int8: bool = False
    union_backend: str = "auto"
    sparse = True

    def __post_init__(self):
        if self.topk < 0:
            raise ValueError(f"topk must be >= 0 (0 disables), got {self.topk}")

    def round_comm(self, rnd: int, meta: CommMeta, valid_counts: np.ndarray,
                   num_features: int, capacity: Optional[int] = None,
                   submodel_downlink: bool = False,
                   local_iters: int = 1) -> CommStats:
        """Price one round in exact bytes from per-client sub-id counts.

        Uplink: top-k ships exactly ``min(topk, valid)`` delta rows per
        client (int8 pricing applied when enabled). Downlink prices what the
        execution actually ships: the gathered ``capacity``-row submodel
        buffer (clamped to the table — pow2 padding past V never hits the
        wire) when ``submodel_downlink``, else the full feature table. The
        dense baseline carries the ``local_iters`` factor (the I=1 dense
        protocol re-ships the model every local step).
        """
        valid_counts = np.asarray(valid_counts)
        k = len(valid_counts)
        up = (np.minimum(valid_counts, self.topk) if self.topk
              else valid_counts)
        if submodel_downlink:
            if capacity is None:
                raise ValueError("submodel downlink pricing needs the "
                                 "gathered replica capacity")
            down = np.full(k, min(int(capacity), int(num_features)))
        else:
            down = np.full(k, int(num_features))
        return round_comm_stats(
            rnd, meta.dense_bytes, meta.sparse_static_bytes,
            meta.row_payload_bytes, valid_counts, num_features,
            int8=self.int8, row_elems=meta.row_elems,
            uplink_rows_per_client=up, downlink_rows_per_client=down,
            local_iters=local_iters)


Transport = Union[DenseTransport, RowSparseTransport]


@dataclass(frozen=True)
class ServerUpdate:
    """Heat correction + the server algorithm that applies the update.

    ``algorithm`` picks the apply slot: plain (``fedavg``/``fedprox``/
    ``fedsubavg``) applies ``X += eta * update`` (sparse leaves via
    scatter-add, never densified); the stateful optimizers (``scaffold``/
    ``fedadam``) consume a dense mean delta — densified once at the server
    boundary on the sparse plane. The FedSubAvg correction ``N / n_m`` is
    applied iff ``algorithm == "fedsubavg"`` — fused into the sparse
    aggregation, broadcast onto dense leaves.
    """

    algorithm: str = "fedsubavg"

    def __post_init__(self):
        if self.algorithm not in PLAN_ALGORITHMS:
            raise ValueError(
                f"unknown server algorithm {self.algorithm!r}: expected one "
                f"of {PLAN_ALGORITHMS}")

    @property
    def correct(self) -> bool:
        return self.algorithm == "fedsubavg"

    @property
    def stateless(self) -> bool:
        return self.algorithm in ("fedavg", "fedprox", "fedsubavg")


@dataclass(frozen=True)
class CohortSharding:
    """Shard one round's cohort axis over a device mesh (FedAvg-style rounds
    are embarrassingly parallel over clients until the union segment-sum).

    ``mesh``/``axis`` name the data-parallel mesh axis the cohort is split
    over; ``build_round_step`` wraps the local phase in ``shard_map`` so each
    device shard runs its K/dev clients' local steps and a *per-shard*
    partial aggregation, then a cross-device combine produces the global
    update before the (replicated, identical-on-all-shards) server apply.

    ``combine`` picks the sparse-plane cross-shard reduction: ``"psum"``
    (densify + all-reduce, small tables), ``"union"`` (all-gather the shard
    unions, second RowSparse segment-sum, large tables) or ``"auto"``
    (byte-budget heuristic — see ``repro.sparse.aggregate.pick_combine``).
    """

    mesh: jax.sharding.Mesh
    axis: str = "data"
    combine: str = "auto"

    def __post_init__(self):
        if self.axis not in self.mesh.axis_names:
            raise ValueError(
                f"CohortSharding axis {self.axis!r} not in mesh axes "
                f"{self.mesh.axis_names}")
        if self.combine not in ("auto", "psum", "union"):
            raise ValueError(
                f"unknown combine strategy {self.combine!r}: expected "
                "'auto', 'psum' or 'union'")

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])


@dataclass(frozen=True)
class RoundPlan:
    """One federated round as a composition of three orthogonal strategies.

    ``sharding`` is the optional fourth, orthogonal to all of them: a
    :class:`CohortSharding` runs the SAME plan multi-device by splitting the
    cohort over a mesh axis — every local/transport/server composition gains
    multi-device execution without changing its math (parity to 1e-5 against
    the single-device step, same RNG stream).
    """

    local: LocalStep
    transport: Transport
    server: ServerUpdate
    feature_keys: Tuple[str, ...] = ("tokens",)
    sharding: Optional[CohortSharding] = None
    #: emit in-jit RowSparse contract checks (checkify) at the plane
    #: boundaries. Off by default: the checks are simply not traced, so the
    #: compiled program is byte-identical to a plan without the flag. When
    #: on, the step must run through ``repro.analysis.sanitize.checked_jit``
    #: (``make_round_step`` / ``FederatedTrainer`` handle this) — a bare
    #: ``jax.jit`` over an emitting step raises at trace time.
    debug_checks: bool = False

    def describe(self) -> str:
        base = (f"{type(self.local).__name__} -> "
                f"{type(self.transport).__name__} -> "
                f"ServerUpdate({self.server.algorithm})")
        if self.sharding is not None:
            base += (f" [sharded x{self.sharding.num_shards} over "
                     f"'{self.sharding.axis}']")
        if self.debug_checks:
            base += " [debug_checks]"
        return base


# ---------------------------------------------------------------------------
# mode-string / config resolution (the two legacy dispatch systems, unified)
# ---------------------------------------------------------------------------


def resolve_plan(mode_or_plan, cfg: FedConfig, correct: bool = True,
                 feature_key: str = "tokens") -> RoundPlan:
    """Resolve a legacy ``make_round_step`` mode string into its RoundPlan.

    The four strings are thin aliases — each names the composition that
    reproduces the historical branch byte-for-byte. A RoundPlan passes
    through unchanged (so callers can hand either to ``make_round_step``),
    but then the plan is the whole truth: the string-mode knobs must not
    silently contradict it.
    """
    if isinstance(mode_or_plan, RoundPlan):
        plan = mode_or_plan
        if not correct and plan.server.correct:
            raise ValueError(
                "correct=False conflicts with an explicit RoundPlan whose "
                "ServerUpdate applies the heat correction — encode the "
                "choice in the plan (ServerUpdate('fedavg'), etc.)")
        if feature_key != "tokens" and feature_key not in plan.feature_keys:
            raise ValueError(
                f"feature_key={feature_key!r} conflicts with the explicit "
                f"RoundPlan's feature_keys={plan.feature_keys} — set it on "
                "the plan")
        return plan
    server = ServerUpdate("fedsubavg" if correct else "fedavg")
    fk = (feature_key,)
    if mode_or_plan == "fedsgd":
        return RoundPlan(FedSgdLocal(max(cfg.microbatches, 1)),
                         DenseTransport(), server, fk)
    if mode_or_plan == "sparse":
        if cfg.microbatches > 1:
            raise ValueError(
                "mode='sparse' composes with microbatches=1: the sparse "
                "plane computes one fused cohort gradient per round")
        return RoundPlan(FedSgdLocal(), RowSparseTransport(), server, fk)
    if mode_or_plan == "replicated":
        return RoundPlan(ReplicatedLocal(), DenseTransport(), server, fk)
    if mode_or_plan == "sparse_replicated":
        return RoundPlan(SubmodelReplicatedLocal(), RowSparseTransport(),
                         server, fk)
    raise ValueError(mode_or_plan)


def plan_from_config(cfg: FedConfig, feature_keys: Tuple[str, ...] = ("tokens",),
                     gatherable: bool = True) -> RoundPlan:
    """Resolve ``FedConfig`` flags into the RoundPlan the trainer executes.

    ``gatherable``: whether the model's axis-0 feature tables span the
    dataset's id space (the precondition for submodel replicas) — decides
    the ``sparse_local="auto"`` branch.
    """
    if cfg.algorithm == "central":
        raise ValueError("central training is not a federated round plan")
    server = ServerUpdate(cfg.algorithm)
    if not cfg.sparse:
        return RoundPlan(ReplicatedLocal(), DenseTransport(), server,
                         tuple(feature_keys))
    mode = cfg.sparse_local
    if mode == "auto":
        mode = "sparse_replicated" if gatherable else "replicated"
    local = (SubmodelReplicatedLocal() if mode == "sparse_replicated"
             else ReplicatedLocal())
    transport = RowSparseTransport(topk=cfg.sparse_topk, int8=cfg.sparse_int8)
    return RoundPlan(local, transport, server, tuple(feature_keys))


def plan_comm_meta(boxed_params) -> CommMeta:
    """Static comm geometry of a model for ``Transport.round_comm``."""
    spec = heat_spec_from_axes(boxed_params)
    paths = {p for p, _ in sparse_table_paths(spec)}
    return model_comm_meta(unbox(boxed_params), paths)


def round_collective_budget(plan: "RoundPlan", boxed_params_template,
                            cfg: FedConfig, batch: Dict, *,
                            sub_ids=None) -> Dict:
    """Analytic per-collective budget of one cohort-sharded round step.

    Mirrors, term by term, the collectives ``build_round_step``'s shard
    bodies emit — so ``analysis.hlo_audit.collective_contract`` can compare
    the compiled HLO's inventory against what the plan PROMISED, and any
    extra kind or byte (an XLA resharding all-gather, an accidentally
    densified combine) is a contract violation, not noise.

    Per-device bytes, telemetry-off steps only (telemetry's host-side
    drop-stat assembly reshards the per-device id stacks in ways no static
    budget predicts; the oracle lowers steps with ``telemetry=False``).
    Payloads are priced as f32 (the update-tree dtype) and ids as s32.

    The budget's terms per path:

    - stacked locals (``ReplicatedLocal``/``SubmodelReplicatedLocal``):
      loss psum (4 B) + sparse ``sub_rows`` psum (4 B) + dense-leaf psums
      (non-table leaves, or the whole densified tree on a dense transport)
      + the per-table combine: ``pick_combine`` decides psum (all-reduce of
      the densified (V, E_t) f32 partial) vs union (all-gather of the
      partial's ``min(V, K/ndev * cap_client)`` ids + rows).
    - flat local (``FedSgdLocal`` sparse): loss pmean + dense-leaf pmeans
      + the single-table combine on the round-union capacity + the extra
      ``used_ids`` all-gather that computes the cross-shard union count.

    Returns ``{"axis", "num_shards", "vocab", "stacked", "combine":
    {table: mode}, "capacity": {table: per-shard partial capacity},
    "components": {name: {"op", "bytes"}}, "by_op", "allowed_ops"}``.
    """
    sharding = plan.sharding
    if sharding is None:
        raise ValueError("round_collective_budget prices the cross-shard "
                         "combine: the plan has no CohortSharding")
    local, transport, server = plan.local, plan.transport, plan.server
    sparse = transport.sparse
    ndev = sharding.num_shards
    feature_keys = tuple(plan.feature_keys)
    heat_spec = heat_spec_from_axes(boxed_params_template)
    table_paths = [p for p, _ in sparse_table_paths(heat_spec)]
    plain = unbox(boxed_params_template)
    vocabs = sorted({int(tree_leaf_at(plain, p).shape[0])
                     for p in table_paths})
    vocab = vocabs[-1] if vocabs else 0
    _, data = split_heat_batch(batch)

    tables = []  # (name, vocab_t, row_elems_t)
    for p in table_paths:
        leaf = tree_leaf_at(plain, p)
        tables.append(("/".join(str(k) for k in p),
                       int(leaf.shape[0]),
                       max(int(np.prod(leaf.shape[1:])), 1)))
    static_f32 = sum(
        float(np.prod(leaf.shape))
        for path, leaf in jax.tree_util.tree_flatten_with_path(plain)[0]
        if tree_path_keys(path) not in set(table_paths)) * 4.0

    components: Dict[str, Dict] = {}
    combine_modes: Dict[str, str] = {}
    capacities: Dict[str, int] = {}

    def add(name, op, nbytes):
        if nbytes > 0:
            components[name] = {"op": op, "bytes": float(nbytes)}

    add("loss", "all-reduce", 4.0)
    if local.stacked:
        k_real = int(data[feature_keys[0]].shape[0])
        k_shard = -(-k_real // ndev)
        if sparse:
            add("sub_rows", "all-reduce", 4.0)
            add("dense_leaves", "all-reduce", static_f32)
            if sub_ids is not None:
                cap_client = int(sub_ids.shape[-1])
            else:
                feats = sum(int(np.prod(data[k].shape[1:]))
                            for k in feature_keys)
                cap_client = round_capacity(vocab, feats)
            for name, v_t, elems_t in tables:
                mode = pick_combine(v_t, elems_t, sharding.combine)
                combine_modes[name] = mode
                cap_part = min(v_t, k_shard * cap_client)
                capacities[name] = cap_part
                if mode == "psum":
                    add(f"combine:{name}", "all-reduce",
                        float(v_t) * elems_t * 4.0)
                else:
                    add(f"combine:{name}", "all-gather",
                        float(ndev) * cap_part * (4.0 + elems_t * 4.0))
        else:
            # dense transport: every leaf (densified for submodel replicas)
            # rides one psum of its f32 shard-mean
            add("dense_tree", "all-reduce", sum(
                float(np.prod(leaf.shape)) * 4.0
                for leaf in jax.tree.leaves(plain)))
    else:
        # flat pooled batch (FedSgdLocal)
        if sparse:
            add("dense_leaves", "all-reduce", static_f32)
            if sub_ids is not None:
                cap = int(sub_ids.shape[-1])
            else:
                ids_size = sum(int(np.prod(data[k].shape)) // ndev
                               for k in feature_keys)
                cap = round_capacity(vocab, ids_size)
            name, v_t, elems_t = tables[0]
            mode = pick_combine(v_t, elems_t, sharding.combine)
            combine_modes[name] = mode
            capacities[name] = cap
            if mode == "psum":
                add(f"combine:{name}", "all-reduce",
                    float(v_t) * elems_t * 4.0)
            else:
                add(f"combine:{name}", "all-gather",
                    float(ndev) * cap * (4.0 + elems_t * 4.0))
            # the cross-shard union count gathers every shard's used_ids
            add("used_ids", "all-gather", float(ndev) * cap * 4.0)
        else:
            add("dense_tree", "all-reduce", sum(
                float(np.prod(leaf.shape)) * 4.0
                for leaf in jax.tree.leaves(plain)))

    by_op: Dict[str, float] = {}
    for c in components.values():
        by_op[c["op"]] = by_op.get(c["op"], 0.0) + c["bytes"]
    return {
        "axis": sharding.axis, "num_shards": ndev, "vocab": vocab,
        "stacked": bool(local.stacked), "combine": combine_modes,
        "capacity": capacities, "components": components, "by_op": by_op,
        "allowed_ops": sorted(by_op),
    }


# ---------------------------------------------------------------------------
# the compiler: plan -> jitted round step
# ---------------------------------------------------------------------------


def _scale_tree_f32(tree, s: float):
    """``s * tree`` in float32, RowSparse-aware (the sparse-plane scaling)."""

    def f(leaf):
        if is_rowsparse(leaf):
            return RowSparse(leaf.ids, leaf.rows.astype(jnp.float32) * s,
                             leaf.num_rows)
        return leaf.astype(jnp.float32) * s

    return jax.tree.map(f, tree, is_leaf=is_rowsparse)


def _densify_stacked(tree):
    """Scatter per-client RowSparse leaves ``(K, R)`` back to dense ``(K, V)``."""
    return jax.tree.map(
        lambda l: jax.vmap(RowSparse.to_dense)(l) if is_rowsparse(l) else l,
        tree, is_leaf=is_rowsparse)


def _apply_plain(plain_params, update, eta: float):
    """``X += eta * update`` leaf-wise, RowSparse leaves via scatter-add."""

    def ap(p, u):
        if is_rowsparse(u):
            return apply_rowsparse(p, u, eta)
        return p + (u * eta).astype(p.dtype)

    return jax.tree.map(ap, plain_params, update)


def build_round_step(plan: RoundPlan, loss_fn: Callable, boxed_params_template,
                     cfg: FedConfig, *, heat_counts: Optional[Dict] = None,
                     total: Optional[float] = None,
                     server_alg=None, telemetry: bool = False) -> Callable:
    """Compile a :class:`RoundPlan` into the single jittable round step.

    ``step(state, batch, sub_ids=None) -> (new_state, metrics)`` over a
    ``ServerState``. ``batch`` carries the cohort data — flat ``(B, ...)``
    for :class:`FedSgdLocal`, ``(K, I, B, ...)`` for the replicated locals —
    plus, on the simulation entry point, the ``heat_*`` vectors.

    ``heat_counts``/``total``: bake the heat statistics statically (the
    trainer path); when omitted, counts are read from the batch's ``heat_*``
    entries and ``total = cfg.num_clients`` (the simulation path).
    ``sub_ids``: per-client submodel ids ``(K, capacity)`` (or the flat
    union ``(capacity,)``); derived in-step from the batch's feature keys
    when ``None``. ``server_alg``: pass an existing ``ServerAlgorithm`` so
    the trainer's step applies through the exact object it initialised;
    built on demand otherwise.

    ``metrics`` always carries ``"loss"``; sparse transports add
    ``"sub_rows"`` and ``"density"``. ``telemetry=True`` additionally puts a
    :class:`repro.telemetry.round.RoundTelemetry` pytree under
    ``metrics["telemetry"]`` — computed in-jit from values the step already
    produces (no extra PRNG draws, no change to losses or parameters), so it
    stacks along the scan axis under a multi-round ``lax.scan`` engine and
    crosses ``shard_map`` boundaries via psums/all-gathers.
    """
    local, transport, server = plan.local, plan.transport, plan.server
    feature_keys = tuple(plan.feature_keys)
    heat_spec = heat_spec_from_axes(boxed_params_template)
    n_total = float(cfg.num_clients if total is None else total)
    eta = cfg.server_lr
    sparse = transport.sparse
    static_heat = heat_counts is not None
    debug = bool(plan.debug_checks) and sparse  # dense plans: nothing to check

    # ---- static metadata + build-time validation --------------------------
    paths = sparse_table_paths(heat_spec)
    table_paths = [p for p, _ in paths]
    plain_template = unbox(boxed_params_template)
    vocabs = sorted({int(tree_leaf_at(plain_template, p).shape[0])
                     for p in table_paths})
    vocab = vocabs[-1] if vocabs else 0
    if isinstance(local, SubmodelReplicatedLocal):
        if not table_paths:
            raise ValueError(
                "submodel-replica local training needs at least one axis-0 "
                "feature table")
        if len(vocabs) != 1:
            # one shared feature-id space is what lets a single per-client
            # sub_ids vector cover every table's gradient support
            raise ValueError(
                f"submodel-replica feature tables disagree on vocab: {vocabs}")
    if isinstance(local, FedSgdLocal) and not sparse:
        if max(local.microbatches, 1) != max(cfg.microbatches, 1):
            raise ValueError(
                f"cfg.microbatches={cfg.microbatches} conflicts with "
                f"FedSgdLocal(microbatches={local.microbatches}): an "
                "explicit plan owns the knob — set it on the plan")
    if sparse and isinstance(local, FedSgdLocal):
        if max(local.microbatches, 1) > 1 or cfg.microbatches > 1:
            raise ValueError(
                "FedSgdLocal on the sparse transport computes one fused "
                "cohort gradient: microbatches must be 1")
        if len(table_paths) != 1:
            # one table <-> one feature-id union is what keeps this path
            # exact: with several tables a single batch union could not
            # cover every table's gradient support (the replicated locals
            # carry per-client sub_ids and handle multi-table models)
            raise ValueError(
                f"FedSgdLocal sparse mode supports exactly one axis-0 "
                f"feature table, found {len(table_paths)}: {table_paths}")
    if not server.stateless and server_alg is None:
        acfg = dataclasses.replace(cfg, algorithm=server.algorithm)
        server_alg = make_server_algorithm(acfg)
    if server.stateless and not sparse and static_heat and server_alg is None:
        # dense transport with baked heat: the ServerAlgorithm owns the
        # correction (exactly the trainer's historical apply)
        acfg = dataclasses.replace(cfg, algorithm=server.algorithm)
        server_alg = make_server_algorithm(acfg, heat_spec=heat_spec,
                                           heat_counts=heat_counts,
                                           total=n_total)
    base_key = jax.random.PRNGKey(cfg.seed + 17)  # int8 stochastic rounding

    # ---- shared sub-plumbing ---------------------------------------------
    def batch_counts(heat: Dict) -> Dict:
        if static_heat:
            return heat_counts
        return {k[len("heat_"):]: v for k, v in heat.items()}

    def derive_flat_ids(data: Dict) -> Array:
        ids_size = sum(int(np.prod(data[k].shape)) for k in feature_keys)
        capacity = round_capacity(vocab, ids_size)
        if debug:
            sanitize.check_capacity(capacity, vocab)
        return batch_union_ids(data, feature_keys, capacity)

    def derive_cohort_ids(data: Dict) -> Array:
        feats = stacked_feature_ids(data, feature_keys)
        capacity = round_capacity(vocab, feats.shape[1])
        if debug:
            sanitize.check_capacity(capacity, vocab)
        return jax.vmap(lambda f: unique_ids_padded(f, capacity))(feats)

    def require_tables_for_ids():
        if not table_paths or len(vocabs) != 1:
            raise ValueError(
                "in-step sub-id derivation needs feature tables sharing one "
                f"axis-0 id space; found row counts {vocabs} — pass sub_ids "
                "explicitly (as FederatedTrainer does)")

    # ---- debug sanitizer (plan.debug_checks; checkify, compiled away
    # entirely when off) ----------------------------------------------------
    def _debug_check_ids(used_ids: Optional[Array], data: Dict) -> None:
        """Validate the round's sub-id unions against the RowSparse contract.

        Flat ids additionally get the largest-first drop-order check against
        the batch's own tokens; cohort ``(K, R)`` ids check it per client
        (checkify composes with vmap).
        """
        if not debug or used_ids is None or not vocab:
            return
        sanitize.check_union_ids(used_ids, vocab, name="sub_ids")
        if used_ids.ndim == 1:
            for k in feature_keys:
                sanitize.check_drop_order(used_ids, data[k], name="sub_ids")
        else:
            feats = stacked_feature_ids(data, feature_keys)

            def one(ids_row, feats_row):
                sanitize.check_drop_order(ids_row, feats_row, name="sub_ids")
                return jnp.zeros((), jnp.int32)

            jax.vmap(one)(used_ids, feats)

    def _debug_check_agg(agg) -> None:
        """Validate every aggregated RowSparse leaf at the server boundary."""
        if not debug:
            return
        for leaf in jax.tree.leaves(agg, is_leaf=is_rowsparse):
            if is_rowsparse(leaf):
                sanitize.check_rowsparse(leaf, name="agg")

    # ---- telemetry (in-jit observability; pure reads of existing values) --
    heat_space = paths[0][1][0] if paths else None

    def _cohort_drop_tel(data: Dict, used_ids: Optional[Array]):
        """``(union ids, dropped, mass, per_client)`` from the round's ids.

        ``used_ids`` is what the step actually consumed: the per-client
        ``(K, R)`` sub-id stack or the flat ``(R,)`` cohort union. Drops are
        priced against the raw batch feature ids — exactly what
        ``unique_ids_padded``'s capacity contract silently discarded.
        """
        zi, zf = jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32)
        if not (sparse and vocab) or used_ids is None:
            return None, zi, zf, None
        if used_ids.ndim == 2:
            feats = stacked_feature_ids(data, feature_keys)
            d_pc, m_pc = drop_stats(feats, used_ids, vocab)
            return (union_ids_vec(used_ids, vocab),
                    d_pc.sum(dtype=jnp.int32), m_pc.sum(),
                    d_pc.astype(jnp.int32))
        dropped, mass = drop_stats(flat_feature_ids(data, feature_keys),
                                   used_ids, vocab)
        return used_ids, dropped.astype(jnp.int32), mass, None

    def _assemble_tel(union, dropped, mass, per_client, agg, counts,
                      pre_sq, post_sq, shard_union_sizes=None):
        union_size = ((union >= 0).sum(dtype=jnp.int32)
                      if union is not None else jnp.zeros((), jnp.int32))
        hv = counts.get(heat_space) if (counts and heat_space) else None
        hist = (heat_histogram(hv, union)
                if union is not None and hv is not None
                else jnp.zeros((HEAT_BUCKETS,), jnp.float32))
        dens = (union_size.astype(jnp.float32) / vocab if vocab
                else jnp.zeros((), jnp.float32))
        return RoundTelemetry(
            dropped_ids=dropped, dropped_mass=mass,
            dropped_per_client=per_client, union_size=union_size,
            agg_rows=tree_agg_rows(agg) if (sparse and agg is not None)
            else None,
            shard_union_sizes=shard_union_sizes,
            delta_norm_pre=jnp.sqrt(pre_sq),
            delta_norm_post=jnp.sqrt(post_sq),
            heat_hist=hist, density=dens)

    # ---- local step -------------------------------------------------------
    # run_local(params, data, sub_ids) -> (update, forward_loss|None,
    #                                      used_ids|None, data)
    if isinstance(local, FedSgdLocal):
        if sparse:
            table_path = table_paths[0]

            def run_local(params, data, sub_ids):
                data = pin_labels(data, feature_keys[0])
                if sub_ids is None:
                    require_tables_for_ids()
                    sub_ids = derive_flat_ids(data)
                loss, grads = submodel_value_and_grad(
                    loss_fn, params, data, table_path, feature_keys, sub_ids)
                update = _scale_tree_f32(unbox(grads), -cfg.lr)
                return update, loss, sub_ids, data
        else:
            nmb = max(local.microbatches, 1)

            def run_local(params, data, sub_ids):
                if nmb == 1:
                    loss, grads = jax.value_and_grad(loss_fn)(params, data)
                else:
                    # gradient accumulation: cohort split into microbatches
                    # so the live activation set stays within HBM at pod
                    # scale. The batch axis is keyed on the entry NAME: only
                    # "mrope_pos" carries a leading (3,) coordinate axis with
                    # batch on axis 1 — keying on shape would misroute any
                    # genuine batch-size-3 entry.
                    def split(k, x):
                        if x.ndim == 0:
                            return x
                        axis = 1 if k == "mrope_pos" else 0   # mrope (3,B,S)
                        b = x.shape[axis]
                        assert b % nmb == 0, (x.shape, nmb)
                        xs = jnp.moveaxis(x, axis, 0).reshape(
                            (nmb, b // nmb) + x.shape[:axis]
                            + x.shape[axis + 1:])
                        return xs

                    # mrope needs its leading 3-axis restored per microbatch
                    def restore(k, x):
                        if k == "mrope_pos":
                            return jnp.moveaxis(x, 1, 0)
                        return x

                    mb = {k: split(k, v) for k, v in data.items()}

                    def acc_step(carry, mbatch):
                        g_acc, l_acc = carry
                        mbatch = {k: restore(k, v) for k, v in mbatch.items()}
                        l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                        g32 = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                           g_acc, g)
                        return (g32, l_acc + l), None

                    g0 = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32),
                        jax.tree.map(lambda x: x, params))
                    (gsum, lsum), _ = jax.lax.scan(
                        acc_step, (g0, jnp.zeros((), jnp.float32)), mb)
                    grads = tree_scale(gsum, 1.0 / nmb)
                    loss = lsum / nmb
                update = tree_scale(grads, -cfg.lr)
                return update, loss, None, data

    elif isinstance(local, ReplicatedLocal):
        local_train = make_local_trainer(loss_fn, cfg, prox_mu=local.prox_mu)

        def run_local(params, data, sub_ids):
            deltas = cohort_deltas(local_train, params, data)
            if sparse:
                if sub_ids is None:
                    require_tables_for_ids()
                    sub_ids = derive_cohort_ids(data)
                deltas = encode_delta_tree(deltas, heat_spec, sub_ids)
            return deltas, None, sub_ids, data

    elif isinstance(local, SubmodelReplicatedLocal):
        local_train = make_submodel_local_trainer(
            loss_fn, cfg, table_paths, feature_keys, prox_mu=local.prox_mu)

        def run_local(params, data, sub_ids):
            data = pin_labels(data, feature_keys[0])
            if sub_ids is None:
                sub_ids = derive_cohort_ids(data)
            deltas = cohort_submodel_deltas(local_train, params, data, sub_ids)
            return deltas, None, sub_ids, data

    else:
        raise TypeError(f"unknown LocalStep: {local!r}")

    # ---- server apply (shared by the single-device and sharded paths) -----
    def apply_sparse(state, agg):
        """Apply an aggregated sparse-plane update (RowSparse or dense leaves,
        correction already fused)."""
        _debug_check_agg(agg)
        if server.stateless:
            plain = unbox(state.params)
            new_plain = _apply_plain(plain, agg, eta)
            return ServerState(boxed_like(new_plain, state.params),
                               state.opt, state.rounds + 1)
        # stateful server optimizers consume the dense mean delta;
        # densify once at the server boundary
        dense = boxed_like(decode_delta_tree(agg), state.params)
        return server_alg.apply(state, dense)

    def apply_dense(state, update, counts):
        """Apply a dense-transport cohort-mean update (correction pending)."""
        if server_alg is not None:
            return server_alg.apply(state, update)
        corrected = (correct_update_tree(update, heat_spec, counts, n_total)
                     if server.correct else update)
        # cast back to each param's dtype before the add: the microbatch
        # accumulator is f32, and bf16 params must not come back silently
        # promoted
        new_params = jax.tree.map(
            lambda p, c: p + c.astype(p.dtype) * eta, state.params, corrected)
        return ServerState(new_params, state.opt, state.rounds + 1)

    # ---- cohort-sharded execution (plan.sharding) -------------------------
    sharding = plan.sharding
    if sharding is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh, s_axis = sharding.mesh, sharding.axis
        ndev = sharding.num_shards
        if sparse and transport.int8:
            raise ValueError(
                "CohortSharding does not compose with int8 transport yet: "
                "the stochastic-rounding noise is drawn over the full cohort "
                "stack and would not reproduce the single-device stream "
                "per shard")
        if sparse and transport.topk and isinstance(local, FedSgdLocal):
            raise ValueError(
                "CohortSharding does not compose with top-k on the flat "
                "fused-gradient sparse path: top-k there selects rows of the "
                "whole-cohort union, which no per-shard selection reproduces "
                "— use a replicated local (per-client top-k shards exactly)")

        def _mask_clients(tree, wmask):
            """Zero padded clients' contributions (RowSparse-aware)."""

            def m(leaf):
                if is_rowsparse(leaf):
                    w = wmask.reshape((-1,) + (1,) * (leaf.rows.ndim - 1))
                    return RowSparse(leaf.ids,
                                     leaf.rows * w.astype(leaf.rows.dtype),
                                     leaf.num_rows)
                return leaf * wmask.reshape(
                    (-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)

            return jax.tree.map(m, tree, is_leaf=is_rowsparse)

        def _stacked_shard_body(params, data, sub_ids, wmask, counts,
                                k_real: int):
            """One shard's K/ndev clients: local steps, per-shard partial
            aggregation, cross-shard combine. Returns the REPLICATED global
            aggregate (identical on every shard) + loss / sub-row stats."""
            update, _, used_ids, data = run_local(params, data, sub_ids)
            _debug_check_ids(used_ids, data)  # checkify crosses shard_map
            raw = update
            if sparse and transport.topk:
                # per-client row selection shards exactly (no cohort state)
                update = compress_delta_tree(update, topk=transport.topk)
            update = _mask_clients(update, wmask)
            scale = 1.0 / float(k_real)

            if sparse:
                def agg_leaf(leaf, space):
                    if is_rowsparse(leaf):
                        h = (counts.get(space[0])
                             if server.correct and space is not None else None)
                        part = aggregate_rowsparse_partial(
                            leaf, union_backend=transport.union_backend)
                        return combine_rowsparse_partials(
                            part, s_axis, ndev, h, n_total, scale,
                            combine=sharding.combine,
                            union_backend=transport.union_backend)
                    mean = jax.lax.psum(leaf.sum(axis=0), s_axis) * scale
                    if server.correct:
                        mean = correct_dense_leaf(mean, space, counts, n_total)
                    return mean

                agg = jax.tree.map(
                    agg_leaf, update, heat_spec.leaf_spaces,
                    is_leaf=lambda x: x is None or is_rowsparse(x))
            else:
                if isinstance(local, SubmodelReplicatedLocal):
                    update = _densify_stacked(update)
                agg = jax.tree.map(
                    lambda d: jax.lax.psum(d.sum(axis=0), s_axis) * scale,
                    update)

            first = jax.tree.map(lambda x: x[:, 0], data)
            losses = jax.vmap(lambda b: loss_fn(params, b))(first)
            loss = jax.lax.psum((losses * wmask).sum(), s_axis) / k_real
            if sparse and used_ids is not None:
                valid = (used_ids >= 0) & (wmask > 0)[:, None]
                sub_rows = jax.lax.psum(valid.sum(), s_axis)
            else:
                sub_rows = jnp.zeros((), jnp.int32)
            if not telemetry:
                return agg, loss, sub_rows
            # pre/post-compression norms over the REAL clients only (pad
            # clients are cyclic repeats; masking keeps them out of both)
            pre_sq = jax.lax.psum(tree_sq_sum(_mask_clients(raw, wmask)),
                                  s_axis)
            post_sq = jax.lax.psum(tree_sq_sum(update), s_axis)
            tel = {"norm_pre_sq": pre_sq, "norm_post_sq": post_sq}
            if sparse:
                masked = jnp.where((wmask > 0)[:, None], used_ids, -1)
                tel["used_ids"] = masked
                tel["shard_union"] = count_unique_ids(masked)[None]
            return agg, loss, sub_rows, tel

        def _flat_shard_body(params, data, sub_ids, counts):
            """One shard's B/ndev examples of the pooled cohort batch.

            Exactness contract (the standard data-parallel one): ``loss_fn``
            is a uniform mean over the batch axis, so the cohort gradient is
            the mean of equal-size shard gradients. A caller-provided
            ``sub_ids`` union is replicated to every shard (each shard's
            gradient support is a subset of it), exactly as the
            single-device step consumes it.
            """
            update, fwd_loss, used_ids, _ = run_local(params, data, sub_ids)
            _debug_check_ids(used_ids, data)  # checkify crosses shard_map
            loss = jax.lax.pmean(fwd_loss, s_axis)
            scale = 1.0 / float(ndev)
            if sparse:
                def fix(leaf, space):
                    if is_rowsparse(leaf):
                        h = (counts.get(space[0])
                             if server.correct and space is not None else None)
                        return combine_rowsparse_partials(
                            leaf, s_axis, ndev, h, n_total, scale,
                            combine=sharding.combine,
                            union_backend=transport.union_backend)
                    leaf = jax.lax.pmean(leaf, s_axis)
                    if server.correct:
                        leaf = correct_dense_leaf(leaf, space, counts, n_total)
                    return leaf

                agg = jax.tree.map(
                    fix, update, heat_spec.leaf_spaces,
                    is_leaf=lambda x: x is None or is_rowsparse(x))
                # the single-device union count: distinct ids across shards
                sub_rows = count_unique_ids(
                    jax.lax.all_gather(used_ids, s_axis))
                out = (agg, loss, sub_rows)
            else:
                update = jax.tree.map(lambda g: jax.lax.pmean(g, s_axis),
                                      update)
                out = (update, loss, jnp.zeros((), jnp.int32))
            if not telemetry:
                return out
            # the flat path never compresses under sharding (topk/int8 are
            # rejected combos above), so pre == post: the L2 of the combined
            # replicated aggregate is the honest per-round figure here
            sq = tree_sq_sum(out[0])
            tel = {"norm_pre_sq": sq, "norm_post_sq": sq}
            if sparse:
                tel["used_ids"] = used_ids[None]
                # used_ids is already the cross-shard union (gathered above);
                # out_spec P(axis) reassembles one count per device
                # repro-lint: ok shard-missing-psum -- deliberately per-shard count of the already-gathered union
                tel["shard_union"] = (used_ids >= 0).sum(
                    dtype=jnp.int32)[None]
            return out + (tel,)

        def _shard_out_specs():
            """out_specs of a shard body: (agg, loss, sub_rows[, telemetry]).

            Telemetry parts: psum'd norms are replicated (``P()``); the
            per-shard union size and the shard's used sub-ids keep their
            shard axis (``P(s_axis)``) so the host sees one value per device
            and the full reassembled id stack.
            """
            base = (P(), P(), P())
            if not telemetry:
                return base
            tspec = {"norm_pre_sq": P(), "norm_post_sq": P()}
            if sparse:
                tspec["used_ids"] = P(s_axis)
                tspec["shard_union"] = P(s_axis)
            return base + (tspec,)

        def sharded_cohort_update(params, data, counts, sub_ids):
            """Wrap the shard body in shard_map over the cohort axis.

            Stacked locals shard (and, for non-divisible cohorts, pad + mask)
            the client axis; flat locals shard the pooled batch axis. The
            returned aggregate is replicated — bitwise identical on every
            shard — so the server apply that follows needs no resharding.
            Returns ``(agg, loss, sub_rows, k_real, tel)`` with ``tel`` the
            shard-body telemetry parts (``None`` when telemetry is off).
            """
            ospecs = _shard_out_specs()
            if local.stacked:
                k_real = data[feature_keys[0]].shape[0]
                kp = -(-k_real // ndev) * ndev
                wmask = (jnp.arange(kp) < k_real).astype(jnp.float32)
                if kp != k_real:
                    # shard-major padding: repeat clients cyclically so every
                    # pad slot computes finite values, then mask them out of
                    # every reduction (scale stays 1/k_real)
                    idx = jnp.arange(kp) % k_real
                    data = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                        data)
                    if sub_ids is not None:
                        sub_ids = jnp.take(sub_ids, idx, axis=0)
                dspec = jax.tree.map(lambda _: P(s_axis), data)

                def body(p, d, si, w, c):
                    return _stacked_shard_body(p, d, si, w, c, k_real)

                if sub_ids is None:
                    fn = shard_map(
                        lambda p, d, w, c: body(p, d, None, w, c), mesh=mesh,
                        in_specs=(P(), dspec, P(s_axis), P()),
                        out_specs=ospecs, check_rep=False)
                    res = fn(params, data, wmask, counts)
                else:
                    fn = shard_map(
                        body, mesh=mesh,
                        in_specs=(P(), dspec, P(s_axis), P(s_axis), P()),
                        out_specs=ospecs, check_rep=False)
                    res = fn(params, data, sub_ids, wmask, counts)
                agg, loss, sub_rows = res[:3]
                return agg, loss, sub_rows, k_real, (res[3] if telemetry
                                                     else None)
            # flat pooled batch: shard the example axis
            bleaf = (feature_keys[0] if feature_keys[0] in data
                     else next(iter(data)))
            bsz = data[bleaf].shape[0]
            if bsz % ndev:
                raise ValueError(
                    f"flat cohort batch of {bsz} examples does not divide "
                    f"over {ndev} shards: pad the batch to a multiple of the "
                    "mesh axis, or use a replicated local (which pads and "
                    "masks per-client automatically)")
            nmb = max(getattr(local, "microbatches", 1), 1)
            if nmb > 1 and (bsz // ndev) % nmb:
                raise ValueError(
                    f"per-shard batch of {bsz // ndev} examples (batch {bsz} "
                    f"over {ndev} shards) does not divide into "
                    f"{nmb} microbatches — each shard runs its own gradient "
                    "accumulation, so B must be a multiple of ndev * "
                    "microbatches")

            def fspec(k, x):
                if getattr(x, "ndim", 0) == 0:
                    return P()
                # mrope carries a leading (3,) coordinate axis; batch on 1
                return P(None, s_axis) if k == "mrope_pos" else P(s_axis)

            dspec = {k: fspec(k, v) for k, v in data.items()}
            if sub_ids is None:
                fn = shard_map(
                    lambda p, d, c: _flat_shard_body(p, d, None, c),
                    mesh=mesh, in_specs=(P(), dspec, P()),
                    out_specs=ospecs, check_rep=False)
                res = fn(params, data, counts)
            else:
                fn = shard_map(_flat_shard_body, mesh=mesh,
                               in_specs=(P(), dspec, P(), P()),
                               out_specs=ospecs, check_rep=False)
                res = fn(params, data, sub_ids, counts)
            agg, loss, sub_rows = res[:3]
            return agg, loss, sub_rows, None, (res[3] if telemetry else None)

        def sharded_step(state: ServerState, batch: Dict,
                         sub_ids: Optional[Array] = None):
            params = state.params
            heat, data = split_heat_batch(batch)
            counts = batch_counts(heat)
            agg, loss, sub_rows, k_real, tel = sharded_cohort_update(
                params, data, counts, sub_ids)
            agg_tree = agg if sparse else None
            if sparse:
                new_state = apply_sparse(state, agg)
            else:
                if local.stacked and isinstance(local,
                                                SubmodelReplicatedLocal):
                    agg = boxed_like(agg, params)
                new_state = apply_dense(state, agg, counts)
            metrics = {"loss": loss}
            if sparse and vocab:
                denom = vocab if k_real is None else k_real * vocab
                metrics["sub_rows"] = sub_rows
                metrics["density"] = sub_rows / denom
            if telemetry:
                used = None
                if sparse and vocab:
                    u = tel["used_ids"]
                    # stacked: pad clients sit at the END of the reassembled
                    # (kp, R) stack (cyclic-repeat padding), so [:k_real]
                    # recovers the real cohort. Flat: one per-shard id vector
                    # per device — their union is the cohort union.
                    used = (u[:k_real] if k_real is not None
                            else union_ids_vec(u, vocab))
                union, dropped, mass, per_client = _cohort_drop_tel(
                    data, used)
                metrics["telemetry"] = _assemble_tel(
                    union, dropped, mass, per_client, agg_tree, counts,
                    tel["norm_pre_sq"], tel["norm_post_sq"],
                    shard_union_sizes=tel.get("shard_union"))
            return new_state, metrics

        return sharded_step

    # ---- the step ---------------------------------------------------------
    def step(state: ServerState, batch: Dict, sub_ids: Optional[Array] = None):
        params = state.params
        heat, data = split_heat_batch(batch)
        counts = batch_counts(heat)
        update, fwd_loss, used_ids, data = run_local(params, data, sub_ids)
        _debug_check_ids(used_ids, data)
        pre_sq = tree_sq_sum(update) if telemetry else None

        agg_tree = None
        if sparse:
            if transport.topk or transport.int8:
                key = (jax.random.fold_in(base_key, state.rounds)
                       if transport.int8 else None)
                update = compress_delta_tree(update, topk=transport.topk,
                                             int8=transport.int8, key=key)
            post_sq = tree_sq_sum(update) if telemetry else None
            if local.stacked:
                k = data[feature_keys[0]].shape[0]
                agg = sparse_cohort_aggregate(
                    update, heat_spec, counts, n_total, k,
                    correct=server.correct,
                    union_backend=transport.union_backend)
            else:
                def fix(leaf, space):
                    if is_rowsparse(leaf):
                        h = (counts.get(space[0])
                             if server.correct and space is not None else None)
                        return correct_rowsparse(leaf, h, n_total)
                    if server.correct:
                        return correct_dense_leaf(leaf, space, counts, n_total)
                    return leaf

                agg = jax.tree.map(
                    fix, update, heat_spec.leaf_spaces,
                    is_leaf=lambda x: x is None or is_rowsparse(x))
            agg_tree = agg
            new_state = apply_sparse(state, agg)
        else:
            post_sq = pre_sq          # dense transport: no wire compression
            if isinstance(local, SubmodelReplicatedLocal):
                # submodel replicas against a dense server transport: the
                # born-sparse per-client deltas scatter back to dense stacks
                update = _densify_stacked(update)
            if local.stacked:
                update = jax.tree.map(lambda d: d.mean(axis=0), update)
                if isinstance(local, SubmodelReplicatedLocal):
                    update = boxed_like(update, params)
            new_state = apply_dense(state, update, counts)

        if local.stacked:
            first = jax.tree.map(lambda x: x[:, 0], data)
            loss = jax.vmap(lambda b: loss_fn(params, b))(first).mean()
        else:
            loss = fwd_loss
        metrics = {"loss": loss}
        if sparse and used_ids is not None and vocab:
            sub_rows = (used_ids >= 0).sum()
            denom = vocab if used_ids.ndim == 1 else used_ids.shape[0] * vocab
            metrics["sub_rows"] = sub_rows
            metrics["density"] = sub_rows / denom
        if telemetry:
            union, dropped, mass, per_client = _cohort_drop_tel(data, used_ids)
            metrics["telemetry"] = _assemble_tel(
                union, dropped, mass, per_client, agg_tree, counts,
                pre_sq, post_sq)
        return new_state, metrics

    return step
