"""Server orchestration of federated rounds (Algorithm 1, server process).

``FederatedTrainer`` runs the paper's full experimental protocol over a
``FederatedDataset``: samples K clients per round, dispatches local training,
aggregates deltas, applies the configured server algorithm, and tracks train
loss / test metrics. CentralSGD (the paper's non-federated reference) shares
the same interface.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_add, tree_path_keys, tree_scale
from repro.configs.base import FedConfig
from repro.core.aggregate import HeatSpec
from repro.core.algorithms import ServerState, make_server_algorithm
from repro.core.heat import HeatStats, estimate_heat_randomized_response
from repro.data.batching import pooled_batches, sample_cohort_batch
from repro.data.synthetic import FederatedDataset
from repro.federated.client import (cohort_deltas, cohort_submodel_deltas,
                                    make_local_trainer,
                                    make_submodel_local_trainer)
from repro.federated.metrics import accuracy, auc
from repro.federated.simulation import heat_spec_from_axes, sparse_table_paths
from repro.sharding.logical import boxed_like, unbox
from repro.sparse.aggregate import apply_rowsparse, sparse_cohort_aggregate
from repro.sparse.comm import CommStats, round_comm_stats
from repro.sparse.compress import (QuantRows, dequantize_rows,
                                   quantize_tree_int8, topk_rows)
from repro.sparse.encode import decode_delta_tree, encode_delta_tree
from repro.sparse.rowsparse import is_rowsparse


@dataclass
class RoundRecord:
    round: int
    train_loss: float
    test_metric: float
    # comm accounting (sparse mode; zeros on the dense path)
    bytes_up: float = 0.0            # cumulative sparse-plane uplink bytes
    bytes_down: float = 0.0          # cumulative sparse-plane downlink bytes
    density: float = 1.0             # mean per-client submodel density so far
    wall_time: float = 0.0           # mean seconds/round since the last record


# ---------------------------------------------------------------------------
# jitted sub-id derivation (the server engine's cohort preprocessing)
# ---------------------------------------------------------------------------


def pow2_capacity(max_count: int, floor: int = 8) -> int:
    """Smallest power-of-two >= max(max_count, floor).

    Sub-id capacities are bucketed to powers of two so the jitted round step
    compiles at most O(log V) distinct variants over a whole training run —
    the invariant must never be broken by clamping to a non-pow2 table size
    (a capacity slightly above V only adds padding slots, which every sparse
    consumer drops).
    """
    cap = floor
    while cap < max_count:
        cap *= 2
    return cap


@functools.partial(jax.jit, static_argnames=("num_features",))
def count_sub_ids(feats: jax.Array, num_features: int) -> jax.Array:
    """Per-client distinct-feature counts ``(K,)`` from stacked id leaves.

    ``feats``: ``(K, M)`` int feature ids, negatives are padding. The count
    is over distinct non-negative ids — the size of client k's submodel
    S(k), i.e. the number of valid slots ``derive_sub_ids`` will fill.
    """

    def one(flat):
        safe = jnp.where(flat >= 0, flat, num_features)
        mark = jnp.zeros((num_features,), bool).at[safe].set(True, mode="drop")
        return mark.sum(dtype=jnp.int32)

    return jax.vmap(one)(feats)


@functools.partial(jax.jit, static_argnames=("num_features", "capacity"))
def derive_sub_ids(feats: jax.Array, num_features: int,
                   capacity: int) -> jax.Array:
    """Per-client sorted unique feature ids ``(K, capacity)``, -1 padded.

    The jitted replacement for the trainer's former host-side per-client
    ``np.unique`` loops: mark each client's touched rows in a (V,) bitmap,
    rank the marks by cumsum, and scatter row indices to their rank — one
    fused vectorised program per (K, M, capacity) shape bucket instead of K
    numpy passes per round. ``capacity`` must come from ``pow2_capacity`` of
    ``count_sub_ids(...).max()`` so the jit cache stays O(log V).
    """

    def one(flat):
        safe = jnp.where(flat >= 0, flat, num_features)
        mark = jnp.zeros((num_features,), bool).at[safe].set(True, mode="drop")
        rank = jnp.cumsum(mark) - 1
        slot = jnp.where(mark, rank, capacity)          # unmarked -> dropped
        out = jnp.full((capacity,), -1, jnp.int32)
        return out.at[slot].set(jnp.arange(num_features, dtype=jnp.int32),
                                mode="drop")

    return jax.vmap(one)(feats)


class FederatedTrainer:
    """End-to-end federated training loop for the paper-scale models."""

    def __init__(self, ds: FederatedDataset, make_params: Callable,
                 loss_fn: Callable, cfg: FedConfig,
                 predict_fn: Optional[Callable] = None,
                 metric: str = "auc", rng_seed: int = 0):
        self.ds = ds
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.predict_fn = predict_fn
        self.metric = metric
        self.np_rng = np.random.default_rng(cfg.seed + rng_seed)

        params = make_params(rng=jax.random.PRNGKey(cfg.seed))
        self.heat = self._resolve_heat(ds, cfg)
        heat_spec = heat_spec_from_axes(params)
        heat_counts = {"vocab": jnp.asarray(self.heat.counts, jnp.float32)}
        total = self.heat.total
        self._heat_spec = heat_spec
        self._heat_counts = heat_counts
        self.alg = make_server_algorithm(cfg, heat_spec=heat_spec,
                                         heat_counts=heat_counts, total=total)
        self.state = self.alg.init(params)

        if cfg.algorithm == "central":
            self._central_step = jax.jit(self._make_central_step())
        elif cfg.sparse:
            # jit caches one trace per sub_ids capacity (kept to O(log V)
            # variants by pow2_capacity bucketing); ServerState buffers are
            # donated through the step so the table is updated in place
            self._prepare_sparse_plane(params)
            round_step = self._make_sparse_round_step()
            self._sparse_step = jax.jit(round_step, donate_argnums=(0,))

            def engine(state, cohorts, sub_ids):
                # multi-round driver: scan the round step over stacked
                # cohorts so dispatch overhead amortises across rounds
                return jax.lax.scan(lambda s, xs: round_step(s, *xs), state,
                                    (cohorts, sub_ids))

            self._sparse_engine = jax.jit(engine, donate_argnums=(0,))
        else:
            self._round_step = jax.jit(self._make_round_step())
        self.history: List[RoundRecord] = []
        self.comm_log: List[CommStats] = []
        self._rounds_run = 0
        self._last_capacity: Optional[int] = None   # last sparse sub-id bucket

    # ------------------------------------------------------------------
    def _resolve_heat(self, ds: FederatedDataset, cfg: FedConfig) -> HeatStats:
        """Heat statistics under the configured estimator (App. F) and, when
        ``weighted``, the App. D.4 per-client weighting — *composed with* the
        estimator: weighted randomized response stays private (the weighting
        is applied to the noisy reported bits, never to raw client data);
        exact and secure_agg are exact by construction, so their weighted
        variant aggregates ``w_c`` per involving client directly."""
        key = ds.feature_key

        def client_ids(c):
            ids = ds.client_data[key][c].reshape(-1)
            ids = ids[ids >= 0]
            if key == "hist" and "target" in ds.client_data:
                t = ds.client_data["target"][c].reshape(-1)
                ids = np.concatenate([ids, t[t >= 0]])
            return np.unique(ids)

        w = ds.sample_counts.astype(np.float64) if cfg.weighted else None
        if cfg.heat_estimator == "randomized_response":
            ind = np.zeros((ds.num_clients, ds.num_features), np.int64)
            for c in range(ds.num_clients):
                ind[c, client_ids(c)] = 1
            est = estimate_heat_randomized_response(
                ind, cfg.rr_flip_prob, np.random.default_rng(cfg.seed),
                weights=w)
            total = float(ds.num_clients) if w is None else float(w.sum())
            counts = np.clip(est, 0, total)
        elif cfg.weighted:
            # exact / secure_agg: sum involving clients' weights (App. D.4)
            counts = np.zeros(ds.num_features)
            for c in range(ds.num_clients):
                counts[client_ids(c)] += w[c]
            total = float(w.sum())
        else:  # exact; secure_agg is exact by construction, reuse the counts
            counts, total = ds.heat.counts, ds.heat.total
        return HeatStats(counts=np.asarray(counts, np.float64), total=float(total),
                         name="vocab")

    # ------------------------------------------------------------------
    def _make_round_step(self):
        local_train = make_local_trainer(self.loss_fn, self.cfg)

        def round_step(state: ServerState, cohort_batch):
            deltas = cohort_deltas(local_train, state.params, cohort_batch)
            mean_delta = jax.tree.map(lambda d: d.mean(axis=0), deltas)
            new_state = self.alg.apply(state, mean_delta)
            # monitoring loss: first minibatch of each client under old params
            first = jax.tree.map(lambda x: x[:, 0], cohort_batch)
            loss = jax.vmap(lambda b: self.loss_fn(state.params, b))(first).mean()
            return new_state, loss

        return round_step

    # ------------------------------------------------------------------
    # sparse submodel update plane (repro.sparse)
    # ------------------------------------------------------------------
    def _prepare_sparse_plane(self, params):
        """Precompute static metadata and resolve the sparse local mode."""
        plain = unbox(params)
        ordered_paths = [p for p, _ in sparse_table_paths(self._heat_spec)]
        sparse_paths = set(ordered_paths)
        dense_bytes = sparse_static = row_payload = 0.0
        row_elems = 0
        table_rows = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(plain)[0]:
            nbytes = float(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
            dense_bytes += nbytes
            if tree_path_keys(path) in sparse_paths:
                row_payload += nbytes / leaf.shape[0]
                row_elems += int(np.prod(leaf.shape)) // leaf.shape[0]
                table_rows.append(int(leaf.shape[0]))
            else:
                sparse_static += nbytes
        self._comm_meta = (dense_bytes, sparse_static, row_payload, row_elems)
        keys = [self.ds.feature_key]
        if self.ds.feature_key == "hist" and "target" in self.ds.client_data:
            keys.append("target")
        self._feature_batch_keys = keys
        self._sparse_paths = ordered_paths
        # local-training replica layout: gathered submodel replicas need every
        # feature table keyed by the dataset's id space (sub_ids index rows)
        gatherable = (bool(ordered_paths)
                      and all(r == self.ds.num_features for r in table_rows))
        mode = self.cfg.sparse_local
        if mode not in ("auto", "replicated", "sparse_replicated"):
            raise ValueError(f"unknown sparse_local mode: {mode!r}")
        if mode == "auto":
            mode = "sparse_replicated" if gatherable else "replicated"
        elif mode == "sparse_replicated" and not gatherable:
            raise ValueError(
                "sparse_local='sparse_replicated' needs axis-0 feature tables "
                f"of {self.ds.num_features} rows; found {table_rows}")
        self._sparse_local = mode

    def _make_sparse_round_step(self):
        cfg = self.cfg
        correct = cfg.algorithm == "fedsubavg"
        sparse_apply = cfg.algorithm in ("fedavg", "fedprox", "fedsubavg")
        eta = cfg.server_lr
        base_key = jax.random.PRNGKey(cfg.seed + 17)
        submodel = self._sparse_local == "sparse_replicated"
        if submodel:
            local_train = make_submodel_local_trainer(
                self.loss_fn, cfg, self._sparse_paths,
                self._feature_batch_keys)
        else:
            local_train = make_local_trainer(self.loss_fn, cfg)

        def round_step(state: ServerState, cohort_batch, sub_ids):
            if submodel:
                # each client trains its gathered submodel; deltas are born
                # RowSparse on sub_ids — no dense (K, V, D) stack, no encode
                enc = cohort_submodel_deltas(local_train, state.params,
                                             cohort_batch, sub_ids)
            else:
                deltas = cohort_deltas(local_train, state.params, cohort_batch)
                enc = encode_delta_tree(deltas, self._heat_spec, sub_ids)
            if cfg.sparse_topk:
                enc = jax.tree.map(
                    lambda l: jax.vmap(lambda rs: topk_rows(rs, cfg.sparse_topk))(l)
                    if is_rowsparse(l) else l, enc, is_leaf=is_rowsparse)
            if cfg.sparse_int8:
                key = jax.random.fold_in(base_key, state.rounds)
                enc = jax.tree.map(
                    lambda l: dequantize_rows(l)
                    if isinstance(l, QuantRows) else l,
                    quantize_tree_int8(enc, key),
                    is_leaf=lambda x: isinstance(x, QuantRows))
            agg = sparse_cohort_aggregate(
                enc, self._heat_spec, self._heat_counts, self.heat.total,
                cfg.clients_per_round, correct=correct)
            if sparse_apply:
                # FedAvg/FedSubAvg server: scatter-add the union rows; the
                # heat correction is already fused into the aggregate.
                plain = unbox(state.params)

                def ap(p, u):
                    if is_rowsparse(u):
                        return apply_rowsparse(p, u, eta)
                    return p + (u * eta).astype(p.dtype)

                new_plain = jax.tree.map(ap, plain, agg)
                new_params = boxed_like(new_plain, state.params)
                new_state = ServerState(new_params, state.opt, state.rounds + 1)
            else:
                # stateful server optimizers (scaffold/fedadam) consume the
                # dense mean delta; densify once at the server boundary
                dense = boxed_like(decode_delta_tree(agg), state.params)
                new_state = self.alg.apply(state, dense)
            first = jax.tree.map(lambda x: x[:, 0], cohort_batch)
            loss = jax.vmap(lambda b: self.loss_fn(state.params, b))(first).mean()
            return new_state, loss

        return round_step

    def _sample_sparse_cohort(self):
        """One round's host work: sample the cohort and stack its feature ids.

        Returns ``(cohort_batch, feats)`` where ``feats`` is the ``(K, M)``
        concatenation of every feature-carrying leaf — the input the jitted
        ``count_sub_ids``/``derive_sub_ids`` pair consumes. This is the only
        per-round host-side work left on the sparse path.
        """
        cfg = self.cfg
        ids = self.np_rng.choice(self.ds.num_clients, size=cfg.clients_per_round,
                                 replace=False)
        cohort = sample_cohort_batch(self.ds, ids, cfg.local_iters,
                                     cfg.local_batch, self.np_rng)
        feats = np.concatenate([np.asarray(cohort[k]).reshape(len(ids), -1)
                                for k in self._feature_batch_keys], axis=1)
        return cohort, feats

    def _log_sparse_comm(self, valid_counts: np.ndarray, capacity: int):
        """Comm accounting for one sparse round from per-client sub-id counts.

        Uplink: top-k keeps exactly min(k, valid) delta rows per client.
        Downlink prices what the execution actually ships: in
        ``sparse_replicated`` mode each client receives its gathered
        ``capacity``-row submodel buffer (clamped to the table size — the
        pow2 bucket may exceed V, but the padding slots past the table are
        never materialised on the wire); in dense-replica mode each client
        receives the full feature table. The dense baseline carries the
        ``local_iters`` factor (the I=1 dense protocol re-ships the model
        every local step).
        """
        cfg = self.cfg
        k = len(valid_counts)
        up_counts = (np.minimum(valid_counts, cfg.sparse_topk)
                     if cfg.sparse_topk else valid_counts)
        down_counts = np.full(
            k, min(capacity, self.ds.num_features)
            if self._sparse_local == "sparse_replicated"
            else self.ds.num_features)
        dense_bytes, sparse_static, row_payload, row_elems = self._comm_meta
        self.comm_log.append(round_comm_stats(
            self._rounds_run, dense_bytes, sparse_static, row_payload,
            valid_counts, self.ds.num_features, int8=cfg.sparse_int8,
            row_elems=row_elems, uplink_rows_per_client=up_counts,
            downlink_rows_per_client=down_counts,
            local_iters=cfg.local_iters))

    def _run_sparse_round(self) -> float:
        cohort, feats = self._sample_sparse_cohort()
        feats = jnp.asarray(feats)
        valid_counts = np.asarray(count_sub_ids(feats, self.ds.num_features))
        # pow2 capacity bounds jit recompiles to O(log V) variants
        capacity = pow2_capacity(int(valid_counts.max()))
        sub_ids = derive_sub_ids(feats, self.ds.num_features, capacity)
        cohort = {k: jnp.asarray(v) for k, v in cohort.items()}
        self.state, loss = self._sparse_step(self.state, cohort, sub_ids)
        self._last_capacity = capacity
        self._log_sparse_comm(valid_counts, capacity)
        return float(loss)

    def run_rounds(self, n: int) -> List[float]:
        """Drive ``n`` rounds through the in-jit engine (one ``lax.scan``).

        Identical math and RNG stream to ``n`` successive ``run_round``
        calls — the host samples all ``n`` cohorts up front (consuming
        ``np_rng`` in the same order), sub-ids for every round are derived by
        one jitted call, and a single scan-compiled program advances the
        donated ``ServerState`` through all rounds, so per-round dispatch and
        host work amortise to ~zero. Falls back to the per-round loop for
        non-sparse configurations. Returns the per-round monitoring losses.

        One honest accounting difference vs the loop: the engine buckets ALL
        ``n`` rounds to one shared sub-id capacity, so in sparse_replicated
        mode the priced submodel download per round reflects that shared
        buffer, where the per-round loop prices each round's own (possibly
        smaller) bucket. Losses/params/uplink are identical either way.
        """
        if n <= 0:
            return []
        cfg = self.cfg
        if cfg.algorithm == "central" or not cfg.sparse:
            return [self.run_round() for _ in range(n)]
        k = cfg.clients_per_round
        cohorts, feats = [], []
        for _ in range(n):
            c, f = self._sample_sparse_cohort()
            cohorts.append(c)
            feats.append(f)
        stacked = {key: jnp.asarray(np.stack([c[key] for c in cohorts]))
                   for key in cohorts[0]}
        flat_feats = jnp.asarray(np.stack(feats)).reshape(n * k, -1)
        valid_counts = np.asarray(
            count_sub_ids(flat_feats, self.ds.num_features)).reshape(n, k)
        capacity = pow2_capacity(int(valid_counts.max()))
        sub_ids = derive_sub_ids(flat_feats, self.ds.num_features,
                                 capacity).reshape(n, k, capacity)
        self.state, losses = self._sparse_engine(self.state, stacked, sub_ids)
        losses = np.asarray(losses)
        self._last_capacity = capacity
        for r in range(n):
            self._rounds_run += 1
            self._log_sparse_comm(valid_counts[r], capacity)
        return [float(l) for l in losses]

    def _make_central_step(self):
        def central_step(state: ServerState, batches):
            def step(p, batch):
                l, g = jax.value_and_grad(self.loss_fn)(p, batch)
                return tree_add(p, tree_scale(g, -self.cfg.lr)), l

            p, losses = jax.lax.scan(step, state.params, batches)
            return ServerState(p, state.opt, state.rounds + 1), losses.mean()

        return central_step

    # ------------------------------------------------------------------
    def run_round(self) -> float:
        cfg = self.cfg
        self._rounds_run += 1
        if cfg.algorithm == "central":
            batches = pooled_batches(self.ds, cfg.local_iters,
                                     cfg.local_batch * cfg.clients_per_round,
                                     self.np_rng)
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            self.state, loss = self._central_step(self.state, batches)
            return float(loss)
        if cfg.sparse:
            return self._run_sparse_round()
        ids = self.np_rng.choice(self.ds.num_clients, size=cfg.clients_per_round,
                                 replace=False)
        cohort = sample_cohort_batch(self.ds, ids, cfg.local_iters, cfg.local_batch,
                                     self.np_rng)
        cohort = {k: jnp.asarray(v) for k, v in cohort.items()}
        self.state, loss = self._round_step(self.state, cohort)
        return float(loss)

    def evaluate(self) -> float:
        if self.predict_fn is None:
            return float("nan")
        scores = np.asarray(self.predict_fn(self.state.params, self.ds.test_data))
        labels = self.ds.test_data["label"]
        return auc(labels, scores) if self.metric == "auc" else accuracy(labels, scores)

    def train_loss(self, num_batches: int = 8, batch: int = 256) -> float:
        """Loss over a fixed random sample of the pooled training set."""
        rng = np.random.default_rng(123)
        batches = pooled_batches(self.ds, num_batches, batch, rng)
        tot = 0.0
        for i in range(num_batches):
            b = {k: jnp.asarray(v[i]) for k, v in batches.items()}
            tot += float(self.loss_fn(self.state.params, b))
        return tot / num_batches

    def comm_summary(self) -> Dict[str, float]:
        """Aggregate comm accounting over all sparse rounds so far."""
        from repro.federated.metrics import comm_summary
        return comm_summary(self.comm_log)

    def run(self, rounds: int, eval_every: int = 10, verbose: bool = False,
            engine: bool = False):
        """Train for ``rounds`` rounds, evaluating every ``eval_every``.

        ``engine=True`` drives each between-evals stretch through
        ``run_rounds`` (the in-jit multi-round scan) instead of one
        ``run_round`` dispatch per round; results are identical to f32
        tolerance. Per-round wall time lands in ``RoundRecord.wall_time``.

        ``RoundRecord.round`` numbers continue from the trainer's global
        round counter, so repeated ``run()`` calls (or mixing ``run_round``
        with ``run``) append monotone history instead of colliding with it.
        """
        done = 0
        while done < rounds:
            chunk = min(eval_every - done % eval_every, rounds - done)
            t0 = time.perf_counter()
            if engine:
                self.run_rounds(chunk)
            else:
                for _ in range(chunk):
                    self.run_round()
            wall = (time.perf_counter() - t0) / chunk
            done += chunk
            if done % eval_every == 0 or done == rounds:
                metric = self.evaluate()
                rec = RoundRecord(self._rounds_run, self.train_loss(), metric,
                                  wall_time=wall)
                if self.comm_log:
                    s = self.comm_summary()
                    rec.bytes_up = s["bytes_up_sparse"]
                    rec.bytes_down = s["bytes_down_sparse"]
                    rec.density = s["mean_density"]
                self.history.append(rec)
                if verbose:
                    print(f"[{self.cfg.algorithm}] round {self._rounds_run}: "
                          f"loss={self.history[-1].train_loss:.4f} "
                          f"{self.metric}={metric:.4f} "
                          f"({wall * 1e3:.1f} ms/round)")
        return self.history
