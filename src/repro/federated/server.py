"""Server orchestration of federated rounds (Algorithm 1, server process).

``FederatedTrainer`` runs the paper's full experimental protocol over a
``FederatedDataset``: samples K clients per round, dispatches local training,
aggregates deltas, applies the configured server algorithm, and tracks train
loss / test metrics. CentralSGD (the paper's non-federated reference) shares
the same interface.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_add, tree_path_keys, tree_scale
from repro.configs.base import FedConfig
from repro.core.aggregate import HeatSpec
from repro.core.algorithms import ServerState, make_server_algorithm
from repro.core.heat import HeatStats, estimate_heat_randomized_response
from repro.data.batching import pooled_batches, sample_cohort_batch
from repro.data.synthetic import FederatedDataset
from repro.federated.client import cohort_deltas, make_local_trainer
from repro.federated.metrics import accuracy, auc
from repro.federated.simulation import heat_spec_from_axes, sparse_table_paths
from repro.sharding.logical import boxed_like, unbox
from repro.sparse.aggregate import apply_rowsparse, sparse_cohort_aggregate
from repro.sparse.comm import CommStats, round_comm_stats
from repro.sparse.compress import dequantize_rows, quantize_rows_int8, topk_rows
from repro.sparse.encode import decode_delta_tree, encode_delta_tree
from repro.sparse.rowsparse import is_rowsparse


@dataclass
class RoundRecord:
    round: int
    train_loss: float
    test_metric: float
    # comm accounting (sparse mode; zeros on the dense path)
    bytes_up: float = 0.0            # cumulative sparse-plane uplink bytes
    bytes_down: float = 0.0          # cumulative sparse-plane downlink bytes
    density: float = 1.0             # mean per-client submodel density so far


class FederatedTrainer:
    """End-to-end federated training loop for the paper-scale models."""

    def __init__(self, ds: FederatedDataset, make_params: Callable,
                 loss_fn: Callable, cfg: FedConfig,
                 predict_fn: Optional[Callable] = None,
                 metric: str = "auc", rng_seed: int = 0):
        self.ds = ds
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.predict_fn = predict_fn
        self.metric = metric
        self.np_rng = np.random.default_rng(cfg.seed + rng_seed)

        params = make_params(rng=jax.random.PRNGKey(cfg.seed))
        self.heat = self._resolve_heat(ds, cfg)
        heat_spec = heat_spec_from_axes(params)
        heat_counts = {"vocab": jnp.asarray(self.heat.counts, jnp.float32)}
        total = self.heat.total
        self._heat_spec = heat_spec
        self._heat_counts = heat_counts
        self.alg = make_server_algorithm(cfg, heat_spec=heat_spec,
                                         heat_counts=heat_counts, total=total)
        self.state = self.alg.init(params)

        if cfg.algorithm == "central":
            self._central_step = jax.jit(self._make_central_step())
        elif cfg.sparse:
            # jit caches one trace per sub_ids capacity (kept to O(log V)
            # variants by the power-of-two rounding in _run_sparse_round)
            self._sparse_step = jax.jit(self._make_sparse_round_step())
            self._prepare_sparse_plane(params)
        else:
            self._round_step = jax.jit(self._make_round_step())
        self.history: List[RoundRecord] = []
        self.comm_log: List[CommStats] = []
        self._rounds_run = 0

    # ------------------------------------------------------------------
    def _resolve_heat(self, ds: FederatedDataset, cfg: FedConfig) -> HeatStats:
        if cfg.heat_estimator == "exact":
            counts, total = ds.heat.counts, ds.heat.total
        elif cfg.heat_estimator == "randomized_response":
            ind = np.zeros((ds.num_clients, ds.num_features), np.int64)
            key = ds.feature_key
            for c in range(ds.num_clients):
                ids = ds.client_data[key][c].reshape(-1)
                ids = ids[ids >= 0]
                ind[c, np.unique(ids)] = 1
                if key == "hist" and "target" in ds.client_data:
                    t = ds.client_data["target"][c].reshape(-1)
                    ind[c, np.unique(t)] = 1
            est = estimate_heat_randomized_response(ind, cfg.rr_flip_prob,
                                                    np.random.default_rng(cfg.seed))
            counts, total = np.clip(est, 0, ds.num_clients), float(ds.num_clients)
        else:  # secure_agg is exact by construction; reuse exact counts
            counts, total = ds.heat.counts, ds.heat.total
        if cfg.weighted:
            # App. D.4: weight clients by local dataset size
            w = ds.sample_counts.astype(np.float64)
            counts = np.zeros(ds.num_features)
            key = ds.feature_key
            for c in range(ds.num_clients):
                ids = ds.client_data[key][c].reshape(-1)
                ids = ids[ids >= 0]
                counts[np.unique(ids)] += w[c]
            total = float(w.sum())
        return HeatStats(counts=np.asarray(counts, np.float64), total=float(total),
                         name="vocab")

    # ------------------------------------------------------------------
    def _make_round_step(self):
        local_train = make_local_trainer(self.loss_fn, self.cfg)

        def round_step(state: ServerState, cohort_batch):
            deltas = cohort_deltas(local_train, state.params, cohort_batch)
            mean_delta = jax.tree.map(lambda d: d.mean(axis=0), deltas)
            new_state = self.alg.apply(state, mean_delta)
            # monitoring loss: first minibatch of each client under old params
            first = jax.tree.map(lambda x: x[:, 0], cohort_batch)
            loss = jax.vmap(lambda b: self.loss_fn(state.params, b))(first).mean()
            return new_state, loss

        return round_step

    # ------------------------------------------------------------------
    # sparse submodel update plane (repro.sparse)
    # ------------------------------------------------------------------
    def _prepare_sparse_plane(self, params):
        """Precompute static metadata for the row-sparse round path."""
        plain = unbox(params)
        sparse_paths = {p for p, _ in sparse_table_paths(self._heat_spec)}
        dense_bytes = sparse_static = row_payload = 0.0
        row_elems = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(plain)[0]:
            nbytes = float(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
            dense_bytes += nbytes
            if tree_path_keys(path) in sparse_paths:
                row_payload += nbytes / leaf.shape[0]
                row_elems += int(np.prod(leaf.shape)) // leaf.shape[0]
            else:
                sparse_static += nbytes
        self._comm_meta = (dense_bytes, sparse_static, row_payload, row_elems)
        keys = [self.ds.feature_key]
        if self.ds.feature_key == "hist" and "target" in self.ds.client_data:
            keys.append("target")
        self._feature_batch_keys = keys

    def _make_sparse_round_step(self):
        cfg = self.cfg
        local_train = make_local_trainer(self.loss_fn, cfg)
        correct = cfg.algorithm == "fedsubavg"
        sparse_apply = cfg.algorithm in ("fedavg", "fedprox", "fedsubavg")
        eta = cfg.server_lr
        base_key = jax.random.PRNGKey(cfg.seed + 17)

        def round_step(state: ServerState, cohort_batch, sub_ids):
            deltas = cohort_deltas(local_train, state.params, cohort_batch)
            enc = encode_delta_tree(deltas, self._heat_spec, sub_ids)
            if cfg.sparse_topk:
                enc = jax.tree.map(
                    lambda l: jax.vmap(lambda rs: topk_rows(rs, cfg.sparse_topk))(l)
                    if is_rowsparse(l) else l, enc, is_leaf=is_rowsparse)
            if cfg.sparse_int8:
                key = jax.random.fold_in(base_key, state.rounds)
                enc = jax.tree.map(
                    lambda l: dequantize_rows(quantize_rows_int8(l, key))
                    if is_rowsparse(l) else l, enc, is_leaf=is_rowsparse)
            agg = sparse_cohort_aggregate(
                enc, self._heat_spec, self._heat_counts, self.heat.total,
                cfg.clients_per_round, correct=correct)
            if sparse_apply:
                # FedAvg/FedSubAvg server: scatter-add the union rows; the
                # heat correction is already fused into the aggregate.
                plain = unbox(state.params)

                def ap(p, u):
                    if is_rowsparse(u):
                        return apply_rowsparse(p, u, eta)
                    return p + (u * eta).astype(p.dtype)

                new_plain = jax.tree.map(ap, plain, agg)
                new_params = boxed_like(new_plain, state.params)
                new_state = ServerState(new_params, state.opt, state.rounds + 1)
            else:
                # stateful server optimizers (scaffold/fedadam) consume the
                # dense mean delta; densify once at the server boundary
                dense = boxed_like(decode_delta_tree(agg), state.params)
                new_state = self.alg.apply(state, dense)
            first = jax.tree.map(lambda x: x[:, 0], cohort_batch)
            loss = jax.vmap(lambda b: self.loss_fn(state.params, b))(first).mean()
            return new_state, loss

        return round_step

    def _run_sparse_round(self) -> float:
        cfg = self.cfg
        ids = self.np_rng.choice(self.ds.num_clients, size=cfg.clients_per_round,
                                 replace=False)
        cohort = sample_cohort_batch(self.ds, ids, cfg.local_iters, cfg.local_batch,
                                     self.np_rng)
        feats = [np.asarray(cohort[k]).reshape(len(ids), -1)
                 for k in self._feature_batch_keys]
        per_client = [np.unique(np.concatenate([f[k_] for f in feats]))
                      for k_ in range(len(ids))]
        per_client = [u[u >= 0] for u in per_client]
        valid_counts = np.array([len(u) for u in per_client])
        # pow2 capacity bounds jit recompiles to O(log V) variants
        capacity = 8
        while capacity < valid_counts.max():
            capacity *= 2
        capacity = min(capacity, self.ds.num_features)
        sub_ids = np.full((len(ids), capacity), -1, np.int32)
        for k_, u in enumerate(per_client):
            sub_ids[k_, : len(u)] = u
        cohort = {k: jnp.asarray(v) for k, v in cohort.items()}
        self.state, loss = self._sparse_step(self.state, cohort,
                                             jnp.asarray(sub_ids))
        # uplink: top-k keeps exactly min(k, valid) delta rows per client;
        # downlink (the submodel download) and density stay at the full
        # per-client feature counts
        up_counts = (np.minimum(valid_counts, cfg.sparse_topk)
                     if cfg.sparse_topk else valid_counts)
        dense_bytes, sparse_static, row_payload, row_elems = self._comm_meta
        self.comm_log.append(round_comm_stats(
            self._rounds_run, dense_bytes, sparse_static, row_payload,
            valid_counts, self.ds.num_features, int8=cfg.sparse_int8,
            row_elems=row_elems, uplink_rows_per_client=up_counts))
        return float(loss)

    def _make_central_step(self):
        def central_step(state: ServerState, batches):
            def step(p, batch):
                l, g = jax.value_and_grad(self.loss_fn)(p, batch)
                return tree_add(p, tree_scale(g, -self.cfg.lr)), l

            p, losses = jax.lax.scan(step, state.params, batches)
            return ServerState(p, state.opt, state.rounds + 1), losses.mean()

        return central_step

    # ------------------------------------------------------------------
    def run_round(self) -> float:
        cfg = self.cfg
        self._rounds_run += 1
        if cfg.algorithm == "central":
            batches = pooled_batches(self.ds, cfg.local_iters,
                                     cfg.local_batch * cfg.clients_per_round,
                                     self.np_rng)
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            self.state, loss = self._central_step(self.state, batches)
            return float(loss)
        if cfg.sparse:
            return self._run_sparse_round()
        ids = self.np_rng.choice(self.ds.num_clients, size=cfg.clients_per_round,
                                 replace=False)
        cohort = sample_cohort_batch(self.ds, ids, cfg.local_iters, cfg.local_batch,
                                     self.np_rng)
        cohort = {k: jnp.asarray(v) for k, v in cohort.items()}
        self.state, loss = self._round_step(self.state, cohort)
        return float(loss)

    def evaluate(self) -> float:
        if self.predict_fn is None:
            return float("nan")
        scores = np.asarray(self.predict_fn(self.state.params, self.ds.test_data))
        labels = self.ds.test_data["label"]
        return auc(labels, scores) if self.metric == "auc" else accuracy(labels, scores)

    def train_loss(self, num_batches: int = 8, batch: int = 256) -> float:
        """Loss over a fixed random sample of the pooled training set."""
        rng = np.random.default_rng(123)
        batches = pooled_batches(self.ds, num_batches, batch, rng)
        tot = 0.0
        for i in range(num_batches):
            b = {k: jnp.asarray(v[i]) for k, v in batches.items()}
            tot += float(self.loss_fn(self.state.params, b))
        return tot / num_batches

    def comm_summary(self) -> Dict[str, float]:
        """Aggregate comm accounting over all sparse rounds so far."""
        from repro.federated.metrics import comm_summary
        return comm_summary(self.comm_log)

    def run(self, rounds: int, eval_every: int = 10, verbose: bool = False):
        for r in range(rounds):
            loss = self.run_round()
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                metric = self.evaluate()
                rec = RoundRecord(r + 1, self.train_loss(), metric)
                if self.comm_log:
                    s = self.comm_summary()
                    rec.bytes_up = s["bytes_up_sparse"]
                    rec.bytes_down = s["bytes_down_sparse"]
                    rec.density = s["mean_density"]
                self.history.append(rec)
                if verbose:
                    print(f"[{self.cfg.algorithm}] round {r+1}: "
                          f"loss={self.history[-1].train_loss:.4f} "
                          f"{self.metric}={metric:.4f}")
        return self.history
