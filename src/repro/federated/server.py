"""Server orchestration of federated rounds (Algorithm 1, server process).

``FederatedTrainer`` runs the paper's full experimental protocol over a
``FederatedDataset``: samples K clients per round, dispatches local training,
aggregates deltas, applies the configured server algorithm, and tracks train
loss / test metrics. CentralSGD (the paper's non-federated reference) shares
the same interface.

Since the RoundPlan redesign the trainer no longer re-derives the execution
layout from ``FedConfig`` flags with its own branches: the flags resolve to a
``repro.federated.plan.RoundPlan`` (``plan_from_config``), the jitted round
step comes from the same ``build_round_step`` that backs ``make_round_step``,
and an explicit ``plan=`` argument overrides the flag resolution entirely —
one dispatch system, two entry points.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_add, tree_scale
from repro.configs.base import FedConfig
from repro.core.algorithms import ServerState, make_server_algorithm
from repro.core.heat import (HeatStats, clamp_heat_estimate,
                             estimate_heat_randomized_response)
from repro.data.batching import pooled_batches, sample_cohort_batch
from repro.data.synthetic import FederatedDataset
from repro.federated.arrivals import ArrivalSim
from repro.federated.async_engine import (BufferedAsyncServerUpdate,
                                          build_async_engine)
from repro.federated.plan import (CohortSharding, RoundPlan,
                                  SubmodelReplicatedLocal, build_round_step,
                                  heat_spec_from_axes, plan_from_config,
                                  sparse_table_paths)
from repro.federated.metrics import accuracy, auc
from repro.sharding.logical import unbox
from repro.sparse.comm import CommStats, model_comm_meta
from repro.sparse.encode import tree_leaf_at
from repro.sparse.rowsparse import count_unique_ids, unique_ids_padded
from repro.telemetry import PhaseTimer, TraceSink
from repro.telemetry.round import (RoundTelemetry, split_rounds,
                                   telemetry_to_host)


@dataclass
class RoundRecord:
    round: int
    train_loss: float
    test_metric: float
    # comm accounting (sparse mode; zeros on the dense path)
    bytes_up: float = 0.0            # cumulative sparse-plane uplink bytes
    bytes_down: float = 0.0          # cumulative sparse-plane downlink bytes
    density: float = 1.0             # mean per-client submodel density so far
    wall_time: float = 0.0           # STEADY-STATE mean seconds/round since
                                     # the last record (compiling dispatches
                                     # excluded; blended mean only when every
                                     # dispatch of the stretch compiled)
    compile_time: float = 0.0        # seconds spent in compiling dispatches
                                     # since the last record (0 once warm)


# ---------------------------------------------------------------------------
# jitted sub-id derivation (the server engine's cohort preprocessing)
# ---------------------------------------------------------------------------


def pow2_capacity(max_count: int, floor: int = 8) -> int:
    """Smallest power-of-two >= max(max_count, floor).

    Sub-id capacities are bucketed to powers of two so the jitted round step
    compiles at most O(log V) distinct variants over a whole training run —
    the invariant must never be broken by clamping to a non-pow2 table size
    (a capacity slightly above V only adds padding slots, which every sparse
    consumer drops).
    """
    cap = floor
    while cap < max_count:
        cap *= 2
    return cap


def _valid_ids(flat: jax.Array, num_features: int) -> jax.Array:
    """Ids outside ``[0, num_features)`` become -1 (the padding convention)."""
    flat = flat.astype(jnp.int32)
    return jnp.where((flat >= 0) & (flat < num_features), flat, -1)


@functools.partial(jax.jit, static_argnames=("num_features",))
def count_sub_ids(feats: jax.Array, num_features: int) -> jax.Array:
    """Per-client distinct-feature counts ``(K,)`` from stacked id leaves.

    ``feats``: ``(K, M)`` int feature ids, negatives are padding. The count
    is over distinct non-negative ids — the size of client k's submodel
    S(k), i.e. the number of valid slots ``derive_sub_ids`` will fill.
    Sort-based (``count_unique_ids``), so the per-client cost is O(M log M)
    in the client's own id count, never O(V) in the feature-space size.
    """

    def one(flat):
        return count_unique_ids(_valid_ids(flat, num_features))

    return jax.vmap(one)(feats)


@functools.partial(jax.jit, static_argnames=("num_features", "capacity"))
def derive_sub_ids(feats: jax.Array, num_features: int,
                   capacity: int) -> jax.Array:
    """Per-client sorted unique feature ids ``(K, capacity)``, -1 padded.

    The jitted replacement for the trainer's former host-side per-client
    ``np.unique`` loops, now sort-based (``unique_ids_padded`` under vmap):
    O(M log M) per client in its own id count M. The earlier bitmap-rank
    variant paid O(V) per client — a (V,) bitmap, cumsum and scatter — which
    at V=65k dominated the whole sharded round (~60 ms/round of host-shared
    work no mesh could parallelise). ``capacity`` must come from
    ``pow2_capacity`` of ``count_sub_ids(...).max()`` so the jit cache stays
    O(log V).
    """

    def one(flat):
        return unique_ids_padded(_valid_ids(flat, num_features), capacity)

    return jax.vmap(one)(feats)


class FederatedTrainer:
    """End-to-end federated training loop for the paper-scale models."""

    def __init__(self, ds: FederatedDataset, make_params: Callable,
                 loss_fn: Callable, cfg: FedConfig,
                 predict_fn: Optional[Callable] = None,
                 metric: str = "auc", rng_seed: int = 0,
                 plan: Optional[RoundPlan] = None,
                 mesh: Optional[Any] = None,
                 telemetry: bool = True,
                 sink: Optional[TraceSink] = None):
        """``mesh``: a device mesh (e.g. ``make_cohort_mesh()``) to shard the
        cohort axis of every round over its ``"data"`` axis. The host-side
        pipeline is untouched — cohorts are sampled from the same RNG stream
        and laid out shard-major (device d owns the contiguous client block
        d), so sharded rounds reproduce single-device rounds to 1e-5. Pass a
        plan with an explicit ``CohortSharding`` for a non-default axis or
        combine strategy.

        ``telemetry``: compute the in-jit :class:`RoundTelemetry` counters
        each round (pure reads — losses, parameters and the RNG stream are
        bit-identical either way) and collect them in ``telemetry_log``.
        ``sink``: a :class:`repro.telemetry.TraceSink` receiving structured
        round/record events (and the verbose reporting); an in-memory sink
        is created when omitted — pass ``TraceSink(path)`` to persist JSONL.
        """
        self.ds = ds
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.predict_fn = predict_fn
        self.metric = metric
        self.np_rng = np.random.default_rng(cfg.seed + rng_seed)

        params = make_params(rng=jax.random.PRNGKey(cfg.seed))
        self.heat = self._resolve_heat(ds, cfg)
        heat_spec = heat_spec_from_axes(params)
        heat_counts = {"vocab": jnp.asarray(self.heat.counts, jnp.float32)}
        total = self.heat.total
        self._heat_spec = heat_spec
        self._heat_counts = heat_counts
        self.alg = make_server_algorithm(cfg, heat_spec=heat_spec,
                                         heat_counts=heat_counts, total=total)
        self.state = self.alg.init(params)
        self.history: List[RoundRecord] = []
        self.comm_log: List[CommStats] = []
        self._rounds_run = 0
        self._last_capacity: Optional[int] = None   # last sparse sub-id bucket
        self.plan: Optional[RoundPlan] = None
        self._sparse_local: Optional[str] = None
        self._sparse_paths: List = []
        self._is_sparse = False
        self.telemetry_enabled = bool(telemetry)
        self.sink = sink if sink is not None else TraceSink()
        self.timer = PhaseTimer()
        self.telemetry_log: List[Dict[str, Any]] = []
        self._compiled_keys: set = set()      # jit-cache keys seen -> warm
        self._last_dispatch_compiled = False
        # buffered-async engines, keyed by (server slot, telemetry flag);
        # the streaming-heat EMA persists across run_async calls
        self._async_engines: Dict[Any, Any] = {}
        self._async_heat_ema = None

        if cfg.algorithm == "central":
            if plan is not None:
                raise ValueError("central training takes no RoundPlan")
            if mesh is not None:
                raise ValueError("central training takes no cohort mesh")
            self._central_step = jax.jit(self._make_central_step())
            return

        self.plan = self._resolve_trainer_plan(params, plan)
        if mesh is not None:
            if (self.plan.sharding is not None
                    and self.plan.sharding.mesh is not mesh):
                raise ValueError(
                    "mesh= conflicts with the explicit plan's CohortSharding "
                    "— set the mesh on the plan only")
            if self.plan.sharding is None:
                self.plan = dataclasses.replace(
                    self.plan, sharding=CohortSharding(mesh))
        self._is_sparse = self.plan.transport.sparse
        round_step = build_round_step(self.plan, loss_fn, params, cfg,
                                      heat_counts=heat_counts, total=total,
                                      server_alg=self.alg,
                                      telemetry=self.telemetry_enabled)
        if self._is_sparse:
            # jit caches one trace per sub_ids capacity (kept to O(log V)
            # variants by pow2_capacity bucketing); ServerState buffers are
            # donated through the step so the table is updated in place.
            # Donation is skipped for cohort-sharded plans: donating the
            # replicated state through a shard_map program forces a full
            # buffer round-trip per call on the multi-device CPU backend
            # (measured ~20x per-round regression), defeating the sharding
            donate = () if self.plan.sharding is not None else (0,)
            self._comm_meta = model_comm_meta(unbox(params),
                                              set(self._sparse_paths))

            def engine(state, cohorts, sub_ids):
                # multi-round driver: scan the round step over stacked
                # cohorts so dispatch overhead amortises across rounds
                return jax.lax.scan(lambda s, xs: round_step(s, *xs), state,
                                    (cohorts, sub_ids))

            if self.plan.debug_checks:
                # the step emits checkify checks: functionalise + jit via
                # checked_jit. Donation is dropped — the checkify error
                # output aliases nothing, and debug mode is not a perf path.
                from repro.analysis.sanitize import checked_jit
                self._sparse_step = checked_jit(round_step)
                self._sparse_engine = checked_jit(engine)
            else:
                self._sparse_step = jax.jit(round_step,
                                            donate_argnums=donate)
                self._sparse_engine = jax.jit(engine, donate_argnums=donate)
        else:
            self._round_step = jax.jit(round_step)
        if self.plan.sharding is not None:
            # commit the server state replicated over the cohort mesh BEFORE
            # the first step: the executable then compiles for (and returns)
            # that layout, so threading the state through rounds never
            # reshards. (Compiling against the initial single-device layout
            # instead makes every later call copy the replicated output back
            # to one device — a measured ~6x per-round penalty.)
            self.state = jax.device_put(
                self.state,
                jax.sharding.NamedSharding(self.plan.sharding.mesh,
                                           jax.sharding.PartitionSpec()))

    # ------------------------------------------------------------------
    def _resolve_trainer_plan(self, params,
                              plan: Optional[RoundPlan]) -> RoundPlan:
        """Resolve FedConfig flags (or validate an explicit plan) against the
        model/dataset: which leaves ride the sparse plane, whether submodel
        replicas are gatherable, and which batch keys carry feature ids."""
        keys = [self.ds.feature_key]
        if self.ds.feature_key == "hist" and "target" in self.ds.client_data:
            keys.append("target")
        self._feature_batch_keys = keys
        ordered_paths = [p for p, _ in sparse_table_paths(self._heat_spec)]
        self._sparse_paths = ordered_paths
        plain = unbox(params)
        table_rows = [int(tree_leaf_at(plain, p).shape[0])
                      for p in ordered_paths]
        # gathered submodel replicas need every feature table keyed by the
        # dataset's id space (sub_ids index rows)
        gatherable = (bool(ordered_paths)
                      and all(r == self.ds.num_features for r in table_rows))
        if plan is None:
            plan = plan_from_config(self.cfg, feature_keys=tuple(keys),
                                    gatherable=gatherable)
        else:
            if plan.server.algorithm != self.cfg.algorithm:
                raise ValueError(
                    f"plan.server.algorithm={plan.server.algorithm!r} "
                    f"disagrees with cfg.algorithm={self.cfg.algorithm!r}: "
                    "the trainer's server state is built from the config")
            if not plan.local.stacked:
                raise ValueError(
                    f"{type(plan.local).__name__} consumes a flat pooled "
                    "batch, but FederatedTrainer samples stacked "
                    "(K, I, B, ...) cohorts with per-client sub_ids — drive "
                    "flat plans through make_round_step/build_round_step")
            # the dataset, not the caller, knows which batch keys carry
            # feature ids — rebind so submodel remapping stays correct
            plan = dataclasses.replace(plan, feature_keys=tuple(keys))
        submodel = isinstance(plan.local, SubmodelReplicatedLocal)
        if submodel and not gatherable:
            raise ValueError(
                "SubmodelReplicatedLocal (sparse_local='sparse_replicated') "
                f"needs axis-0 feature tables of {self.ds.num_features} rows; "
                f"found {table_rows}")
        if plan.transport.sparse:
            self._sparse_local = ("sparse_replicated" if submodel
                                  else "replicated")
        return plan

    # ------------------------------------------------------------------
    def _resolve_heat(self, ds: FederatedDataset, cfg: FedConfig) -> HeatStats:
        """Heat statistics under the configured estimator (App. F) and, when
        ``weighted``, the App. D.4 per-client weighting — *composed with* the
        estimator: weighted randomized response stays private (the weighting
        is applied to the noisy reported bits, never to raw client data);
        exact and secure_agg are exact by construction, so their weighted
        variant aggregates ``w_c`` per involving client directly."""
        key = ds.feature_key

        def client_ids(c):
            ids = ds.client_data[key][c].reshape(-1)
            ids = ids[ids >= 0]
            if key == "hist" and "target" in ds.client_data:
                t = ds.client_data["target"][c].reshape(-1)
                ids = np.concatenate([ids, t[t >= 0]])
            return np.unique(ids)

        w = ds.sample_counts.astype(np.float64) if cfg.weighted else None
        if cfg.heat_estimator == "randomized_response":
            ind = np.zeros((ds.num_clients, ds.num_features), np.int64)
            for c in range(ds.num_clients):
                ind[c, client_ids(c)] = 1
            est = estimate_heat_randomized_response(
                ind, cfg.rr_flip_prob, np.random.default_rng(cfg.seed),
                weights=w)
            total = float(ds.num_clients) if w is None else float(w.sum())
            # clamp into [min_count, total], NOT [0, total]: a noisy estimate
            # <= 0 for a genuinely hot feature would hit the counts > 0 /
            # h > 0 gates and silently zero that row's update every round
            counts = clamp_heat_estimate(est, total)
        elif cfg.weighted:
            # exact / secure_agg: sum involving clients' weights (App. D.4)
            counts = np.zeros(ds.num_features)
            for c in range(ds.num_clients):
                counts[client_ids(c)] += w[c]
            total = float(w.sum())
        else:  # exact; secure_agg is exact by construction, reuse the counts
            counts, total = ds.heat.counts, ds.heat.total
        return HeatStats(counts=np.asarray(counts, np.float64), total=float(total),
                         name="vocab")

    def _mark_dispatch(self, key) -> None:
        """Record whether the NEXT jitted dispatch will compile.

        ``key`` names the executable variant about to run — ``("step", cap)``,
        ``("engine", n, cap)``, ``("dense",)``, ``("central",)`` — mirroring
        the static arguments that actually key the jit cache, so ``run()``
        can attribute wall time to compile vs steady state without poking
        jit internals.
        """
        self._last_dispatch_compiled = key not in self._compiled_keys
        self._compiled_keys.add(key)

    def _record_telemetry(self, tel, rnd: int,
                          comm: Optional[CommStats] = None) -> None:
        """Append one round's telemetry to ``telemetry_log`` and the sink.

        ``tel`` is the in-jit :class:`RoundTelemetry` (or an already-host
        dict split from a scan-stacked engine run); ``comm`` attaches the
        round's byte accounting under a ``"comm"`` sub-object (its
        ``round``/``density`` keys would collide with telemetry fields
        at the top level).
        """
        if tel is None:
            return
        if isinstance(tel, RoundTelemetry):
            tel = telemetry_to_host(tel)
        event = {"event": "round", "round": int(rnd), **tel}
        if comm is not None:
            event["comm"] = comm.as_dict()
        self.telemetry_log.append(event)
        self.sink.emit(event)

    def _sample_sparse_cohort(self):
        """One round's host work: sample the cohort and stack its feature ids.

        Returns ``(cohort_batch, feats)`` where ``feats`` is the ``(K, M)``
        concatenation of every feature-carrying leaf — the input the jitted
        ``count_sub_ids``/``derive_sub_ids`` pair consumes. This is the only
        per-round host-side work left on the sparse path.
        """
        cfg = self.cfg
        ids = self.np_rng.choice(self.ds.num_clients, size=cfg.clients_per_round,
                                 replace=False)
        cohort = sample_cohort_batch(self.ds, ids, cfg.local_iters,
                                     cfg.local_batch, self.np_rng)
        feats = np.concatenate([np.asarray(cohort[k]).reshape(len(ids), -1)
                                for k in self._feature_batch_keys], axis=1)
        return cohort, feats

    def _log_sparse_comm(self, valid_counts: np.ndarray, capacity: int):
        """Comm accounting for one sparse round from per-client sub-id counts.

        The pricing itself lives on the plan's transport
        (``RowSparseTransport.round_comm``); this method feeds it the
        trainer's host-side metadata: the model's byte geometry, the round's
        sub-id counts, and whether the downlink ships gathered submodel
        buffers (submodel-replica local training) or the full table.
        """
        self.comm_log.append(self.plan.transport.round_comm(
            self._rounds_run, self._comm_meta, valid_counts,
            self.ds.num_features, capacity=capacity,
            submodel_downlink=self._sparse_local == "sparse_replicated",
            local_iters=self.cfg.local_iters))

    def _run_sparse_round(self) -> float:
        cohort, feats = self._sample_sparse_cohort()
        feats = jnp.asarray(feats)
        valid_counts = np.asarray(count_sub_ids(feats, self.ds.num_features))
        # pow2 capacity bounds jit recompiles to O(log V) variants
        capacity = pow2_capacity(int(valid_counts.max()))
        sub_ids = derive_sub_ids(feats, self.ds.num_features, capacity)
        cohort = {k: jnp.asarray(v) for k, v in cohort.items()}
        self._mark_dispatch(("step", capacity))
        self.state, metrics = self._sparse_step(self.state, cohort, sub_ids)
        self._last_capacity = capacity
        self._log_sparse_comm(valid_counts, capacity)
        self._record_telemetry(metrics.get("telemetry"), self._rounds_run,
                               comm=self.comm_log[-1])
        return float(metrics["loss"])

    def run_rounds(self, n: int) -> List[float]:
        """Drive ``n`` rounds through the in-jit engine (one ``lax.scan``).

        Identical math and RNG stream to ``n`` successive ``run_round``
        calls — the host samples all ``n`` cohorts up front (consuming
        ``np_rng`` in the same order), sub-ids for every round are derived by
        one jitted call, and a single scan-compiled program advances the
        donated ``ServerState`` through all rounds, so per-round dispatch and
        host work amortise to ~zero. Falls back to the per-round loop for
        non-sparse configurations. Returns the per-round monitoring losses.

        One honest accounting difference vs the loop: the engine buckets ALL
        ``n`` rounds to one shared sub-id capacity, so in sparse_replicated
        mode the priced submodel download per round reflects that shared
        buffer, where the per-round loop prices each round's own (possibly
        smaller) bucket. Losses/params/uplink are identical either way.
        """
        if n <= 0:
            return []
        cfg = self.cfg
        if cfg.algorithm == "central" or not self._is_sparse:
            return [self.run_round() for _ in range(n)]
        k = cfg.clients_per_round
        cohorts, feats = [], []
        for _ in range(n):
            c, f = self._sample_sparse_cohort()
            cohorts.append(c)
            feats.append(f)
        stacked = {key: jnp.asarray(np.stack([c[key] for c in cohorts]))
                   for key in cohorts[0]}
        flat_feats = jnp.asarray(np.stack(feats)).reshape(n * k, -1)
        valid_counts = np.asarray(
            count_sub_ids(flat_feats, self.ds.num_features)).reshape(n, k)
        capacity = pow2_capacity(int(valid_counts.max()))
        sub_ids = derive_sub_ids(flat_feats, self.ds.num_features,
                                 capacity).reshape(n, k, capacity)
        self._mark_dispatch(("engine", n, capacity))
        self.state, metrics = self._sparse_engine(self.state, stacked, sub_ids)
        losses = np.asarray(metrics["loss"])
        self._last_capacity = capacity
        # telemetry rode the scan: each field gained a leading round axis
        tel_events = (split_rounds(metrics["telemetry"], n)
                      if "telemetry" in metrics else [None] * n)
        for r in range(n):
            self._rounds_run += 1
            self._log_sparse_comm(valid_counts[r], capacity)
            self._record_telemetry(tel_events[r], self._rounds_run,
                                   comm=self.comm_log[-1])
        return [float(l) for l in losses]

    def run_async(self, sim: ArrivalSim,
                  server: Optional[BufferedAsyncServerUpdate] = None
                  ) -> List[float]:
        """Drive a buffered-async run over ``sim``'s compiled event stream.

        The trainer samples ``sim.num_rounds`` dispatch waves of K clients
        from the SAME ``np_rng`` stream (and in the same order) as
        ``run_rounds(sim.num_rounds)``, stacks them as per-task data, and
        scans the :mod:`repro.federated.async_engine` event loop over the
        schedule in one jitted dispatch. ``server`` overrides the async
        server slot; by default the plan's algorithm runs with
        ``buffer_size = K`` — which on a zero-delay sim makes this call
        reproduce ``run_rounds`` losses/params/RNG exactly (the pinned
        degeneracy).

        Each buffer fire is one server version: it consumes one global round
        number, one comm-log entry (priced over the M arrivals it
        aggregated) and one telemetry event, exactly like a synchronous
        round. Returns the per-fire buffered monitoring losses
        (``sim`` arrivals that never complete a buffer are absorbed but not
        applied, matching the engine).
        """
        if self.plan is None or not self._is_sparse:
            raise ValueError("run_async needs a sparse federated plan "
                             "(RowSparseTransport)")
        if self.plan.sharding is not None:
            raise ValueError(
                "run_async does not compose with CohortSharding: the event "
                "stream is inherently sequential — run the synchronous "
                "engine on the mesh instead")
        cfg = self.cfg
        srv = (server if server is not None else BufferedAsyncServerUpdate(
            algorithm=self.plan.server.algorithm,
            buffer_size=cfg.clients_per_round))
        key = (srv, self.telemetry_enabled)
        if key not in self._async_engines:
            plan = dataclasses.replace(self.plan, server=srv)
            eng = build_async_engine(plan, self.loss_fn, self.state.params,
                                     cfg, heat_counts=self._heat_counts,
                                     total=self.heat.total,
                                     telemetry=self.telemetry_enabled)
            self._async_engines[key] = (eng, jax.jit(eng.run,
                                                     donate_argnums=(0,)))
        eng, run = self._async_engines[key]

        k = cfg.clients_per_round
        sch = sim.compile(k, srv.buffer_size)
        cohorts, feats = [], []
        for _ in range(sim.num_rounds):
            c, f = self._sample_sparse_cohort()
            cohorts.append(c)
            feats.append(f)
        tasks = {key_: jnp.asarray(np.concatenate(
            [np.asarray(c[key_]) for c in cohorts], axis=0))
            for key_ in cohorts[0]}
        flat_feats = jnp.asarray(np.concatenate(feats, axis=0))
        valid_counts = np.asarray(count_sub_ids(flat_feats,
                                                self.ds.num_features))
        capacity = pow2_capacity(int(valid_counts.max()))
        sub_ids = derive_sub_ids(flat_feats, self.ds.num_features, capacity)

        state0 = eng.init(self.state, num_slots=sch.num_slots,
                          capacity=capacity,
                          heat_ema=(self._async_heat_ema
                                    if srv.heat == "ema" else None))
        self._mark_dispatch(("async", srv, sch.num_events, capacity,
                             sch.num_slots))
        state, ys = run(state0, sch.event_arrays(), tasks, sub_ids,
                        flat_feats if self.telemetry_enabled else None)
        self.state = state.server
        if srv.heat == "ema":
            self._async_heat_ema = state.heat_ema
        self._last_capacity = capacity

        fired = np.flatnonzero(np.asarray(sch.fire))
        losses = np.asarray(ys["loss"])[fired]
        tel_events = (split_rounds(ys["telemetry"], sch.num_events)
                      if "telemetry" in ys else None)
        m = srv.buffer_size
        for f in range(sch.num_fires):
            self._rounds_run += 1
            arrived = sch.arrival_tasks[f * m:(f + 1) * m]
            self._log_sparse_comm(valid_counts[arrived], capacity)
            self._record_telemetry(
                tel_events[fired[f]] if tel_events else None,
                self._rounds_run, comm=self.comm_log[-1])
        return [float(l) for l in losses]

    def _make_central_step(self):
        def central_step(state: ServerState, batches):
            def step(p, batch):
                l, g = jax.value_and_grad(self.loss_fn)(p, batch)
                return tree_add(p, tree_scale(g, -self.cfg.lr)), l

            p, losses = jax.lax.scan(step, state.params, batches)
            return ServerState(p, state.opt, state.rounds + 1), losses.mean()

        return central_step

    # ------------------------------------------------------------------
    def run_round(self) -> float:
        cfg = self.cfg
        self._rounds_run += 1
        if cfg.algorithm == "central":
            batches = pooled_batches(self.ds, cfg.local_iters,
                                     cfg.local_batch * cfg.clients_per_round,
                                     self.np_rng)
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            self._mark_dispatch(("central",))
            self.state, loss = self._central_step(self.state, batches)
            return float(loss)
        if self._is_sparse:
            return self._run_sparse_round()
        ids = self.np_rng.choice(self.ds.num_clients, size=cfg.clients_per_round,
                                 replace=False)
        cohort = sample_cohort_batch(self.ds, ids, cfg.local_iters, cfg.local_batch,
                                     self.np_rng)
        cohort = {k: jnp.asarray(v) for k, v in cohort.items()}
        self._mark_dispatch(("dense",))
        self.state, metrics = self._round_step(self.state, cohort)
        self._record_telemetry(metrics.get("telemetry"), self._rounds_run)
        return float(metrics["loss"])

    def evaluate(self) -> float:
        if self.predict_fn is None:
            return float("nan")
        scores = np.asarray(self.predict_fn(self.state.params, self.ds.test_data))
        labels = self.ds.test_data["label"]
        return auc(labels, scores) if self.metric == "auc" else accuracy(labels, scores)

    def train_loss(self, num_batches: int = 8, batch: int = 256) -> float:
        """Loss over a fixed random sample of the pooled training set."""
        rng = np.random.default_rng(123)
        batches = pooled_batches(self.ds, num_batches, batch, rng)
        tot = 0.0
        for i in range(num_batches):
            b = {k: jnp.asarray(v[i]) for k, v in batches.items()}
            tot += float(self.loss_fn(self.state.params, b))
        return tot / num_batches

    def comm_summary(self) -> Dict[str, float]:
        """Aggregate comm accounting over all sparse rounds so far."""
        from repro.federated.metrics import comm_summary
        return comm_summary(self.comm_log)

    def telemetry_summary(self) -> Dict[str, Any]:
        """Aggregate the per-round telemetry events collected so far."""
        from repro.federated.metrics import telemetry_summary
        return telemetry_summary(self.telemetry_log)

    def run(self, rounds: int, eval_every: int = 10, verbose: bool = False,
            engine: bool = False, profile_dir: Optional[str] = None):
        """Train for ``rounds`` rounds, evaluating every ``eval_every``.

        ``engine=True`` drives each between-evals stretch through
        ``run_rounds`` (the in-jit multi-round scan) instead of one
        ``run_round`` dispatch per round; results are identical to f32
        tolerance.

        Timing is attributed per dispatch: ``RoundRecord.wall_time`` is the
        steady-state mean seconds/round of the stretch (compiling dispatches
        excluded — falling back to the blended mean only when EVERY dispatch
        of the stretch compiled, so it is never zero), and the compile cost
        lands in ``RoundRecord.compile_time`` (zero once the jit caches are
        warm). The same samples feed ``self.timer`` (phases ``"round"``,
        ``"eval"``, ``"train_loss"``).

        ``profile_dir``: wrap the whole call in a ``jax.profiler`` trace
        written under that directory (TensorBoard-loadable), with one
        ``TraceAnnotation`` per dispatched stretch so kernels are
        attributable to training phases.

        ``RoundRecord.round`` numbers continue from the trainer's global
        round counter, so repeated ``run()`` calls (or mixing ``run_round``
        with ``run``) append monotone history instead of colliding with it.
        """
        if profile_dir is not None:
            jax.profiler.start_trace(str(profile_dir))
        try:
            return self._run_chunks(rounds, eval_every, verbose, engine,
                                    annotate=profile_dir is not None)
        finally:
            if profile_dir is not None:
                jax.profiler.stop_trace()

    def _run_chunks(self, rounds: int, eval_every: int, verbose: bool,
                    engine: bool, annotate: bool = False):
        done = 0
        # the engine only exists on the sparse path; dense/central configs
        # fall back to per-round dispatches (where compile attribution is
        # per round, not per chunk)
        use_engine = (engine and self._is_sparse
                      and self.cfg.algorithm != "central")
        while done < rounds:
            chunk = min(eval_every - done % eval_every, rounds - done)
            ctx = (jax.profiler.TraceAnnotation(
                f"rounds[{self._rounds_run}:{self._rounds_run + chunk}]")
                if annotate else contextlib.nullcontext())
            compile_s = 0.0
            steady: List[float] = []

            def account(dt: float, per_round: float):
                nonlocal compile_s
                if self._last_dispatch_compiled:
                    compile_s += dt
                    self.timer.add("round", dt, compile=True)
                else:
                    steady.append(per_round)
                    self.timer.add("round", per_round)

            t0 = time.perf_counter()
            with ctx:
                if use_engine:
                    self.run_rounds(chunk)
                    dt = time.perf_counter() - t0
                    account(dt, dt / chunk)
                else:
                    for _ in range(chunk):
                        t1 = time.perf_counter()
                        self.run_round()
                        dt = time.perf_counter() - t1
                        account(dt, dt)
            total = time.perf_counter() - t0
            wall = sum(steady) / len(steady) if steady else total / chunk
            done += chunk
            if done % eval_every == 0 or done == rounds:
                with self.timer.phase("eval"):
                    metric = self.evaluate()
                with self.timer.phase("train_loss"):
                    tl = self.train_loss()
                rec = RoundRecord(self._rounds_run, tl, metric,
                                  wall_time=wall, compile_time=compile_s)
                if self.comm_log:
                    s = self.comm_summary()
                    rec.bytes_up = s["bytes_up_sparse"]
                    rec.bytes_down = s["bytes_down_sparse"]
                    rec.density = s["mean_density"]
                self.history.append(rec)
                self.sink.emit({"event": "record",
                                **dataclasses.asdict(rec)})
                if verbose:
                    self.sink.report(
                        f"[{self.cfg.algorithm}] round {self._rounds_run}: "
                        f"loss={self.history[-1].train_loss:.4f} "
                        f"{self.metric}={metric:.4f} "
                        f"({wall * 1e3:.1f} ms/round)")
        return self.history
