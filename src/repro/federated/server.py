"""Server orchestration of federated rounds (Algorithm 1, server process).

``FederatedTrainer`` runs the paper's full experimental protocol over a
``FederatedDataset``: samples K clients per round, dispatches local training,
aggregates deltas, applies the configured server algorithm, and tracks train
loss / test metrics. CentralSGD (the paper's non-federated reference) shares
the same interface.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_add, tree_scale
from repro.configs.base import FedConfig
from repro.core.aggregate import HeatSpec
from repro.core.algorithms import ServerState, make_server_algorithm
from repro.core.heat import (HeatStats, estimate_heat_randomized_response,
                             heat_correction_factors)
from repro.data.batching import pooled_batches, sample_cohort_batch
from repro.data.synthetic import FederatedDataset
from repro.federated.client import cohort_deltas, make_local_trainer
from repro.federated.metrics import accuracy, auc
from repro.federated.simulation import heat_spec_from_axes


@dataclass
class RoundRecord:
    round: int
    train_loss: float
    test_metric: float


class FederatedTrainer:
    """End-to-end federated training loop for the paper-scale models."""

    def __init__(self, ds: FederatedDataset, make_params: Callable,
                 loss_fn: Callable, cfg: FedConfig,
                 predict_fn: Optional[Callable] = None,
                 metric: str = "auc", rng_seed: int = 0):
        self.ds = ds
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.predict_fn = predict_fn
        self.metric = metric
        self.np_rng = np.random.default_rng(cfg.seed + rng_seed)

        params = make_params(rng=jax.random.PRNGKey(cfg.seed))
        self.heat = self._resolve_heat(ds, cfg)
        heat_spec = heat_spec_from_axes(params)
        heat_counts = {"vocab": jnp.asarray(self.heat.counts, jnp.float32)}
        total = self.heat.total
        self.alg = make_server_algorithm(cfg, heat_spec=heat_spec,
                                         heat_counts=heat_counts, total=total)
        self.state = self.alg.init(params)

        if cfg.algorithm == "central":
            self._central_step = jax.jit(self._make_central_step())
        else:
            self._round_step = jax.jit(self._make_round_step())
        self.history: List[RoundRecord] = []

    # ------------------------------------------------------------------
    def _resolve_heat(self, ds: FederatedDataset, cfg: FedConfig) -> HeatStats:
        if cfg.heat_estimator == "exact":
            counts, total = ds.heat.counts, ds.heat.total
        elif cfg.heat_estimator == "randomized_response":
            ind = np.zeros((ds.num_clients, ds.num_features), np.int64)
            key = ds.feature_key
            for c in range(ds.num_clients):
                ids = ds.client_data[key][c].reshape(-1)
                ids = ids[ids >= 0]
                ind[c, np.unique(ids)] = 1
                if key == "hist" and "target" in ds.client_data:
                    t = ds.client_data["target"][c].reshape(-1)
                    ind[c, np.unique(t)] = 1
            est = estimate_heat_randomized_response(ind, cfg.rr_flip_prob,
                                                    np.random.default_rng(cfg.seed))
            counts, total = np.clip(est, 0, ds.num_clients), float(ds.num_clients)
        else:  # secure_agg is exact by construction; reuse exact counts
            counts, total = ds.heat.counts, ds.heat.total
        if cfg.weighted:
            # App. D.4: weight clients by local dataset size
            w = ds.sample_counts.astype(np.float64)
            counts = np.zeros(ds.num_features)
            key = ds.feature_key
            for c in range(ds.num_clients):
                ids = ds.client_data[key][c].reshape(-1)
                ids = ids[ids >= 0]
                counts[np.unique(ids)] += w[c]
            total = float(w.sum())
        return HeatStats(counts=np.asarray(counts, np.float64), total=float(total),
                         name="vocab")

    # ------------------------------------------------------------------
    def _make_round_step(self):
        local_train = make_local_trainer(self.loss_fn, self.cfg)

        def round_step(state: ServerState, cohort_batch):
            deltas = cohort_deltas(local_train, state.params, cohort_batch)
            mean_delta = jax.tree.map(lambda d: d.mean(axis=0), deltas)
            new_state = self.alg.apply(state, mean_delta)
            # monitoring loss: first minibatch of each client under old params
            first = jax.tree.map(lambda x: x[:, 0], cohort_batch)
            loss = jax.vmap(lambda b: self.loss_fn(state.params, b))(first).mean()
            return new_state, loss

        return round_step

    def _make_central_step(self):
        def central_step(state: ServerState, batches):
            def step(p, batch):
                l, g = jax.value_and_grad(self.loss_fn)(p, batch)
                return tree_add(p, tree_scale(g, -self.cfg.lr)), l

            p, losses = jax.lax.scan(step, state.params, batches)
            return ServerState(p, state.opt, state.rounds + 1), losses.mean()

        return central_step

    # ------------------------------------------------------------------
    def run_round(self) -> float:
        cfg = self.cfg
        if cfg.algorithm == "central":
            batches = pooled_batches(self.ds, cfg.local_iters,
                                     cfg.local_batch * cfg.clients_per_round,
                                     self.np_rng)
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            self.state, loss = self._central_step(self.state, batches)
            return float(loss)
        ids = self.np_rng.choice(self.ds.num_clients, size=cfg.clients_per_round,
                                 replace=False)
        cohort = sample_cohort_batch(self.ds, ids, cfg.local_iters, cfg.local_batch,
                                     self.np_rng)
        cohort = {k: jnp.asarray(v) for k, v in cohort.items()}
        self.state, loss = self._round_step(self.state, cohort)
        return float(loss)

    def evaluate(self) -> float:
        if self.predict_fn is None:
            return float("nan")
        scores = np.asarray(self.predict_fn(self.state.params, self.ds.test_data))
        labels = self.ds.test_data["label"]
        return auc(labels, scores) if self.metric == "auc" else accuracy(labels, scores)

    def train_loss(self, num_batches: int = 8, batch: int = 256) -> float:
        """Loss over a fixed random sample of the pooled training set."""
        rng = np.random.default_rng(123)
        batches = pooled_batches(self.ds, num_batches, batch, rng)
        tot = 0.0
        for i in range(num_batches):
            b = {k: jnp.asarray(v[i]) for k, v in batches.items()}
            tot += float(self.loss_fn(self.state.params, b))
        return tot / num_batches

    def run(self, rounds: int, eval_every: int = 10, verbose: bool = False):
        for r in range(rounds):
            loss = self.run_round()
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                metric = self.evaluate()
                self.history.append(RoundRecord(r + 1, self.train_loss(), metric))
                if verbose:
                    print(f"[{self.cfg.algorithm}] round {r+1}: "
                          f"loss={self.history[-1].train_loss:.4f} "
                          f"{self.metric}={metric:.4f}")
        return self.history
