"""Pod-scale federated simulation: the jitted round step the dry-run lowers.

``make_round_step`` is the (params, batch) -> (params, metrics) entry point;
since the RoundPlan redesign it is a thin alias layer over
``repro.federated.plan``: every mode string resolves to the RoundPlan
composition that reproduces the historical branch (``resolve_plan``), and
``build_round_step`` compiles it. The four string modes:

``fedsgd`` (default for the big architectures): I = 1 local step, so the
    cohort-mean delta equals ``-lr * grad`` of the cohort-mean loss — no
    per-client model replicas are needed. This is exactly Algorithm 1 with
    I=1; the FedSubAvg correction applies verbatim.
    = RoundPlan(FedSgdLocal(microbatches), DenseTransport(), ...)

``replicated``: true I>1 local SGD with per-client parameter replicas
    (vmap). Memory scales with clients-in-flight x model size, so this is for
    models that fit K replicas (the paper's own models, or ~100M LMs in the
    examples); the dry-run uses fedsgd. This memory wall is real in
    production too — documented in DESIGN.md.
    = RoundPlan(ReplicatedLocal(), DenseTransport(), ...)

``sparse``: fedsgd semantics on the row-sparse update plane — the feature
    table's dense gradient never exists (gather-before-backward).
    = RoundPlan(FedSgdLocal(), RowSparseTransport(), ...)

``sparse_replicated``: the paper's actual protocol — I>1 local SGD where
    each client's replica is its *submodel* only (gathered ``(capacity, D)``
    feature rows + dense leaves), deltas emitted RowSparse. Breaks the
    ``replicated`` memory wall: K * capacity * D instead of K * V * D.
    = RoundPlan(SubmodelReplicatedLocal(), RowSparseTransport(), ...)

``mode`` also accepts a ``RoundPlan`` directly, which opens every other
composition the strings never expressed (e.g. ``RowSparseTransport(topk=8)``
under the fedsgd sparse path). The FedSubAvg correction consults the boxed
parameters' logical axes: any leaf with a "vocab" axis is feature-keyed by
token id; any "experts" axis is keyed by expert id (our beyond-paper
extension of heat to MoE experts).
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.algorithms import ServerState
from repro.federated.plan import (  # noqa: F401 (historical re-exports)
    RoundPlan,
    build_round_step,
    heat_spec_from_axes,
    resolve_plan,
    round_capacity,
    sparse_table_paths,
    split_heat_batch,
)


def make_round_step(loss_fn: Callable, boxed_params_template, cfg: FedConfig,
                    mode: str = "fedsgd", correct: bool = True,
                    feature_key: str = "tokens",
                    telemetry: bool = False) -> Callable:
    """Build the jittable federated round step for pod-scale training.

    round_step(params, batch) -> (new_params, metrics)

    ``batch`` carries the cohort data plus the static heat vectors
    (``heat_vocab``, and ``heat_expert`` for MoE). ``correct=False`` gives the
    FedAvg baseline under the identical execution path. ``mode`` is a legacy
    string alias or an explicit :class:`repro.federated.plan.RoundPlan`;
    both compile through :func:`repro.federated.plan.build_round_step`.

    This entry point is stateless — it threads bare parameters, not a
    ``ServerState`` — so plans with stateful server optimizers (scaffold /
    fedadam) must run under ``FederatedTrainer`` or ``build_round_step``.

    ``telemetry=True`` adds the in-jit observability counters
    (:class:`repro.telemetry.round.RoundTelemetry`) under
    ``metrics["telemetry"]`` without changing losses, parameters, or the
    RNG stream.

    Plans with ``debug_checks=True`` on a sparse transport come back
    already compiled through :func:`repro.analysis.sanitize.checked_jit`
    (the checkify checks need functionalisation) — call the result
    directly, do not wrap it in ``jax.jit`` again.
    """
    plan = resolve_plan(mode, cfg, correct=correct, feature_key=feature_key)
    if not plan.server.stateless:
        raise ValueError(
            f"make_round_step is stateless; ServerUpdate("
            f"{plan.server.algorithm!r}) carries optimizer slots — drive "
            f"this plan through FederatedTrainer or build_round_step")
    step = build_round_step(plan, loss_fn, boxed_params_template, cfg,
                            telemetry=telemetry)
    int8 = getattr(plan.transport, "int8", False)

    def round_step(params, batch):
        # the int8 transport keys its stochastic rounding off
        # ``ServerState.rounds``; this wrapper is stateless, so a constant
        # would draw the SAME rounding noise every round. Seed the counter
        # with a batch fingerprint instead: distinct cohorts draw
        # independent noise (and reruns on the same cohort stay
        # deterministic).
        rounds = jnp.zeros((), jnp.int32)
        if int8:
            entropy = jnp.zeros((), jnp.uint32)
            for k in plan.feature_keys:
                if k in batch:
                    entropy += jnp.sum(batch[k].astype(jnp.uint32))
            # 31 bits: fold_in consumes the value as PRNG data; keep it a
            # valid non-negative int32 counter
            rounds = (entropy & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
        state = ServerState(params, (), rounds)
        new_state, metrics = step(state, batch)
        return new_state.params, metrics

    if plan.debug_checks and plan.transport.sparse:
        from repro.analysis.sanitize import checked_jit
        return checked_jit(round_step)
    return round_step
