"""Pod-scale federated simulation: the jitted round step the dry-run lowers.

At LLM scale a cohort client's local data is one (or a few) sequences and the
cohort is sharded across the ``data`` mesh axis. Four modes:

``fedsgd`` (default for the big architectures): I = 1 local step, so the
    cohort-mean delta equals ``-lr * grad`` of the cohort-mean loss — no
    per-client model replicas are needed. This is exactly Algorithm 1 with
    I=1; the FedSubAvg correction applies verbatim.

``replicated``: true I>1 local SGD with per-client parameter replicas
    (vmap). Memory scales with clients-in-flight x model size, so this is for
    models that fit K replicas (the paper's own models, or ~100M LMs in the
    examples); the dry-run uses fedsgd. This memory wall is real in
    production too — documented in DESIGN.md.

``sparse``: fedsgd semantics on the row-sparse update plane — the feature
    table's dense gradient never exists (gather-before-backward).

``sparse_replicated``: the paper's actual protocol — I>1 local SGD where
    each client's replica is its *submodel* only (gathered ``(capacity, D)``
    feature rows + dense leaves), deltas emitted RowSparse. Breaks the
    ``replicated`` memory wall: K * capacity * D instead of K * V * D.

The FedSubAvg correction consults the boxed parameters' logical axes: any
leaf with a "vocab" axis is feature-keyed by token id; any "experts" axis is
keyed by expert id (our beyond-paper extension of heat to MoE experts).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_add, tree_path_keys, tree_scale
from repro.configs.base import FedConfig
from repro.core.aggregate import HeatSpec, correct_dense_leaf, correct_update_tree
from repro.federated.client import (cohort_deltas, cohort_submodel_deltas,
                                    make_local_trainer,
                                    make_submodel_local_trainer)
from repro.sharding.logical import axes_tree, boxed_like, unbox
from repro.sparse.aggregate import (apply_rowsparse, heat_factor_at,
                                    sparse_cohort_aggregate)
from repro.sparse.encode import (DEFAULT_SPARSE_SPACES, batch_union_ids,
                                 sparse_eligible, submodel_value_and_grad,
                                 tree_leaf_at)
from repro.sparse.rowsparse import is_rowsparse, unique_ids_padded


def heat_spec_from_axes(boxed_params,
                        spaces: Dict[str, str] = None) -> HeatSpec:
    """Derive the HeatSpec from Param logical axes.

    spaces maps logical axis name -> heat space name; default:
    "vocab" axis -> "vocab" space, "experts" axis -> "expert" space.
    """
    spaces = spaces or {"vocab": "vocab", "experts": "expert"}
    axes = axes_tree(boxed_params)

    def is_axes(x):
        return x is None or (isinstance(x, tuple)
                             and all(e is None or isinstance(e, str) for e in x))

    def leaf_space(ax):
        if ax is None:
            return None
        for i, name in enumerate(ax):
            if name in spaces:
                return (spaces[name], i)
        return None

    return HeatSpec(jax.tree.map(leaf_space, axes, is_leaf=is_axes))


def _is_space(x) -> bool:
    return x is None or (isinstance(x, tuple) and len(x) == 2
                         and isinstance(x[0], str) and isinstance(x[1], int))


def sparse_table_paths(heat_spec: HeatSpec, spaces=None):
    """Paths of the leaves that ride the sparse plane (axis-0 feature tables)."""
    if spaces is None:
        spaces = DEFAULT_SPARSE_SPACES
    flat, _ = jax.tree_util.tree_flatten_with_path(heat_spec.leaf_spaces,
                                                   is_leaf=_is_space)
    return [(tree_path_keys(path), space) for path, space in flat
            if sparse_eligible(space, spaces)]


def round_capacity(vocab: int, ids_size: int, align: int = 8) -> int:
    """Union-id capacity for one sparse round step.

    ``min(vocab, ids_size)`` rounded up to a multiple of ``align`` for tiling,
    then clamped back to ``vocab`` — the rounding must never allocate union
    slots past the feature table (e.g. V=50257 would otherwise get 50264
    slots, gathering rows that don't exist in the table's id space).
    """
    cap = min(int(vocab), int(ids_size))
    cap += (-cap) % align
    return min(cap, int(vocab))


def make_round_step(loss_fn: Callable, boxed_params_template, cfg: FedConfig,
                    mode: str = "fedsgd", correct: bool = True,
                    feature_key: str = "tokens") -> Callable:
    """Build the jittable federated round step for pod-scale training.

    round_step(params, batch) -> (new_params, metrics)

    ``batch`` carries the cohort data plus the static heat vectors
    (``heat_vocab``, and ``heat_expert`` for MoE). ``correct=False`` gives the
    FedAvg baseline under the identical execution path.
    """
    heat_spec = heat_spec_from_axes(boxed_params_template)

    def apply_correction(delta, batch):
        if not correct:
            return delta
        counts = {"vocab": batch["heat_vocab"]}
        if "heat_expert" in batch:
            counts["expert"] = batch["heat_expert"]
        # spaces without stats (e.g. expert heat disabled) pass through, factor 1
        return correct_update_tree(delta, heat_spec, counts, float(cfg.num_clients))

    if mode == "fedsgd":
        nmb = max(cfg.microbatches, 1)

        def round_step(params, batch):
            heat = {k: v for k, v in batch.items() if k.startswith("heat_")}
            data = {k: v for k, v in batch.items() if not k.startswith("heat_")}
            if nmb == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, data)
            else:
                # gradient accumulation: cohort split into microbatches so the
                # live activation set stays within HBM at pod scale. The batch
                # axis is keyed on the entry NAME: only "mrope_pos" carries a
                # leading (3,) coordinate axis with batch on axis 1 — keying
                # on shape would misroute any genuine batch-size-3 entry.
                def split(k, x):
                    if x.ndim == 0:
                        return x
                    axis = 1 if k == "mrope_pos" else 0      # mrope (3,B,S)
                    b = x.shape[axis]
                    assert b % nmb == 0, (x.shape, nmb)
                    xs = jnp.moveaxis(x, axis, 0).reshape(
                        (nmb, b // nmb) + x.shape[:axis] + x.shape[axis + 1:])
                    return xs

                # mrope needs its leading 3-axis restored per microbatch
                def restore(k, x):
                    if k == "mrope_pos":
                        return jnp.moveaxis(x, 1, 0)
                    return x

                mb = {k: split(k, v) for k, v in data.items()}

                def acc_step(carry, mbatch):
                    g_acc, l_acc = carry
                    mbatch = {k: restore(k, v) for k, v in mbatch.items()}
                    l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                    g32 = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                    return (g32, l_acc + l), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  jax.tree.map(lambda x: x, params))
                (gsum, lsum), _ = jax.lax.scan(acc_step, (g0, jnp.zeros((), jnp.float32)), mb)
                grads = tree_scale(gsum, 1.0 / nmb)
                loss = lsum / nmb
            delta = tree_scale(grads, -cfg.lr)
            corrected = apply_correction(delta, {**heat})
            new = jax.tree.map(lambda p, c: (p + c.astype(p.dtype) * cfg.server_lr),
                               params, corrected)
            return new, {"loss": loss}

        return round_step

    if mode == "sparse":
        # fedsgd semantics on the sparse update plane: the feature-table
        # update is computed, corrected, and applied in (ids, rows) form —
        # the dense (V, D) delta never exists. Gather-before-backward (the
        # submodel swap in repro.sparse.encode) is used when the model has a
        # single axis-0 feature table, which covers the LM zoo; otherwise
        # dense grads are encoded post-hoc (still exact: lookup-table grads
        # are supported on the batch ids).
        assert cfg.microbatches <= 1, "sparse mode composes with microbatches=1"
        paths = sparse_table_paths(heat_spec)
        if len(paths) != 1:
            # one table <-> one feature key is what keeps this path exact:
            # with several tables the single batch_union_ids could not cover
            # every table's gradient support (FederatedTrainer's sparse path
            # handles multi-key models; it derives ids per client host-side)
            raise ValueError(
                f"sparse mode supports exactly one axis-0 feature table, "
                f"found {len(paths)}: {[p for p, _ in paths]}")
        n_total = float(cfg.num_clients)
        plain_template = unbox(boxed_params_template)
        vocab = int(tree_leaf_at(plain_template, paths[0][0]).shape[0])

        def round_step(params, batch):
            heat = {k: v for k, v in batch.items() if k.startswith("heat_")}
            data = {k: v for k, v in batch.items() if not k.startswith("heat_")}
            tokens = data[feature_key]
            if "labels" not in data and tokens.ndim == 2:
                # pin CE targets to the ORIGINAL token ids before the
                # submodel swap remaps them to row slots (every LM family's
                # loss falls back to next-token targets from batch["tokens"])
                data = {**data,
                        "labels": jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))}
            capacity = round_capacity(vocab, tokens.size)
            ids = batch_union_ids(data, (feature_key,), capacity)
            loss, grads = submodel_value_and_grad(
                loss_fn, params, data, paths[0][0], (feature_key,), ids)

            plain_params = unbox(params)
            plain_grads = unbox(grads)

            def apply_leaf(p, g, space):
                if is_rowsparse(g):
                    if correct:
                        factor = heat_factor_at(heat[f"heat_{space[0]}"],
                                                g.ids, n_total)
                    else:
                        factor = jnp.where(g.ids >= 0, 1.0, 0.0)
                    bshape = factor.shape + (1,) * (g.rows.ndim - 1)
                    rows = (g.rows.astype(jnp.float32)
                            * factor.reshape(bshape) * (-cfg.lr) * cfg.server_lr)
                    safe = jnp.where(g.ids >= 0, g.ids, g.num_rows)
                    return p.at[safe].add(rows.astype(p.dtype), mode="drop")
                delta = g.astype(jnp.float32) * (-cfg.lr)
                if correct:
                    counts = {k[len("heat_"):]: v for k, v in heat.items()}
                    delta = correct_dense_leaf(delta, space, counts, n_total)
                return p + delta.astype(p.dtype) * cfg.server_lr

            new_plain = jax.tree.map(apply_leaf, plain_params, plain_grads,
                                     heat_spec.leaf_spaces)
            new = boxed_like(new_plain, params)
            sub_rows = (ids >= 0).sum()
            metrics = {"loss": loss, "sub_rows": sub_rows,
                       "density": sub_rows / vocab}
            return new, metrics

        return round_step

    if mode == "replicated":
        local_train = make_local_trainer(loss_fn, cfg)

        def round_step(params, batch):
            data = {k: v for k, v in batch.items() if not k.startswith("heat_")}
            deltas = cohort_deltas(local_train, params, data)
            mean_delta = jax.tree.map(lambda d: d.mean(axis=0), deltas)
            corrected = apply_correction(mean_delta, batch)
            new = tree_add(params, tree_scale(corrected, cfg.server_lr))
            first = jax.tree.map(lambda x: x[:, 0], data)
            loss = jax.vmap(lambda b: loss_fn(params, b))(first).mean()
            return new, {"loss": loss}

        return round_step

    if mode == "sparse_replicated":
        # replicated (true I>1 local SGD) on per-client SUBMODEL replicas:
        # each client's replica holds the gathered (capacity, D) rows of the
        # feature tables at its own batch ids plus the dense leaves, so the
        # cohort costs K * capacity * D of feature-table HBM instead of the
        # K * V * D dense-replica wall. Deltas come out RowSparse and feed
        # aggregate_rowsparse directly — the dense (K, V, D) stack and the
        # dense (V, D) mean never exist. Math matches mode="replicated" to
        # f32 tolerance for lookup-table models (tested).
        paths = sparse_table_paths(heat_spec)
        if not paths:
            raise ValueError(
                "sparse_replicated needs at least one axis-0 feature table")
        plain_template = unbox(boxed_params_template)
        vocabs = {int(tree_leaf_at(plain_template, p).shape[0])
                  for p, _ in paths}
        if len(vocabs) != 1:
            # one shared feature-id space is what lets a single per-client
            # sub_ids vector cover every table's gradient support
            raise ValueError(
                f"sparse_replicated feature tables disagree on vocab: {vocabs}")
        vocab = vocabs.pop()
        n_total = float(cfg.num_clients)
        table_paths = [p for p, _ in paths]
        local_train = make_submodel_local_trainer(loss_fn, cfg, table_paths,
                                                  (feature_key,))

        def round_step(params, batch):
            heat = {k: v for k, v in batch.items() if k.startswith("heat_")}
            data = {k: v for k, v in batch.items() if not k.startswith("heat_")}
            tokens = data[feature_key]                       # (K, I, B, ...)
            if "labels" not in data and tokens.ndim == 4:
                # pin CE targets to the ORIGINAL token ids before the
                # submodel gather remaps them to row slots (same rule as
                # mode="sparse")
                data = {**data, "labels": jnp.pad(
                    tokens[..., 1:], ((0, 0), (0, 0), (0, 0), (0, 1)))}
            k = tokens.shape[0]
            per_client = 1
            for d in tokens.shape[1:]:
                per_client *= int(d)
            capacity = round_capacity(vocab, per_client)
            sub_ids = jax.vmap(
                lambda f: unique_ids_padded(f, capacity))(tokens.reshape(k, -1))
            deltas = cohort_submodel_deltas(local_train, params, data, sub_ids)
            counts = {name[len("heat_"):]: v for name, v in heat.items()}
            agg = sparse_cohort_aggregate(deltas, heat_spec, counts, n_total,
                                          k, correct=correct)
            plain = unbox(params)

            def ap(p, u):
                if is_rowsparse(u):
                    return apply_rowsparse(p, u, cfg.server_lr)
                return p + (u * cfg.server_lr).astype(p.dtype)

            new = boxed_like(jax.tree.map(ap, plain, agg), params)
            first = jax.tree.map(lambda x: x[:, 0], data)
            loss = jax.vmap(lambda b: loss_fn(params, b))(first).mean()
            sub_rows = (sub_ids >= 0).sum()
            return new, {"loss": loss, "sub_rows": sub_rows,
                         "density": sub_rows / (k * vocab)}

        return round_step

    raise ValueError(mode)
