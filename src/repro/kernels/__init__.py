"""Pallas TPU kernels for the framework's compute hot-spots.

heat_scatter      -- FedSubAvg's fused aggregate+correct embedding update
rowsparse_scatter -- generalisation to cohort row-sparse deltas (sparse plane)
flash_attention   -- causal GQA flash attention (+ sliding window)
flash_decode      -- single-token decode against long KV caches

Validated in interpret mode on CPU against repro.kernels.ref oracles; on TPU
the real compiled path is selected at runtime.
"""
from repro.kernels.ops import (  # noqa: F401
    flash_attention,
    flash_decode,
    heat_scatter,
    rowsparse_scatter,
)
