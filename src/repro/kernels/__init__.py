"""Pallas TPU kernels for the framework's compute hot-spots.

heat_scatter      -- FedSubAvg's fused aggregate+correct embedding update
rowsparse_scatter -- generalisation to cohort row-sparse deltas (sparse plane)
union_segsum      -- fused union build + segment-sum + heat scaling producing
                     the union-id RowSparse aggregate (sparse server engine)
flash_attention   -- causal GQA flash attention (+ sliding window)
flash_decode      -- single-token decode against long KV caches

Validated in interpret mode on CPU against repro.kernels.ref oracles; on TPU
the real compiled path is selected at runtime.
"""
from repro.kernels.ops import (  # noqa: F401
    flash_attention,
    flash_decode,
    heat_scatter,
    rowsparse_scatter,
    union_segsum,
)
