"""Pallas TPU kernels for the framework's compute hot-spots.

heat_scatter    -- FedSubAvg's fused aggregate+correct embedding update
flash_attention -- causal GQA flash attention (+ sliding window)
flash_decode    -- single-token decode against long KV caches

Validated in interpret mode on CPU against repro.kernels.ref oracles.
"""
from repro.kernels.ops import flash_attention, flash_decode, heat_scatter  # noqa: F401
