"""Pallas TPU kernel: causal GQA flash attention with optional sliding window.

Online-softmax attention tiled as (B*H, q_blocks, k_blocks): each grid step
streams one (BLK_K, hd) K/V tile through VMEM against a resident (BLK_Q, hd)
query tile, maintaining running (m, l, acc) in VMEM scratch. GQA is handled
in the BlockSpec index map (query head h reads KV head h // group_size) — no
materialised K/V repeat. The sliding window adds a lower bound to the same
position mask that enforces causality.

Block sizes default to (512, 512): at hd=128 the working set is
  q 512x128x4B + k/v 2x512x128x4B + acc 512x128x4B + scores 512x512x4B ~ 2.3 MB
well inside a v5e core's 16 MB VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.heat_scatter import VMEM_BUDGET, _tpu_compiler_params

NEG_INF = -1e30


def _block_sizes(sq, sk, blk_q: int, blk_k: int):
    """The (blk_q, blk_k) the kernel actually runs with — the single source
    of the block clamps, shared by ``flash_attention``, its ``fits_vmem``
    guard, and the static auditor so they cannot drift."""
    if sq is not None:
        blk_q = min(blk_q, sq)
    if sk is not None:
        blk_k = min(blk_k, sk)
    return blk_q, blk_k


def vmem_footprint(hd: int, *, sq: int | None = None, sk: int | None = None,
                   blk_q: int = 512, blk_k: int = 512) -> int:
    """Analytic per-program VMEM bytes for ``flash_attention``.

    Double-buffered pipeline blocks (q, k, v in; o out), the (m, l, acc)
    scratch, and the two (blk_q, blk_k) f32 score/prob temporaries.
    """
    blk_q, blk_k = _block_sizes(sq, sk, blk_q, blk_k)
    blocks = 2 * (blk_q * hd + 2 * blk_k * hd + blk_q * hd) * 4
    scratch = (2 * blk_q + blk_q * hd) * 4
    scores = 2 * blk_q * blk_k * 4
    return blocks + scratch + scores


def fits_vmem(hd: int, *, sq: int | None = None, sk: int | None = None,
              blk_q: int = 512, blk_k: int = 512,
              budget: int = VMEM_BUDGET) -> bool:
    """Whether ``flash_attention``'s working set fits the compiled budget."""
    return vmem_footprint(hd, sq=sq, sk=sk, blk_q=blk_q, blk_k=blk_k) <= budget


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, blk_q: int, blk_k: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                       # (BLK_Q, hd)
    k = k_ref[0].astype(jnp.float32)                       # (BLK_K, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qpos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    kpos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 512, blk_k: int = 512, interpret: bool = True):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) -> (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    groups = h // kvh
    blk_q, blk_k = _block_sizes(sq, sk, blk_q, blk_k)
    assert sq % blk_q == 0 and sk % blk_k == 0
    nq, nk = sq // blk_q, sk // blk_k
    scale = 1.0 / float(hd) ** 0.5

    qh = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, hd)

    def kv_index(ibh, iq, ik):
        # query stream ibh = b * h + head; KV stream = b * kvh + head // groups
        bidx = ibh // h
        head = ibh % h
        return (bidx * kvh + head // groups, ik, 0)

    kwargs = {}
    if not interpret:
        # (batch*head, q-block) axes write disjoint output tiles; the
        # k-block axis carries (m, l, acc) scratch and must stay sequential
        cp = _tpu_compiler_params(
            semantics=("parallel", "parallel", "arbitrary"))
        if cp is not None:
            kwargs["compiler_params"] = cp
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          blk_q=blk_q, blk_k=blk_k, nk=nk),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda ibh, iq, ik: (ibh, iq, 0)),
            pl.BlockSpec((1, blk_k, hd), kv_index),
            pl.BlockSpec((1, blk_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda ibh, iq, ik: (ibh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qh, kh, vh)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
