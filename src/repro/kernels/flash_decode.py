"""Pallas TPU kernel: single-token flash decode against a long KV cache.

One query vector per (batch, head) attends to S cached keys streamed through
VMEM in (BLK_S, hd) tiles with running (m, l, acc). Slot positions (absolute
token index per cache slot, -1 = empty) come in as a streamed int tile, so
ring-buffer (sliding-window) caches mask correctly.

The per-shard form of this kernel plus a psum-LSE merge is the seq-sharded
distributed decode path (see repro.models.decode / EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.heat_scatter import VMEM_BUDGET, _tpu_compiler_params

NEG_INF = -1e30


def _block_sizes(s, blk_s: int):
    """The blk_s the kernel actually runs with — the single source of the
    block clamp, shared by ``flash_decode``, its ``fits_vmem`` guard, and
    the static auditor so they cannot drift."""
    if s is not None:
        blk_s = min(blk_s, s)
    return blk_s


def vmem_footprint(hd: int, *, s: int | None = None, blk_s: int = 1024) -> int:
    """Analytic per-program VMEM bytes for ``flash_decode``.

    Double-buffered pipeline blocks (qpos, q, k, v, positions in; o out),
    the (m, l, acc) scratch, and the (1, blk_s) f32 score/prob temporaries.
    """
    blk_s = _block_sizes(s, blk_s)
    blocks = 2 * (1 + hd + 2 * blk_s * hd + blk_s + hd) * 4
    scratch = (2 + hd) * 4
    scores = 2 * blk_s * 4
    return blocks + scratch + scores


def fits_vmem(hd: int, *, s: int | None = None, blk_s: int = 1024,
              budget: int = VMEM_BUDGET) -> bool:
    """Whether ``flash_decode``'s working set fits the compiled budget."""
    return vmem_footprint(hd, s=s, blk_s=blk_s) <= budget


def _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, window: int, blk_s: int, ns: int):
    isb = pl.program_id(1)

    @pl.when(isb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (1, hd)
    k = k_ref[0].astype(jnp.float32)                 # (BLK_S, hd)
    v = v_ref[0].astype(jnp.float32)
    kpos = pos_ref[...]                              # (BLK_S,)
    qpos = qpos_ref[0]

    s = (q @ k.T) * scale                            # (1, BLK_S)
    valid = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(isb == ns - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, k_positions, q_position, *, window: int = 0,
                 blk_s: int = 1024, interpret: bool = True):
    """q: (B, H, hd); caches: (B, KV, S, hd); k_positions: (S,) -> (B, H, hd)."""
    b, h, hd = q.shape
    _, kvh, s, _ = k_cache.shape
    groups = h // kvh
    blk_s = _block_sizes(s, blk_s)
    assert s % blk_s == 0
    ns = s // blk_s
    scale = 1.0 / float(hd) ** 0.5

    qh = q.reshape(b * h, 1, hd)
    kh = k_cache.reshape(b * kvh, s, hd)
    vh = v_cache.reshape(b * kvh, s, hd)
    qpos = jnp.broadcast_to(jnp.asarray(q_position, jnp.int32), (b * h,))

    def kv_index(ibh, isb):
        bidx = ibh // h
        head = ibh % h
        return (bidx * kvh + head // groups, isb, 0)

    kwargs = {}
    if not interpret:
        # the (batch*head) axis writes disjoint outputs; the cache-block
        # axis carries (m, l, acc) scratch and must stay sequential
        cp = _tpu_compiler_params(semantics=("parallel", "arbitrary"))
        if cp is not None:
            kwargs["compiler_params"] = cp
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, blk_s=blk_s, ns=ns),
        grid=(b * h, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda ibh, isb: (ibh,)),
            pl.BlockSpec((1, 1, hd), lambda ibh, isb: (ibh, 0, 0)),
            pl.BlockSpec((1, blk_s, hd), kv_index),
            pl.BlockSpec((1, blk_s, hd), kv_index),
            pl.BlockSpec((blk_s,), lambda ibh, isb: (isb,)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda ibh, isb: (ibh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qpos, qh, kh, vh, k_positions.astype(jnp.int32))
    return out.reshape(b, h, hd)
