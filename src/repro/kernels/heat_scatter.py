"""Pallas TPU kernel: fused FedSubAvg row-sparse aggregation.

The paper's server-side hot path, generalised from token-level embedding
gradients to arbitrary row-sparse deltas: rows ``(T, D)`` tagged with target
ids ``(T,)`` must be (a) scatter-added into the ``(V, D)`` feature table and
(b) scaled by ``scale * N / n_v`` — the cohort-mean factor and the heat
correction (Algorithm 1 line 9) fused into one pass. Token gradients are the
special case where ids repeat per occurrence; cohort row-sparse deltas are
the case where ids repeat once per contributing client.

GPU implementations scatter with atomics; the TPU-native form is a blocked
one-hot matmul — for each (vocab_tile x row_tile) grid cell, build the
(V_BLK, T_BLK) one-hot match matrix in VREGs and accumulate
``one_hot @ rows_block`` on the MXU into the VMEM-resident output tile. The
fused scaling happens in the final row-block iteration, so the corrected
update never round-trips through HBM uncorrected.

Grid: (vocab_tiles, row_tiles); the row dim is the TPU-sequential minor grid
axis, so accumulation into ``out_ref`` across row tiles is well-defined (the
vocab axis is embarrassingly parallel and marked as such for Mosaic).

Backend selection happens at runtime: on TPU the kernel compiles for real
(``interpret=False``); everywhere else it falls back to interpret mode,
which executes the same kernel body and is the CI validation target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.hw import HW

DEFAULT_V_BLK = 512
DEFAULT_T_BLK = 1024

#: VMEM budget (bytes) a kernel's per-program working set must fit for the
#: compiled path: the per-core capacity from ``repro.common.hw`` minus 1/4
#: headroom for Mosaic's own pipeline buffers and compiler scratch. Shared
#: by every kernel guard in this package and by the static auditor
#: (``repro.analysis.kernel_audit``).
VMEM_BUDGET = 3 * HW["vmem_bytes"] // 4


def _kernel(params_ref, ids_ref, rows_ref, heat_ref, out_ref, *,
            v_blk: int, t_blk: int, nt: int):
    iv = pl.program_id(0)
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                                   # (T_BLK,)
    base = iv * v_blk
    vrows = base + jax.lax.broadcasted_iota(jnp.int32, (v_blk, t_blk), 0)
    # padding ids (-1) are < 0 and match no vocab row in any tile
    onehot = (vrows == ids[None, :]).astype(jnp.float32)  # (V_BLK, T_BLK)
    rows = rows_ref[...].astype(jnp.float32)             # (T_BLK, D)
    out_ref[...] += jnp.dot(onehot, rows, preferred_element_type=jnp.float32)

    @pl.when(it == nt - 1)
    def _finalize():
        total = params_ref[0]
        scale = params_ref[1]
        heat = heat_ref[...].astype(jnp.float32)         # (V_BLK,)
        factor = jnp.where(heat > 0, scale * total / jnp.maximum(heat, 1.0), 0.0)
        out_ref[...] *= factor[:, None]


def _pick_blk(dim: int, blk: int) -> int:
    """Largest power-of-two block <= min(blk, dim)."""
    b = 1
    while b * 2 <= min(blk, dim):
        b *= 2
    return b


def _block_sizes(vocab, t, v_blk: int, t_blk: int):
    """The (v_blk, t_blk) the kernel actually runs with — the single source
    of the block adjustments, shared by ``rowsparse_scatter``, its
    ``fits_vmem`` guard, and the static auditor so they cannot drift."""
    if vocab is not None:
        v_blk = _pick_blk(vocab, v_blk)
    if t is not None and t > 0:
        t_blk = min(t_blk, t)
    return v_blk, t_blk


def vmem_footprint(row_elems: int, *, vocab: int | None = None,
                   t: int | None = None, v_blk: int = DEFAULT_V_BLK,
                   t_blk: int = DEFAULT_T_BLK) -> int:
    """Analytic per-program VMEM bytes for ``rowsparse_scatter``.

    Double-buffered pipeline blocks (ids, rows, heat inputs and the output
    tile — its index map varies with the grid), the (v_blk, t_blk) one-hot
    matmul operand, and the SMEM params pair.
    """
    d = max(int(row_elems), 1)
    v_blk, t_blk = _block_sizes(vocab, t, v_blk, t_blk)
    blocks = 2 * (t_blk + t_blk * d + v_blk + v_blk * d) * 4
    onehot = v_blk * t_blk * 4
    smem = 2 * 4
    return blocks + onehot + smem


def fits_vmem(row_elems: int, *, vocab: int | None = None,
              t: int | None = None, v_blk: int = DEFAULT_V_BLK,
              t_blk: int = DEFAULT_T_BLK, budget: int = VMEM_BUDGET) -> bool:
    """Whether ``rowsparse_scatter``'s working set fits the compiled budget."""
    return vmem_footprint(row_elems, vocab=vocab, t=t, v_blk=v_blk,
                          t_blk=t_blk) <= budget


def on_tpu() -> bool:
    """Single source of the runtime backend check for kernel dispatch."""
    return jax.default_backend() == "tpu"


def _tpu_compiler_params(semantics=("parallel", "arbitrary")):
    """Mosaic params for the compiled path; None when unavailable.

    ``semantics`` declares one entry per grid dim. ``heat_scatter``'s vocab
    axis is safe to split across cores ('parallel': its vocab blocks touch
    disjoint output rows); a kernel that carries state across a grid dim
    (e.g. ``union_segsum``'s SMEM union offset) must declare that dim
    'arbitrary' or Megacore partitioning will corrupt it.
    """
    try:
        return pltpu.TPUCompilerParams(dimension_semantics=tuple(semantics))
    except Exception:  # pragma: no cover — jax build without TPUCompilerParams
        return None


def rowsparse_scatter(ids, rows, heat, total: float, vocab: int, *,
                      scale: float = 1.0, v_blk: int = DEFAULT_V_BLK,
                      t_blk: int = DEFAULT_T_BLK, interpret=None):
    """Fused scatter-add + FedSubAvg correction for row-sparse deltas.

    ids: (T,) int32 target rows (-1 pads, dropped); rows: (T, D); heat:
    (vocab,). Returns ``(vocab, D)`` float32 where row v holds
    ``scale * total / heat[v] * sum_{t: ids[t]=v} rows[t]`` (0 if heat 0).

    ``total`` and ``scale`` may be Python floats or traced scalars — they
    reach the kernel through an SMEM operand, so varying them never
    retraces or recompiles. ``interpret=None`` selects the real compiled
    TPU path when running on TPU and the interpreter elsewhere. Neither row
    count nor vocab need align to the block sizes — rows are padded with
    ``-1`` ids (free: they match nothing) and the vocab axis is padded with
    zero-heat rows (which no id targets and the correction zeroes), then
    sliced off.
    """
    if interpret is None:
        interpret = not on_tpu()
    t, d = rows.shape
    if t == 0:
        # an empty grid would never run the kernel body (or its output init)
        return jnp.zeros((vocab, d), jnp.float32)
    v_blk, t_blk = _block_sizes(vocab, t, v_blk, t_blk)
    pad = (-t) % t_blk
    if pad:
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, ids.dtype)])
        rows = jnp.concatenate([rows, jnp.zeros((pad, d), rows.dtype)])
        t += pad
    vpad = (-vocab) % v_blk
    vocab_p = vocab + vpad
    if vpad:
        heat = jnp.concatenate([heat, jnp.zeros((vpad,), heat.dtype)])
    nv, nt = vocab_p // v_blk, t // t_blk
    params = jnp.stack([jnp.asarray(total, jnp.float32),
                        jnp.asarray(scale, jnp.float32)])

    kwargs = {}
    if not interpret:
        # vocab grid axis: disjoint output rows per block, Megacore-safe to
        # split; row axis: sequential accumulation into out_ref
        cp = _tpu_compiler_params(semantics=("parallel", "arbitrary"))
        if cp is not None:
            kwargs["compiler_params"] = cp
    return pl.pallas_call(
        functools.partial(_kernel, v_blk=v_blk, t_blk=t_blk, nt=nt),
        grid=(nv, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((t_blk,), lambda iv, it: (it,)),
            pl.BlockSpec((t_blk, d), lambda iv, it: (it, 0)),
            pl.BlockSpec((v_blk,), lambda iv, it: (iv,)),
        ],
        out_specs=pl.BlockSpec((v_blk, d), lambda iv, it: (iv, 0)),
        out_shape=jax.ShapeDtypeStruct((vocab_p, d), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(params, ids, rows, heat)[:vocab]


def heat_scatter(ids, grads, heat, total: float, vocab: int, *,
                 v_blk: int = DEFAULT_V_BLK, t_blk: int = DEFAULT_T_BLK,
                 interpret=None):
    """Token-gradient aggregation (the original paper hot path).

    ids: (T,) int32 token ids (-1 pads); grads: (T, D); heat: (vocab,).
    Returns the corrected dense update (vocab, D) float32. Token grads are
    row-sparse deltas with per-occurrence duplicate ids, so this is
    ``rowsparse_scatter`` with ``scale=1``.
    """
    return rowsparse_scatter(ids, grads, heat, total, vocab, scale=1.0,
                             v_blk=v_blk, t_blk=t_blk, interpret=interpret)
