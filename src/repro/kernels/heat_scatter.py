"""Pallas TPU kernel: fused FedSubAvg embedding-update aggregation.

The paper's server-side hot path: cohort token-level embedding gradients
(T, D) with token ids (T,) must be (a) scatter-added into vocab rows and
(b) scaled by the heat correction ``N / n_v`` (Algorithm 1 line 9).

GPU implementations scatter with atomics; the TPU-native form is a blocked
one-hot matmul — for each (vocab_tile x token_tile) grid cell, build the
(V_BLK, T_BLK) one-hot match matrix in VREGs and accumulate
``one_hot @ grads_block`` on the MXU into the VMEM-resident output tile. The
heat scaling fuses into the final token-block iteration, so the corrected
update never round-trips through HBM uncorrected.

Grid: (vocab_tiles, token_tiles); token dim is the TPU-sequential minor grid
axis, so accumulation into ``out_ref`` across token tiles is well-defined.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_V_BLK = 512
DEFAULT_T_BLK = 1024


def _kernel(ids_ref, grads_ref, heat_ref, out_ref, *, total: float, v_blk: int,
            t_blk: int, nt: int):
    iv = pl.program_id(0)
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                                   # (T_BLK,)
    base = iv * v_blk
    rows = base + jax.lax.broadcasted_iota(jnp.int32, (v_blk, t_blk), 0)
    onehot = (rows == ids[None, :]).astype(jnp.float32)  # (V_BLK, T_BLK)
    grads = grads_ref[...].astype(jnp.float32)           # (T_BLK, D)
    out_ref[...] += jnp.dot(onehot, grads, preferred_element_type=jnp.float32)

    @pl.when(it == nt - 1)
    def _finalize():
        heat = heat_ref[...].astype(jnp.float32)         # (V_BLK,)
        factor = jnp.where(heat > 0, total / jnp.maximum(heat, 1.0), 0.0)
        out_ref[...] *= factor[:, None]


def heat_scatter(ids, grads, heat, total: float, vocab: int, *,
                 v_blk: int = DEFAULT_V_BLK, t_blk: int = DEFAULT_T_BLK,
                 interpret: bool = True):
    """ids: (T,) int32 (-1 pads); grads: (T, D); heat: (vocab,).

    Returns the corrected dense update (vocab, D) float32.
    """
    t, d = grads.shape
    v_blk = min(v_blk, vocab)
    t_blk = min(t_blk, t)
    assert vocab % v_blk == 0, (vocab, v_blk)
    assert t % t_blk == 0, (t, t_blk)
    nv, nt = vocab // v_blk, t // t_blk

    # padding ids (-1) match no row in any tile, so they drop out naturally
    return pl.pallas_call(
        functools.partial(_kernel, total=float(total), v_blk=v_blk, t_blk=t_blk, nt=nt),
        grid=(nv, nt),
        in_specs=[
            pl.BlockSpec((t_blk,), lambda iv, it: (it,)),
            pl.BlockSpec((t_blk, d), lambda iv, it: (it, 0)),
            pl.BlockSpec((v_blk,), lambda iv, it: (iv,)),
        ],
        out_specs=pl.BlockSpec((v_blk, d), lambda iv, it: (iv, 0)),
        out_shape=jax.ShapeDtypeStruct((vocab, d), jnp.float32),
        interpret=interpret,
    )(ids, grads, heat)
