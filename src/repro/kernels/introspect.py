"""Audit registry: buildable traces + self-reported guards for every kernel.

The static auditor (``repro.analysis.kernel_audit``) needs two things per
Pallas kernel: (a) a way to *capture* the ``pallas_call`` — a traceable
callable plus representative abstract arguments, traced with
``interpret=False`` so the Mosaic ``dimension_semantics`` land in the jaxpr
(tracing needs no TPU; only lowering does) — and (b) the kernel's *own*
account of itself: the ``fits_vmem``/``vmem_footprint`` guard verdict and
the block shapes its ``_block_sizes`` helper predicts, at the same shapes.

The auditor compares (b) against what it reads out of (a). Because each
kernel module routes its runtime block picks through the same
``_block_sizes`` helper the guard uses, any drift between guard and kernel
(the PR-2 ``fits_vmem`` bug class) shows up here as a block-shape or
footprint mismatch — machine-checked for all kernels, not just
``union_segsum``.

Audit shapes are production-representative but fixed: large enough that no
block clamp degenerates (every default block size survives contact with the
shape) yet small enough that the trace is instant on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

# the package __init__ re-exports jitted ops under the same names as the
# modules, so attribute-style imports resolve to the functions; go through
# sys.modules to get the module objects themselves
import sys

import repro.kernels.flash_attention
import repro.kernels.flash_decode
import repro.kernels.heat_scatter
import repro.kernels.union_segsum

_fa = sys.modules["repro.kernels.flash_attention"]
_fd = sys.modules["repro.kernels.flash_decode"]
_hs = sys.modules["repro.kernels.heat_scatter"]
_us = sys.modules["repro.kernels.union_segsum"]


@dataclasses.dataclass(frozen=True)
class GuardReport:
    """What a kernel's own guard predicts at the audit shape."""
    fits: bool           # guard verdict at the audit shape
    footprint: int       # guard's analytic VMEM bytes
    #: operand name -> (index among the pallas_call's inputs+outputs block
    #: mappings, block shape the kernel's _block_sizes helper predicts)
    blocks: dict


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One auditable kernel: how to capture it and what it claims."""
    name: str
    budget: int
    build: Callable      # () -> (fn, args) for jax.make_jaxpr(fn)(*args)
    guard: Callable      # () -> GuardReport at the same audit shape


# -- union_segsum -----------------------------------------------------------
# 16 clients x 656 ids over a 64k vocab, D=64, union capacity 8192: both
# grid dims >1 (nv=128, nt=21) and the row count is deliberately NOT a
# multiple of t_blk so the wrapper's padding path is part of the trace.
_US = dict(V=65536, K=16, R=656, D=64, CAP=8192)


def _build_union_segsum():
    c = _US
    args = (jax.ShapeDtypeStruct((c["K"], c["R"]), jnp.int32),
            jax.ShapeDtypeStruct((c["K"], c["R"], c["D"]), jnp.float32),
            jax.ShapeDtypeStruct((c["V"],), jnp.float32))

    def fn(ids, rows, heat):
        return _us.union_segsum(ids, rows, heat, 64.0, c["CAP"], c["V"],
                                interpret=False)
    return fn, args


def _guard_union_segsum() -> GuardReport:
    c = _US
    t = c["K"] * c["R"]
    v_blk, t_blk = _us._block_sizes(c["V"], t, _us.DEFAULT_V_BLK,
                                    _us.DEFAULT_T_BLK)
    cap_p = c["CAP"] + v_blk
    return GuardReport(
        fits=_us.fits_vmem(c["CAP"], c["D"], num_rows=c["V"], t=t),
        footprint=_us.vmem_footprint(c["CAP"], c["D"], num_rows=c["V"], t=t),
        blocks={"ids": (1, (t_blk,)),
                "rows": (2, (t_blk, c["D"])),
                "heat": (3, (v_blk,)),
                "out_ids": (4, (cap_p, 1)),
                "out_rows": (5, (cap_p, c["D"]))},
    )


# -- rowsparse_scatter ------------------------------------------------------
# 8192 rows into a 64k vocab at D=64: grid (nv=128, nt=8).
_HS = dict(V=65536, T=8192, D=64)


def _build_rowsparse_scatter():
    c = _HS
    args = (jax.ShapeDtypeStruct((c["T"],), jnp.int32),
            jax.ShapeDtypeStruct((c["T"], c["D"]), jnp.float32),
            jax.ShapeDtypeStruct((c["V"],), jnp.float32))

    def fn(ids, rows, heat):
        return _hs.rowsparse_scatter(ids, rows, heat, 64.0, c["V"],
                                     interpret=False)
    return fn, args


def _guard_rowsparse_scatter() -> GuardReport:
    c = _HS
    v_blk, t_blk = _hs._block_sizes(c["V"], c["T"], _hs.DEFAULT_V_BLK,
                                    _hs.DEFAULT_T_BLK)
    return GuardReport(
        fits=_hs.fits_vmem(c["D"], vocab=c["V"], t=c["T"]),
        footprint=_hs.vmem_footprint(c["D"], vocab=c["V"], t=c["T"]),
        blocks={"ids": (1, (t_blk,)),
                "rows": (2, (t_blk, c["D"])),
                "heat": (3, (v_blk,)),
                "out": (4, (v_blk, c["D"]))},
    )


# -- flash_attention --------------------------------------------------------
# 1 sequence, 4 query heads over 2 KV heads (GQA), S=2048, hd=128:
# grid (b*h=4, nq=4, nk=4).
_FA = dict(B=1, H=4, KV=2, S=2048, HD=128)


def _build_flash_attention():
    c = _FA
    args = (jax.ShapeDtypeStruct((c["B"], c["S"], c["H"], c["HD"]),
                                 jnp.float32),
            jax.ShapeDtypeStruct((c["B"], c["S"], c["KV"], c["HD"]),
                                 jnp.float32),
            jax.ShapeDtypeStruct((c["B"], c["S"], c["KV"], c["HD"]),
                                 jnp.float32))

    def fn(q, k, v):
        return _fa.flash_attention(q, k, v, causal=True, interpret=False)
    return fn, args


def _guard_flash_attention() -> GuardReport:
    c = _FA
    blk_q, blk_k = _fa._block_sizes(c["S"], c["S"], 512, 512)
    return GuardReport(
        fits=_fa.fits_vmem(c["HD"], sq=c["S"], sk=c["S"]),
        footprint=_fa.vmem_footprint(c["HD"], sq=c["S"], sk=c["S"]),
        blocks={"q": (0, (1, blk_q, c["HD"])),
                "k": (1, (1, blk_k, c["HD"])),
                "v": (2, (1, blk_k, c["HD"])),
                "o": (3, (1, blk_q, c["HD"]))},
    )


# -- flash_decode -----------------------------------------------------------
# 2 sequences, 4 query heads over 2 KV heads, S=4096 cache, hd=128:
# grid (b*h=8, ns=4).
_FD = dict(B=2, H=4, KV=2, S=4096, HD=128)


def _build_flash_decode():
    c = _FD
    args = (jax.ShapeDtypeStruct((c["B"], c["H"], c["HD"]), jnp.float32),
            jax.ShapeDtypeStruct((c["B"], c["KV"], c["S"], c["HD"]),
                                 jnp.float32),
            jax.ShapeDtypeStruct((c["B"], c["KV"], c["S"], c["HD"]),
                                 jnp.float32),
            jax.ShapeDtypeStruct((c["S"],), jnp.int32))

    def fn(q, kc, vc, kpos):
        return _fd.flash_decode(q, kc, vc, kpos, c["S"] - 1, interpret=False)
    return fn, args


def _guard_flash_decode() -> GuardReport:
    c = _FD
    blk_s = _fd._block_sizes(c["S"], 1024)
    return GuardReport(
        fits=_fd.fits_vmem(c["HD"], s=c["S"]),
        footprint=_fd.vmem_footprint(c["HD"], s=c["S"]),
        blocks={"qpos": (0, (1,)),
                "q": (1, (1, 1, c["HD"])),
                "k": (2, (1, blk_s, c["HD"])),
                "v": (3, (1, blk_s, c["HD"])),
                "pos": (4, (blk_s,)),
                "o": (5, (1, 1, c["HD"]))},
    )


#: Every in-repo Pallas kernel, in audit order. The auditor iterates this;
#: a new kernel module ships by adding its entry here (the auditor's
#: coverage test counts pallas_call sites under repro.kernels and fails if
#: the registry falls behind).
REGISTRY = (
    KernelEntry("union_segsum", _us.VMEM_BUDGET,
                _build_union_segsum, _guard_union_segsum),
    KernelEntry("rowsparse_scatter", _hs.VMEM_BUDGET,
                _build_rowsparse_scatter, _guard_rowsparse_scatter),
    KernelEntry("flash_attention", _fa.VMEM_BUDGET,
                _build_flash_attention, _guard_flash_attention),
    KernelEntry("flash_decode", _fd.VMEM_BUDGET,
                _build_flash_decode, _guard_flash_decode),
)
