"""Jitted public wrappers around the Pallas kernels with platform dispatch.

On TPU the kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body faithfully and is the
validation target for the test suite's oracle sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.heat_scatter import heat_scatter as _heat_scatter
from repro.kernels.heat_scatter import on_tpu as _on_tpu
from repro.kernels.heat_scatter import rowsparse_scatter as _rowsparse_scatter
from repro.kernels.union_segsum import union_segsum as _union_segsum


@functools.partial(jax.jit, static_argnames=("vocab", "v_blk", "t_blk"))
def heat_scatter(ids, grads, heat, total, vocab: int,
                 v_blk: int = 512, t_blk: int = 1024):
    return _heat_scatter(ids, grads, heat, total, vocab, v_blk=v_blk, t_blk=t_blk,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("vocab", "v_blk", "t_blk"))
def rowsparse_scatter(ids, rows, heat, total, vocab: int,
                      scale=1.0, v_blk: int = 512, t_blk: int = 1024):
    """Fused cohort row-sparse aggregation + heat correction (see kernel).

    As with ``union_segsum``, ``total``/``scale`` are traced scalar
    operands — only the shape parameters are static.
    """
    return _rowsparse_scatter(ids, rows, heat, total, vocab, scale=scale,
                              v_blk=v_blk, t_blk=t_blk, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("cap", "num_rows", "v_blk", "t_blk"))
def union_segsum(ids, rows, heat, total, cap: int, num_rows: int,
                 scale=1.0, v_blk: int = 512, t_blk: int = 512):
    """Fused union + segment-sum + heat scaling (see kernel module).

    ``total`` and ``scale`` are traced scalar operands — varying them (e.g.
    across rounds or in a sweep) hits the same compiled kernel; only the
    true shape parameters (``cap``, ``num_rows``, blocks) are static.
    """
    return _union_segsum(ids, rows, heat, total, cap, num_rows, scale=scale,
                         v_blk=v_blk, t_blk=t_blk, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    blk_q: int = 512, blk_k: int = 512):
    return _flash_attention(q, k, v, causal=causal, window=window,
                            blk_q=blk_q, blk_k=blk_k, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("window", "blk_s"))
def flash_decode(q, k_cache, v_cache, k_positions, q_position,
                 window: int = 0, blk_s: int = 1024):
    return _flash_decode(q, k_cache, v_cache, k_positions, q_position,
                         window=window, blk_s=blk_s, interpret=not _on_tpu())
