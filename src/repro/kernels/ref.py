"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the mathematical definition the kernels must match
bit-for-bit (up to accumulation-order fp tolerance); tests sweep shapes and
dtypes against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import decode_attention as _decode_attention
from repro.models.layers import mea_attention as _mea_attention


def heat_scatter_ref(ids, grads, heat, total: float, vocab: int):
    """FedSubAvg embedding aggregation: scatter-add token grads into vocab rows
    and scale row v by total/heat[v] (0 where heat[v] == 0).

    ids: (T,) int32 in [0, vocab) (-1 = padding); grads: (T, D).
    Returns (vocab, D) float32.
    """
    d = grads.shape[-1]
    valid = (ids >= 0).astype(grads.dtype)
    out = jnp.zeros((vocab, d), jnp.float32)
    out = out.at[jnp.maximum(ids, 0)].add((grads * valid[:, None]).astype(jnp.float32),
                                          mode="drop")
    safe = jnp.maximum(heat, 1.0)
    factor = jnp.where(heat > 0, total / safe, 0.0)
    return out * factor[:, None]


def rowsparse_scatter_ref(ids, rows, heat, total: float, vocab: int,
                          scale: float = 1.0):
    """Generalised row-sparse aggregation oracle: ``heat_scatter_ref`` with a
    fused extra ``scale`` factor (the cohort 1/K mean)."""
    return heat_scatter_ref(ids, rows, heat, total, vocab) * scale


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). GQA, optional sliding window."""
    return _mea_attention(q, k, v, causal=causal, window=window,
                          query_chunk=min(q.shape[1], 512),
                          kv_chunk=min(k.shape[1], 512))


def flash_decode_ref(q, k_cache, v_cache, k_positions, q_position, *, window=0):
    """q: (B, H, hd); caches: (B, KV, S, hd)."""
    return _decode_attention(q, k_cache, v_cache, k_positions, q_position,
                             window=window)
