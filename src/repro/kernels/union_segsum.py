"""Pallas TPU kernel: fused union + segment-sum + heat scaling (sparse server).

The FedSubAvg server step over a cohort's row-sparse deltas has three parts:
build the union of the clients' submodel ids, segment-sum the contributed rows
onto those union slots, and scale each slot by ``scale * N / n_m`` (Algorithm
1 line 9, fused with the cohort mean). The jnp backends in
``repro.sparse.aggregate`` express this as a chain of sort/searchsorted (or
bitmap/cumsum) + scatter ops; this kernel does all three in one blocked pass
so the server hot loop issues a single fused program instead of a dispatch
chain.

Layout: grid ``(nv, nt)`` over vocab blocks x row blocks, both sequential on
TPU (row-major), with the vocab axis outer. Per vocab block the kernel

1. accumulates the block's segment-sums as a blocked one-hot MXU matmul
   ``(v_blk, t_blk) @ (t_blk, D)`` into a VMEM scratch accumulator across the
   row blocks (same scheme as ``heat_scatter``), together with per-row match
   counts;
2. on the block's last row tile, applies the fused heat factor, ranks the
   touched rows with an in-block cumsum, compacts them to the front of the
   block through a ``(v_blk, v_blk)`` permutation matmul, and
3. appends the compacted ``(ids, rows)`` window to the output at the running
   union offset (an SMEM carry across vocab blocks) with a dynamic store.

Because vocab blocks are visited in ascending order the emitted union ids are
sorted — the same invariant as ``unique_ids_padded`` — and overflow beyond
``cap`` falls into a ``v_blk`` padding tail that is sliced off, which drops
the largest ids exactly like the sort backend's capacity drop.

The union outputs ``(cap + v_blk,)`` ids and ``(cap + v_blk, D)`` rows stay
VMEM-resident for the whole kernel (constant output index map), so the kernel
targets union capacities that fit VMEM — the regime the sparse plane is for.
``fits_vmem`` is the runtime guard the ``"auto"`` backend selection consults;
beyond it the jnp backends take over. Backend selection mirrors
``heat_scatter``: compiled on TPU, interpret mode elsewhere (the CI parity
target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.heat_scatter import (VMEM_BUDGET, _pick_blk,
                                        _tpu_compiler_params, on_tpu)

DEFAULT_V_BLK = 512
DEFAULT_T_BLK = 512

__all__ = ["union_segsum", "fits_vmem", "vmem_footprint", "VMEM_BUDGET"]


#: Grid dimension semantics for the compiled path. BOTH dims are
#: order-dependent — the SMEM ``carry_ref`` union offset threads across vocab
#: blocks and the VMEM accumulator across row tiles — so neither may be
#: declared 'parallel' (Megacore would split it across cores and corrupt the
#: union). Do not reuse ``heat_scatter``'s default ('parallel', ...) here.
_DIM_SEMANTICS = ("arbitrary", "arbitrary")


def _kernel(params_ref, ids_ref, rows_ref, heat_ref, out_ids_ref, out_rows_ref,
            acc_ref, cnt_ref, carry_ref, *, use_heat: bool, v_blk: int,
            t_blk: int, nt: int, cap: int):
    iv = pl.program_id(0)
    it = pl.program_id(1)

    @pl.when((iv == 0) & (it == 0))
    def _init_out():
        carry_ref[0] = 0
        out_ids_ref[...] = jnp.full_like(out_ids_ref, -1)
        out_rows_ref[...] = jnp.zeros_like(out_rows_ref)

    @pl.when(it == 0)
    def _init_block():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    ids = ids_ref[...]                                     # (t_blk,)
    base = iv * v_blk
    vrows = base + jax.lax.broadcasted_iota(jnp.int32, (v_blk, t_blk), 0)
    # padding ids (-1) are < 0 and match no vocab row in any tile
    onehot = (vrows == ids[None, :]).astype(jnp.float32)   # (v_blk, t_blk)
    rows = rows_ref[...].astype(jnp.float32)               # (t_blk, D)
    # HIGHEST keeps the accumulation in true f32 on TPU (the default MXU
    # bf16 passes would cost ~1e-3 relative error vs the jnp backends)
    acc_ref[...] += jnp.dot(onehot, rows, preferred_element_type=jnp.float32,
                            precision=jax.lax.Precision.HIGHEST)
    cnt_ref[...] += onehot.sum(axis=1)

    @pl.when(it == nt - 1)
    def _emit():
        touched = cnt_ref[...] > 0                         # (v_blk,)
        total = params_ref[0]
        scale = params_ref[1]
        if use_heat:
            heat = heat_ref[...].astype(jnp.float32)
            factor = jnp.where(heat > 0,
                               scale * total / jnp.maximum(heat, 1.0), 0.0)
        else:
            factor = jnp.broadcast_to(scale, (v_blk,)).astype(jnp.float32)
        scaled = acc_ref[...] * factor[:, None]
        rank = jnp.cumsum(touched.astype(jnp.int32)) - 1   # in-block rank
        n_new = jnp.sum(touched.astype(jnp.int32))
        # compact the touched rows to the window front: P[s, v] = 1 iff the
        # touched vocab row v has rank s — a permutation matmul on the MXU
        srange = jax.lax.broadcasted_iota(jnp.int32, (v_blk, v_blk), 0)
        sel = (srange == rank[None, :]) & touched[None, :]   # (slot, vocab)
        win_rows = jnp.dot(sel.astype(jnp.float32), scaled,
                           preferred_element_type=jnp.float32,
                           precision=jax.lax.Precision.HIGHEST)
        # ids stay integer end-to-end: each window slot selects exactly one
        # vocab row, so an int32 max-reduction extracts it exactly at any
        # vocab size (a f32 matmul would corrupt ids >= 2^24)
        vr = base + jax.lax.broadcasted_iota(jnp.int32, (v_blk, v_blk), 1)
        win_ids_m = jnp.max(jnp.where(sel, vr, -1), axis=1)
        slot = jax.lax.broadcasted_iota(jnp.int32, (v_blk, 1), 0)
        win_ids = jnp.where(slot < n_new, win_ids_m[:, None], -1)
        carry = carry_ref[0]
        # clamp: once the union overflows cap, windows land in the padding
        # tail [cap, cap + v_blk) and are sliced off by the wrapper
        offset = jnp.minimum(carry, cap)
        pl.store(out_ids_ref, (pl.ds(offset, v_blk), slice(None)), win_ids)
        pl.store(out_rows_ref, (pl.ds(offset, v_blk), slice(None)), win_rows)
        carry_ref[0] = carry + n_new


def _block_sizes(num_rows, t, v_blk: int, t_blk: int):
    """The (v_blk, t_blk) the kernel actually runs with — the single source
    of the block adjustments, shared by ``union_segsum`` and ``fits_vmem``
    so the ``"auto"`` budget guard and the kernel never drift apart."""
    if num_rows is not None:
        v_blk = _pick_blk(num_rows, v_blk)
    if t is not None and t > 0:
        t_blk = min(t_blk, t)
    return v_blk, t_blk


def vmem_footprint(cap: int, row_elems: int, *, num_rows: int | None = None,
                   t: int | None = None, v_blk: int = DEFAULT_V_BLK,
                   t_blk: int = DEFAULT_T_BLK) -> int:
    """Analytic per-program VMEM bytes for ``union_segsum``.

    Applies the same ``_block_sizes`` adjustments ``union_segsum`` itself
    makes when ``num_rows`` / ``t`` are given, so the ``"auto"`` guard, the
    kernel, and the static auditor agree near the budget boundary.
    """
    d = max(int(row_elems), 1)
    v_blk, t_blk = _block_sizes(num_rows, t, v_blk, t_blk)
    resident = (cap + v_blk) * (d + 1) * 4          # out rows + ids
    # double-buffered pipeline input blocks (ids, rows, heat), scratch
    # accumulators (acc, cnt), and the onehot/sel matmul temporaries
    blocks = (2 * (t_blk + t_blk * d + v_blk)
              + v_blk * d + v_blk
              + v_blk * t_blk + v_blk * v_blk) * 4
    smem = (2 + 1) * 4                               # params pair + carry
    return resident + blocks + smem


def fits_vmem(cap: int, row_elems: int, *, num_rows: int | None = None,
              t: int | None = None, v_blk: int = DEFAULT_V_BLK,
              t_blk: int = DEFAULT_T_BLK, budget: int = VMEM_BUDGET) -> bool:
    """Whether the kernel's VMEM-resident footprint fits the compiled budget."""
    return vmem_footprint(cap, row_elems, num_rows=num_rows, t=t,
                          v_blk=v_blk, t_blk=t_blk) <= budget


def union_segsum(ids, rows, heat, total: float, cap: int, num_rows: int, *,
                 scale: float = 1.0, v_blk: int = DEFAULT_V_BLK,
                 t_blk: int = DEFAULT_T_BLK, interpret=None):
    """Fused union + segment-sum + FedSubAvg scaling over cohort deltas.

    ids: ``(K, R)`` or flat ``(T,)`` int32 feature ids (-1 pads, dropped);
    rows: matching ``(K, R, ...)`` / ``(T, ...)`` payload; heat: ``(num_rows,)``
    or None (factor ``scale`` for every union row). Returns ``(union_ids,
    union_rows)``: sorted-ascending union ids padded with -1 to ``cap`` and
    the summed rows scaled by ``scale * total / n_m`` (0 where heat is 0).
    Ids beyond ``cap`` distinct values are dropped largest-first, matching
    ``unique_ids_padded``.

    ``total`` and ``scale`` may be Python floats or traced scalars — they
    reach the kernel through an SMEM operand, so varying them never
    retraces or recompiles. ``interpret=None`` selects the compiled TPU
    path on TPU and the interpreter elsewhere.
    """
    if interpret is None:
        interpret = not on_tpu()
    ids = jnp.asarray(ids)
    rows = jnp.asarray(rows)
    trailing = tuple(rows.shape[ids.ndim:])      # payload dims beyond the ids'
    ids = ids.reshape(-1).astype(jnp.int32)
    rows = rows.reshape((ids.shape[0], -1))
    t, d = rows.shape
    out_shape = (cap,) + trailing
    if t == 0 or cap == 0:
        return (jnp.full((cap,), -1, jnp.int32),
                jnp.zeros(out_shape, jnp.float32))

    use_heat = heat is not None
    heat = (jnp.asarray(heat, jnp.float32) if use_heat
            else jnp.zeros((num_rows,), jnp.float32))
    v_blk, t_blk = _block_sizes(num_rows, t, v_blk, t_blk)
    pad = (-t) % t_blk
    if pad:
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, ids.dtype)])
        rows = jnp.concatenate([rows, jnp.zeros((pad, d), rows.dtype)])
        t += pad
    vpad = (-num_rows) % v_blk
    v_p = num_rows + vpad
    if vpad:
        # padded vocab rows are matched by no id, so they are never touched
        # and never emitted into the union
        heat = jnp.concatenate([heat, jnp.zeros((vpad,), heat.dtype)])
    nv, nt = v_p // v_blk, t // t_blk
    cap_p = cap + v_blk

    params = jnp.stack([jnp.asarray(total, jnp.float32),
                        jnp.asarray(scale, jnp.float32)])

    kwargs = {}
    if not interpret:
        cp = _tpu_compiler_params(semantics=_DIM_SEMANTICS)
        if cp is not None:
            kwargs["compiler_params"] = cp
    out_ids, out_rows = pl.pallas_call(
        functools.partial(_kernel, use_heat=use_heat, v_blk=v_blk, t_blk=t_blk,
                          nt=nt, cap=cap),
        grid=(nv, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((t_blk,), lambda iv, it: (it,)),
            pl.BlockSpec((t_blk, d), lambda iv, it: (it, 0)),
            pl.BlockSpec((v_blk,), lambda iv, it: (iv,)),
        ],
        out_specs=[
            pl.BlockSpec((cap_p, 1), lambda iv, it: (0, 0)),
            pl.BlockSpec((cap_p, d), lambda iv, it: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap_p, 1), jnp.int32),
            jax.ShapeDtypeStruct((cap_p, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((v_blk, d), jnp.float32),
            pltpu.VMEM((v_blk,), jnp.float32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(params, ids, rows, heat)
    return out_ids[:cap, 0], out_rows[:cap].reshape(out_shape)
