import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST be the first statements in this module —
# before any other import — since jax locks the device count on first init.

_DOC = """Multi-pod dry-run: prove every (architecture x input shape x mesh) lowers,
compiles, fits, and extract the roofline inputs — on 512 placeholder host
devices (the two lines above MUST precede any jax import; jax locks the
device count at first init, which is why this env var is set here and only
here, never in conftest/pyproject).

For each combo we lower + compile the real step function:
    train_4k              -> federated round_step (FedSubAvg, fedsgd mode)
    prefill_32k           -> serve prefill
    decode_32k, long_500k -> serve decode_step (1 token vs seq_len KV cache)
and record ``memory_analysis`` (fits?), ``cost_analysis`` (FLOPs / bytes),
and the collective inventory parsed from optimized HLO (loop-aware, see
repro.launch.hlo). Results land in JSON consumed by benchmarks/roofline.py
and EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        --out results/dryrun
"""

import argparse  # noqa: E402
import gc
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, FedConfig, get_config
from repro.federated.simulation import make_round_step
from repro.launch.hlo import analyze_hlo, cost_analysis_dict
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (shard_batch_sds, shard_cache_sds,
                                    shard_params_sds)
from repro.models import build_model
from repro.sharding.context import clear_rules, param_shardings, set_rules
from repro.sharding.rules import make_rules


def pick_remat_groups(num_layers: int, target: int) -> int:
    """Largest-benefit divisor of L for two-level remat: minimise G + L/G
    among divisors near the target (residual memory ~ (G + L/G) activations)."""
    divisors = [g for g in range(2, num_layers) if num_layers % g == 0]
    if not divisors:
        return 0
    return min(divisors, key=lambda g: (g + num_layers // g, abs(g - target)))


def shape_applicable(cfg, shape_name: str) -> Optional[str]:
    """None if applicable, else the reason for the documented skip."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("long_500k requires a sub-quadratic path; "
                f"{cfg.name} is full-attention (see DESIGN.md shape coverage)")
    return None


def choose_layout(cfg, hbm_budget_gib: float = 6.0) -> str:
    """auto layout: weight-stationary TP when the model-axis shard of the
    parameters fits comfortably; FSDP (d_model over data) otherwise.

    TP keeps weights resident (collectives = per-layer activation psums);
    FSDP re-gathers weights per layer — cheaper memory, far more collective
    bytes (see EXPERIMENTS.md §Perf iteration 6).
    """
    shard_gib = cfg.param_counts()["total"] * 2 / 16 / 2**30
    return "tp" if shard_gib <= hbm_budget_gib else "fsdp"


def build_combo(arch: str, shape_name: str, mesh, multi_pod: bool,
                expert_parallel: bool = False, seq_shard_decode: bool = True,
                query_chunk: int = 256, kv_chunk: int = 512,
                microbatches: int = 8, remat_groups: int = 8,
                layout: str = "fsdp"):
    """Returns (fn, args, out_shardings?) ready to lower under the mesh."""
    cfg = get_config(arch)
    # attention chunking is a launch-time memory/perf knob (see §Perf):
    # scores live set per device = B_dev * H * q_chunk * kv_chunk * 4B
    if remat_groups:
        cfg = cfg.replace(remat_groups=pick_remat_groups(cfg.num_layers, remat_groups))
    cfg = cfg.replace(query_chunk=query_chunk, kv_chunk=kv_chunk)
    if cfg.is_moe and SHAPES[shape_name].kind != "train":
        # scan the MoE dispatch in token chunks for serving: the (E, C, d)
        # dispatch buffers otherwise scale with the full 1M-token prefill
        # (47.8 -> 9.1 GiB for mixtral prefill_32k). Kept OFF for training:
        # measured +50% collective bytes through the chunk-scan backward
        # (§Perf pair C addendum).
        cfg = cfg.replace(moe_token_chunk=8192)
    sc = SHAPES[shape_name]
    api = build_model(cfg)
    rules = make_rules(sc.kind, multi_pod=multi_pod,
                       expert_parallel=expert_parallel,
                       seq_shard_decode=seq_shard_decode)
    if layout == "auto":
        layout = choose_layout(cfg)
    if layout == "fsdp":
        # FSDP: shard the d_model dimension of weights across the data axis so
        # 100B+ configs fit HBM (baseline layout; see EXPERIMENTS.md)
        rules = dict(rules, embed=("data",))
    # attention activation head sharding only when the head counts divide the
    # model axis — partial-head layouts force per-chunk all-reduces (§Perf)
    mdl = mesh.shape["model"]
    rules = dict(rules,
                 heads_act=("model",) if cfg.num_heads % mdl == 0 else None,
                 kv_act=("model",) if (cfg.num_kv_heads % mdl == 0
                                       and cfg.num_heads % mdl == 0) else None)
    set_rules(mesh, rules)

    abstract = api.abstract_params()
    params_sds = shard_params_sds(mesh, rules, abstract)
    batch_sds = shard_batch_sds(mesh, rules, api.input_specs(shape_name))
    # out_shardings mirror the (divisibility-fitted) input shardings
    from repro.sharding.logical import is_param
    p_shardings = jax.tree.map(
        lambda p: p.value.sharding if is_param(p) else p.sharding,
        params_sds, is_leaf=is_param)

    if sc.kind == "train":
        fed = FedConfig(num_clients=1_000_000, clients_per_round=sc.global_batch,
                        local_iters=1, lr=1e-2, algorithm="fedsubavg",
                        microbatches=microbatches)
        step = make_round_step(api.loss, abstract, fed, mode="fedsgd", correct=True)
        fn = jax.jit(step, out_shardings=(p_shardings, None))
        args = (params_sds, batch_sds)
    elif sc.kind == "prefill":
        cache = api.init_cache(sc.global_batch, sc.seq_len, abstract=True)
        cache_sds = shard_cache_sds(mesh, rules, cache)
        # donate the cache: serving updates it in place every step
        fn = jax.jit(api.prefill, donate_argnums=(2,))
        args = (params_sds, batch_sds, cache_sds)
    else:  # decode
        cache = api.init_cache(sc.global_batch, sc.seq_len, abstract=True)
        cache_sds = shard_cache_sds(mesh, rules, cache)
        fn = jax.jit(api.decode_step, donate_argnums=(1,))
        args = (params_sds, cache_sds, batch_sds)
    return cfg, fn, args


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            keep_hlo: bool = False, **build_kw) -> Dict:
    cfg = get_config(arch)
    reason = shape_applicable(cfg, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "multi_pod": multi_pod}
    if reason:
        return dict(base, status="skipped", reason=reason)

    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        cfg, fn, args = build_combo(arch, shape_name, mesh, multi_pod, **build_kw)
        t0 = time.time()
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        mem_info = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_info[k] = int(getattr(mem, k, 0) or 0)
        cost = cost_analysis_dict(compiled)
        hlo_text = compiled.as_text()
        col = analyze_hlo(hlo_text)
        result = dict(
            base,
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem_info,
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            collectives=col.summary(),
            num_devices=mesh.devices.size,
            params_total=cfg.param_counts()["total"],
            params_active=cfg.param_counts()["active"],
        )
        if keep_hlo:
            result["hlo_len"] = len(hlo_text)
        del compiled, lowered, fn
        gc.collect()
        return result
    except Exception as e:
        return dict(base, status="error", error=f"{type(e).__name__}: {e}",
                    trace=traceback.format_exc()[-2000:])
    finally:
        clear_rules()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="disable decode KV seq sharding (baseline ablation)")
    ap.add_argument("--layout", default="fsdp", choices=["fsdp", "tp", "auto"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    combos = []
    arches = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in arches:
        for s in shapes:
            for mp in pods:
                combos.append((a, s, mp))

    results = []
    for a, s, mp in combos:
        r = run_one(a, s, multi_pod=mp, expert_parallel=args.expert_parallel,
                    seq_shard_decode=not args.no_seq_shard, layout=args.layout,
                    microbatches=args.microbatches)
        status = r["status"]
        extra = ""
        if status == "ok":
            per_dev_gb = (r["memory"]["argument_size_in_bytes"]
                          + r["memory"]["temp_size_in_bytes"]) / 2**30
            extra = (f"compile={r['compile_s']}s mem/dev={per_dev_gb:.2f}GiB "
                     f"flops={r['flops']:.3e} coll={r['collectives']['total_collective_bytes']:.3e}B")
        elif status == "error":
            extra = r["error"][:160]
        else:
            extra = r["reason"][:80]
        print(f"[{r['mesh']}] {a:28s} {s:12s} {status:8s} {extra}", flush=True)
        results.append(r)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        path = args.out if args.out.endswith(".json") else args.out + ".json"
        with open(path, "w") as f:
            json.dump(results, f, indent=1)
        print("wrote", path)

    n_err = sum(1 for r in results if r["status"] == "error")
    if n_err:
        raise SystemExit(f"{n_err} combos failed")


if __name__ == "__main__":
    main()
