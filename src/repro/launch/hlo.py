"""Optimized-HLO analysis: collective inventory with loop-aware multipliers.

``compiled.cost_analysis()`` gives FLOPs/bytes but no per-collective detail,
and it counts while-loop bodies ONCE (verified empirically: a 10-iteration
scan of a 128x128 matmul reports ~1 matmul of FLOPs). This module parses the
optimized HLO text into its computation graph, finds every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
incl. async ``-start``/``-done`` pairs), and multiplies ops inside while
bodies by the loop's trip count when XLA recorded one
(``known_trip_count``/``trip_count``). Unresolvable trips are reported with
multiplier 1 and flagged so the roofline layer can apply model-structure
corrections (layer counts, chunk counts).

Byte attribution rules (the contract the hlo_audit oracle depends on):

- only the RESULT shape of a collective is counted — the text between
  `` = `` and the op name. Operand shapes (inside the call parens) are never
  counted, so ``all-gather(f32[1,2,4] %x)`` contributes nothing from ``%x``.
- tuple / variadic results sum their element shapes: a merged variadic
  ``all-reduce`` with result ``(f32[4], f32[8])`` counts both outputs once.
- async pairs are counted ONCE, at the ``-done`` line (whose result is the
  final output shape — the ``-start`` result tuple for gather-like ops
  carries (operand, result) and would double-count). The ``-start`` line is
  still parsed for ``replica_groups``, which XLA attaches to the start form
  only, and the attribute is carried over to the paired ``-done``.
- ``replica_groups`` (explicit ``{{0,1},{2,3}}``, empty ``{}`` = one group of
  all devices, and non-transposed iota ``[G,S]<=[N]``) are parsed onto each
  op so :meth:`HloReport.attribute_axes` can map collectives back to the
  mesh axis that produced them (see :func:`mesh_axis_groups`).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

#: a collective op use-site: the base kind, an optional async suffix, and the
#: opening paren that distinguishes a call from an lhs name like
#: ``%all-gather.1`` (followed by ``.``/`` ``, never ``(``)
_COLLECTIVE_RE = re.compile(
    r"(?<![\w-])(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")

_LHS_RE = re.compile(r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=")

_RG_RE = re.compile(
    r"replica_groups=(\{\{.*?\}\}|\{\}"
    r"|\[[0-9,]+\]<=\[[0-9,]+\](?:T\([0-9,]+\))?)")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jaxlib versions (dict vs [dict])."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_bytes_list(text: str) -> List[int]:
    """Byte sizes of every typed shape literal in a string, in order."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dtype])
    return out


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in a string."""
    return sum(_shape_bytes_list(text))


def _parse_replica_groups(line: str):
    """``replica_groups`` attr -> tuple of device-id groups, or None.

    ``{}`` (all devices, one group) parses to ``()``; a transposed iota
    spec (``...T(1,0)``) parses to None — the op stays unattributed rather
    than attributed wrongly.
    """
    m = _RG_RE.search(line)
    if not m:
        return None
    spec = m.group(1)
    if spec == "{}":
        return ()
    if spec.startswith("{{"):
        groups = []
        for part in spec[2:-2].split("},{"):
            part = part.strip()
            if part:
                groups.append(tuple(int(x) for x in part.split(",") if x.strip()))
        return tuple(groups)
    if "T(" in spec:
        return None
    dims_part, _ = spec.split("<=")
    dims = [int(x) for x in dims_part.strip("[]").split(",")]
    total = 1
    for d in dims:
        total *= d
    size = dims[-1]
    ids = range(total)
    return tuple(tuple(ids[i * size:(i + 1) * size])
                 for i in range(total // size))


@dataclass
class CollectiveOp:
    op: str
    computation: str
    out_bytes: int
    multiplier: int
    resolved: bool
    name: Optional[str] = None
    replica_groups: Optional[Tuple[Tuple[int, ...], ...]] = None
    mesh_axis: Optional[str] = None


@dataclass
class HloReport:
    collectives: List[CollectiveOp] = field(default_factory=list)
    unresolved_loops: int = 0

    def total_bytes(self) -> int:
        return sum(c.out_bytes * c.multiplier for c in self.collectives)

    def by_op(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for c in self.collectives:
            out[c.op] += c.out_bytes * c.multiplier
        return dict(out)

    def by_axis(self) -> Dict[str, int]:
        """Collective bytes keyed by attributed mesh axis ('?' = unknown)."""
        out: Dict[str, int] = defaultdict(int)
        for c in self.collectives:
            out[c.mesh_axis or "?"] += c.out_bytes * c.multiplier
        return dict(out)

    def attribute_axes(self, axis_groups: Dict[str, Tuple[Tuple[int, ...], ...]]):
        """Stamp ``mesh_axis`` on each op whose replica_groups match an axis.

        ``axis_groups`` maps axis name -> device-id groups (see
        :func:`mesh_axis_groups`). Empty parsed groups (``{}``) match any
        axis whose groups form a single group — the all-devices case.
        """
        norm = {name: frozenset(frozenset(g) for g in groups)
                for name, groups in axis_groups.items()}
        for c in self.collectives:
            if c.replica_groups is None:
                continue
            cg = frozenset(frozenset(g) for g in c.replica_groups)
            for name, ng in norm.items():
                if cg == ng or (not c.replica_groups
                                and len(axis_groups[name]) == 1):
                    c.mesh_axis = name
                    break
        return self

    def summary(self) -> Dict:
        return {
            "total_collective_bytes": self.total_bytes(),
            "by_op": self.by_op(),
            "num_ops": len(self.collectives),
            "unresolved_loops": self.unresolved_loops,
        }


def mesh_axis_groups(mesh) -> Dict[str, Tuple[Tuple[int, ...], ...]]:
    """Per-axis device-id groups of a ``jax.sharding.Mesh``.

    For each mesh axis, the groups are the sets of device ids that a
    collective over that axis communicates within — directly comparable to
    a parsed ``replica_groups`` attribute via
    :meth:`HloReport.attribute_axes`.
    """
    import numpy as np

    ids = np.vectorize(lambda d: d.id)(np.asarray(mesh.devices))
    out: Dict[str, Tuple[Tuple[int, ...], ...]] = {}
    for i, name in enumerate(mesh.axis_names):
        moved = np.moveaxis(ids, i, -1).reshape(-1, ids.shape[i])
        out[str(name)] = tuple(tuple(int(x) for x in row) for row in moved)
    return out


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current = None
    for line in text.splitlines():
        stripped = line.strip()
        # computation headers look like `%name (args...) -> type {` (args may
        # contain nested parens for tuples); instruction lines contain " = "
        m = None
        if " = " not in stripped:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$", stripped)
        if m and not stripped.startswith("ROOT"):
            current = m.group(1)
            comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


_CALLEE_RE = re.compile(
    r"(?:body|to_apply|calls)=%?([\w\.\-]+)"
    r"|branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)|trip_count[=:"\s]+(\d+)')


def _scan_collectives(name: str, lines: List[str], mult: int, resolved: bool,
                      out: List[CollectiveOp]) -> None:
    """Collect every collective in one computation's lines into ``out``."""
    # async starts seen so far in this computation, keyed by lhs name:
    # lhs -> (kind, replica_groups, result_region)
    starts: Dict[str, Tuple[str, Optional[tuple], str]] = {}
    for line in lines:
        m = _COLLECTIVE_RE.search(line)
        if m is None:
            continue
        kind, suffix = m.group(1), m.group(2) or ""
        eq = line.find(" = ")
        region = line[eq + 3:m.start()] if 0 <= eq < m.start() else line[:m.start()]
        lm = _LHS_RE.match(line)
        lhs = lm.group(1) if lm else None
        groups = _parse_replica_groups(line)
        if suffix == "-start":
            # replica_groups live on the start form; bytes are counted at the
            # paired -done, whose result is the final output shape (the start
            # result tuple for gather-like ops carries the operand too)
            starts[lhs] = (kind, groups, region)
            continue
        if suffix == "-done":
            om = re.search(r"%([\w\.\-]+)", line[m.end():])
            paired = starts.pop(om.group(1), None) if om else None
            if paired is not None and groups is None:
                groups = paired[1]
        out.append(CollectiveOp(
            op=kind, computation=name, out_bytes=_shape_bytes(region),
            multiplier=mult, resolved=resolved, name=lhs,
            replica_groups=groups))
    # a -start whose -done lives elsewhere (shouldn't happen in optimized
    # HLO, but don't silently drop bytes): count it from the start's own
    # result. For gather-like kinds a 2-tuple result is (operand, result) —
    # count only the result half; variadic all-reduce tuples are all outputs.
    for lhs, (kind, groups, region) in starts.items():
        sizes = _shape_bytes_list(region)
        if kind != "all-reduce" and len(sizes) == 2:
            sizes = sizes[1:]
        out.append(CollectiveOp(
            op=kind, computation=name, out_bytes=sum(sizes),
            multiplier=mult, resolved=resolved, name=lhs,
            replica_groups=groups))


def analyze_hlo(text: str, entry_hint: Optional[str] = None) -> HloReport:
    comps = _split_computations(text)
    # find entry computation name
    entry = entry_hint
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
    if m:
        entry = m.group(1)
    if entry not in comps:
        # fall back: the computation containing no callers
        entry = next(iter(comps)) if comps else None

    report = HloReport()
    if entry is None:
        return report

    # walk the call graph propagating multipliers
    seen: Dict[str, int] = {}

    def walk(name: str, mult: int, resolved: bool):
        if name not in comps:
            return
        if name in seen and seen[name] >= mult:
            return
        if name in seen:
            # re-reached with a larger multiplier (e.g. first called
            # directly, then from inside a counted loop): replace the stale
            # entries instead of double-appending
            report.collectives = [c for c in report.collectives
                                  if c.computation != name]
        seen[name] = mult
        _scan_collectives(name, comps[name], mult, resolved,
                          report.collectives)
        for line in comps[name]:
            is_while = re.search(r"\bwhile\(", line) is not None
            trip = None
            if is_while:
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1) or tm.group(2))
            for cm in _CALLEE_RE.finditer(line):
                if cm.group(1):
                    callees = [cm.group(1)]
                else:
                    callees = [c.strip().lstrip("%") for c in cm.group(2).split(",")]
                for callee in callees:
                    if is_while:
                        if trip is None:
                            report.unresolved_loops += 1
                            walk(callee, mult, False)
                        else:
                            walk(callee, mult * trip, resolved)
                    else:
                        walk(callee, mult, resolved)

    walk(entry, 1, True)
    return report
