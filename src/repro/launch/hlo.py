"""Optimized-HLO analysis: collective inventory with loop-aware multipliers.

``compiled.cost_analysis()`` gives FLOPs/bytes but no per-collective detail,
and it counts while-loop bodies ONCE (verified empirically: a 10-iteration
scan of a 128x128 matmul reports ~1 matmul of FLOPs). This module parses the
optimized HLO text into its computation graph, finds every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
incl. async start forms), and multiplies ops inside while bodies by the
loop's trip count when XLA recorded one (``known_trip_count``/``trip_count``).
Unresolvable trips are reported with multiplier 1 and flagged so the roofline
layer can apply model-structure corrections (layer counts, chunk counts).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jaxlib versions (dict vs [dict])."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in a string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveOp:
    op: str
    computation: str
    out_bytes: int
    multiplier: int
    resolved: bool


@dataclass
class HloReport:
    collectives: List[CollectiveOp] = field(default_factory=list)
    unresolved_loops: int = 0

    def total_bytes(self) -> int:
        return sum(c.out_bytes * c.multiplier for c in self.collectives)

    def by_op(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for c in self.collectives:
            out[c.op] += c.out_bytes * c.multiplier
        return dict(out)

    def summary(self) -> Dict:
        return {
            "total_collective_bytes": self.total_bytes(),
            "by_op": self.by_op(),
            "num_ops": len(self.collectives),
            "unresolved_loops": self.unresolved_loops,
        }


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current = None
    for line in text.splitlines():
        stripped = line.strip()
        # computation headers look like `%name (args...) -> type {` (args may
        # contain nested parens for tuples); instruction lines contain " = "
        m = None
        if " = " not in stripped:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$", stripped)
        if m and not stripped.startswith("ROOT"):
            current = m.group(1)
            comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


_CALLEE_RE = re.compile(
    r"(?:body|to_apply|calls)=%?([\w\.\-]+)"
    r"|branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)|trip_count[=:"\s]+(\d+)')


def analyze_hlo(text: str, entry_hint: Optional[str] = None) -> HloReport:
    comps = _split_computations(text)
    # find entry computation name
    entry = entry_hint
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
    if m:
        entry = m.group(1)
    if entry not in comps:
        # fall back: the computation containing no callers
        entry = next(iter(comps)) if comps else None

    report = HloReport()
    if entry is None:
        return report

    # walk the call graph propagating multipliers
    seen: Dict[str, int] = {}

    def walk(name: str, mult: int, resolved: bool):
        if name not in comps:
            return
        key = name
        if key in seen and seen[key] >= mult:
            return
        seen[key] = mult
        for line in comps[name]:
            for col in _COLLECTIVES:
                if re.search(rf"\b{col}(?:-start)?\(", line):
                    # output shape: text before " = " holds result shape
                    head = line.split(" = ")[-1] if " = " in line else line
                    shape_part = head.split(col)[0]
                    report.collectives.append(CollectiveOp(
                        op=col, computation=name,
                        out_bytes=_shape_bytes(shape_part),
                        multiplier=mult, resolved=resolved))
                    break
            is_while = re.search(r"\bwhile\(", line) is not None
            trip = None
            if is_while:
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1) or tm.group(2))
            for cm in _CALLEE_RE.finditer(line):
                if cm.group(1):
                    callees = [cm.group(1)]
                else:
                    callees = [c.strip().lstrip("%") for c in cm.group(2).split(",")]
                for callee in callees:
                    if is_while:
                        if trip is None:
                            report.unresolved_loops += 1
                            walk(callee, mult, False)
                        else:
                            walk(callee, mult * trip, resolved)
                    else:
                        walk(callee, mult, resolved)

    walk(entry, 1, True)
    return report
