"""Production mesh construction.

A v5e pod here is 256 chips as (data=16, model=16); the multi-pod config is
2 pods = 512 chips with a leading "pod" axis that extends data parallelism
across the inter-pod links (DCN in practice; the dry-run only needs the axis
to shard). Defined as functions so importing this module never touches jax
device state — the dry-run sets XLA_FLAGS *before* any jax initialisation.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.common.hw import HW as _HW


def make_cohort_mesh(num_devices: Optional[int] = None, axis: str = "data"):
    """1-D data mesh for cohort-sharded federated rounds.

    The cohort axis of a federated round is embarrassingly parallel over
    clients; ``CohortSharding(make_cohort_mesh())`` splits it over every
    visible device (or the first ``num_devices``). On CPU, force virtual
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    *before* jax initialises — exactly how the shard-parity tests and the
    sharded bench section run.
    """
    devs = jax.devices()
    n = len(devs) if num_devices is None else int(num_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"num_devices={num_devices} out of range: {len(devs)} devices "
            "visible")
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over the real host devices (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


# re-exported for existing consumers; the constants themselves are
# single-sourced in repro.common.hw (shared with the kernel cost model)
HW = _HW
