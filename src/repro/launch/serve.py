"""Serving launcher: batched prefill + decode loop for any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x22b \
        --scale tiny --batch 4 --prompt 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.train import SCALES
from repro.models import build_model
from repro.sharding.context import set_rules
from repro.sharding.rules import make_rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x22b")
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if SCALES[args.scale]:
        over = dict(SCALES[args.scale])
        if cfg.family == "ssm":
            over.pop("d_ff", None)
        cfg = cfg.replace(**over)

    mesh = make_host_mesh()
    set_rules(mesh, make_rules("decode"))

    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b = args.batch
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, args.prompt),
                                          0, cfg.vocab_size)}
    if cfg.frontend == "audio_frames":
        batch["frames"] = 0.02 * jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                          jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = 0.02 * jnp.ones((b, cfg.num_patches, cfg.d_model),
                                                jnp.dtype(cfg.dtype))
    if cfg.mrope:
        batch["mrope_pos"] = jnp.broadcast_to(jnp.arange(args.prompt),
                                              (3, b, args.prompt)).astype(jnp.int32)

    cache = api.init_cache(b, args.prompt + args.gen)
    logits, cache = jax.jit(api.prefill)(params, batch, cache)
    decode = jax.jit(api.decode_step)
    t0 = time.time()
    toks = []
    for i in range(args.gen):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        db = {"tokens": nxt}
        if cfg.mrope:
            db["mrope_pos"] = jnp.full((3, b, 1), args.prompt + i, jnp.int32)
        logits, cache = decode(params, cache, db)
        toks.append(nxt)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={b} gen={args.gen} "
          f"{dt/args.gen*1e3:.1f} ms/token ({b*args.gen/dt:.1f} tok/s)")
    print("sample:", jnp.stack(toks, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
