"""Sharding assembly for dry-run / launch entry points.

Turns (mesh, rules, abstract values) into NamedSharding trees / sharded
ShapeDtypeStructs for parameters, batches, and the per-family cache types.
Non-divisible dimensions fall back to replication (e.g. whisper's 51866
vocab, the 1500-frame cross-attn cache, batch=1 in long_500k).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import KVCache
from repro.models.whisper import WhisperCache
from repro.models.xlstm_model import XLSTMCache
from repro.models.zamba import ZambaCache
from repro.sharding.context import spec_for_axes
from repro.sharding.logical import Param, is_param


def _axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def _fit_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop partitioning on dims the shape cannot divide (replicate instead)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    fixed = []
    for dim, names in zip(shape, parts):
        if names is not None and dim % _axis_size(mesh, names) != 0:
            names = None
        fixed.append(names)
    return P(*fixed)


def sharded_sds(mesh: Mesh, value, spec: P) -> jax.ShapeDtypeStruct:
    spec = _fit_spec(mesh, spec, value.shape)
    return jax.ShapeDtypeStruct(value.shape, value.dtype,
                                sharding=NamedSharding(mesh, spec))


def shard_params_sds(mesh: Mesh, rules: Dict, boxed_abstract) -> Any:
    """Boxed abstract params -> boxed SDS with shardings attached."""

    def one(p):
        if not is_param(p):
            return sharded_sds(mesh, p, P())
        spec = spec_for_axes(p.axes, rules)
        return Param(sharded_sds(mesh, p.value, spec), p.axes)

    return jax.tree.map(one, boxed_abstract, is_leaf=is_param)


def batch_spec_for(key: str, ndim: int, batch_axes) -> P:
    if key.startswith("heat_vocab"):
        return P("model")
    if key.startswith("heat_"):
        return P(None)
    if key == "mrope_pos":                       # (3, B, S)
        return P(None, batch_axes, *([None] * (ndim - 2)))
    # tokens/labels/mask/frames/patch_embeds: batch-major
    return P(batch_axes, *([None] * (ndim - 1)))


def shard_batch_sds(mesh: Mesh, rules: Dict, batch_specs: Dict) -> Dict:
    batch_axes = rules.get("batch")
    if batch_axes is not None and len(batch_axes) == 1:
        batch_axes = batch_axes[0]
    elif batch_axes is not None:
        batch_axes = tuple(batch_axes)
    out = {}
    for k, v in batch_specs.items():
        out[k] = sharded_sds(mesh, v, batch_spec_for(k, len(v.shape), batch_axes))
    return out


def shard_cache_sds(mesh: Mesh, rules: Dict, cache) -> Any:
    """Cache tree -> SDS tree with shardings. Handles every cache family."""
    batch_axes = rules.get("batch")
    ba = batch_axes[0] if (batch_axes and len(batch_axes) == 1) else \
        (tuple(batch_axes) if batch_axes else None)
    kv_seq = rules.get("kv_seq")
    kv_seq = kv_seq[0] if kv_seq else None

    def kv_spec(x):      # (L/sites, B, KV, S, hd)
        return sharded_sds(mesh, x, P(None, ba, None, kv_seq, None))

    if isinstance(cache, KVCache):
        return KVCache(kv_spec(cache.k), kv_spec(cache.v),
                       sharded_sds(mesh, cache.pos, P()))
    if isinstance(cache, WhisperCache):
        return WhisperCache(kv_spec(cache.k), kv_spec(cache.v),
                            kv_spec(cache.ck), kv_spec(cache.cv),
                            sharded_sds(mesh, cache.pos, P()))
    if isinstance(cache, ZambaCache):
        return ZambaCache(
            sharded_sds(mesh, cache.ssm_state, P(None, ba, "model", None, None)),
            sharded_sds(mesh, cache.conv_state, P(None, ba, None, "model")),
            kv_spec(cache.k), kv_spec(cache.v),
            sharded_sds(mesh, cache.pos, P()),
        )
    if isinstance(cache, XLSTMCache):
        def st(x, spec):
            return sharded_sds(mesh, x, spec)
        m_states = tuple(
            type(s)(st(s.c, P(None, ba, None, "model", None)),
                    st(s.n, P(None, ba, None, "model")),
                    st(s.m, P(None, ba, None)))
            for s in cache.m_states)
        s_states = tuple(
            type(s)(st(s.c, P(None, ba, "model")), st(s.n, P(None, ba, "model")),
                    st(s.h, P(None, ba, "model")), st(s.m, P(None, ba, "model")))
            for s in cache.s_states)
        return XLSTMCache(m_states, s_states, sharded_sds(mesh, cache.pos, P()))
    raise TypeError(type(cache))
