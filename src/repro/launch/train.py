"""Production federated-training launcher.

Builds the mesh (host-sized by default, production 16x16 / 2x16x16 under
--fake-devices for rehearsal), installs sharding rules, constructs the
FedSubAvg round step for the chosen architecture and runs rounds over a
federated corpus. On the real pod this same entry point runs per host under
the usual multi-host jax.distributed bring-up.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_14b \
        --scale tiny --rounds 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import FedConfig, get_config, get_smoke_config
from repro.data import make_lm_federated
from repro.federated import make_round_step
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.sharding.context import set_rules
from repro.sharding.rules import make_rules
from repro.common.pytree import tree_size

SCALES = {
    # overrides applied to the arch config for CPU-runnable scales
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                 head_dim=32, d_ff=256, vocab_size=2048, dtype="float32",
                 query_chunk=64, kv_chunk=64, num_patches=8, encoder_seq=64,
                 encoder_layers=2, mrope_sections=(4, 6, 6)),
    "100m": dict(num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
                 head_dim=64, d_ff=1408, vocab_size=8192, dtype="float32",
                 query_chunk=128, kv_chunk=128, num_patches=16, encoder_seq=128,
                 encoder_layers=8, mrope_sections=(8, 12, 12)),
    "full": {},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_14b")
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=128)
    ap.add_argument("--cohort", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--algorithm", default="fedsubavg")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if SCALES[args.scale]:
        cfg = cfg.replace(**SCALES[args.scale])

    mesh = make_host_mesh()
    set_rules(mesh, make_rules("train"))

    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} scale={args.scale} params={tree_size(params)/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    ds = make_lm_federated(num_clients=args.clients, vocab=cfg.vocab_size,
                           seq_len=args.seq, samples_per_client=4)
    fed = FedConfig(num_clients=ds.num_clients, clients_per_round=args.cohort,
                    lr=args.lr, algorithm=args.algorithm)
    step = jax.jit(make_round_step(api.loss, params, fed, mode="fedsgd",
                                   correct=args.algorithm == "fedsubavg"))
    heat = jnp.asarray(ds.heat.counts, jnp.float32)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for r in range(args.rounds):
        ids = rng.choice(ds.num_clients, size=args.cohort, replace=False)
        sample = rng.integers(0, ds.client_data["tokens"].shape[1], args.cohort)
        toks = ds.client_data["tokens"][ids, sample]
        params, metrics = step(params, {"tokens": jnp.asarray(toks),
                                        "heat_vocab": heat})
        if (r + 1) % 10 == 0:
            # repro-lint: ok traced-float -- host driver loop; the loss sync
            # happens once per 10 rounds for progress reporting
            print(f"round {r+1:4d} loss={float(metrics['loss']):.4f} "
                  f"{(time.time()-t0)/(r+1):.2f}s/round", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.rounds,
                        extra={"arch": cfg.name})
        print("checkpoint:", args.ckpt)


if __name__ == "__main__":
    main()
