from repro.models.api import build_model, ModelApi  # noqa: F401
