"""Uniform model API over the zoo.

``build_model(cfg)`` returns a ``ModelApi`` whose functions have identical
signatures across families, so the federated runtime, the serving path and
the dry-run treat every architecture the same way:

    loss(params, batch)                    -> scalar        (train shapes)
    prefill(params, batch, cache)          -> (logits, cache)
    decode_step(params, cache, batch)      -> (logits, cache)
    input_specs(shape_name)                -> batch dict of ShapeDtypeStruct

``abstract_params()`` builds the parameter tree as ShapeDtypeStructs — the
only way a 123B config exists on this host.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models import xlstm_model as XM
from repro.models import zamba as Z

Array = jax.Array


@dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[[Array], Any]
    abstract_params: Callable[[], Any]
    loss: Callable[[Any, Dict], Array]
    prefill: Callable[[Any, Dict, Any], Any]
    decode_step: Callable[[Any, Any, Dict], Any]
    init_cache: Callable[..., Any]
    input_specs: Callable[[str], Dict]


def _sds(shape: Sequence[int], dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _common_specs(cfg: ModelConfig, sc: ShapeConfig, kind: str) -> Dict:
    b, s = sc.global_batch, sc.seq_len
    emb_dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, Any] = {}
    if kind == "decode":
        specs["tokens"] = _sds((b,), jnp.int32)
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
    if kind == "train":
        specs["labels"] = _sds((b, s), jnp.int32)
        specs["mask"] = _sds((b, s), jnp.float32)
    if cfg.frontend == "vision_patches" and kind != "decode":
        specs["patch_embeds"] = _sds((b, cfg.num_patches, cfg.d_model), emb_dt)
    if cfg.frontend == "audio_frames":
        specs["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), emb_dt)
    if cfg.mrope:
        seq = 1 if kind == "decode" else s
        specs["mrope_pos"] = _sds((3, b, seq), jnp.int32)
    if kind == "train":
        # static heat statistics consumed by the FedSubAvg correction
        specs["heat_vocab"] = _sds((cfg.vocab_size,), jnp.float32)
        if cfg.is_moe:
            specs["heat_expert"] = _sds((cfg.num_experts,), jnp.float32)
    return specs


def build_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        make, loss = T.make_params, T.loss_fn
        init_cache = lambda b, s, abstract=False: T.init_cache(cfg, b, s, abstract)

        def prefill(params, batch, cache):
            return T.prefill(cfg, params, batch["tokens"], cache,
                             patch_embeds=batch.get("patch_embeds"),
                             mrope_pos=batch.get("mrope_pos"))

        def decode_step(params, cache, batch):
            return T.decode_step(cfg, params, cache, batch["tokens"],
                                 mrope_pos=batch.get("mrope_pos"))

    elif fam == "hybrid":
        make, loss = Z.make_params, Z.loss_fn
        init_cache = lambda b, s, abstract=False: Z.init_cache(cfg, b, s, abstract)

        def prefill(params, batch, cache):
            return Z.prefill(cfg, params, batch["tokens"], cache)

        def decode_step(params, cache, batch):
            return Z.decode_step(cfg, params, cache, batch["tokens"])

    elif fam == "ssm":
        make, loss = XM.make_params, XM.loss_fn
        init_cache = lambda b, s, abstract=False: XM.init_cache(cfg, b, s, abstract)

        def prefill(params, batch, cache):
            return XM.prefill(cfg, params, batch["tokens"], cache)

        def decode_step(params, cache, batch):
            return XM.decode_step(cfg, params, cache, batch["tokens"])

    elif fam == "audio":
        make, loss = W.make_params, W.loss_fn
        init_cache = lambda b, s, abstract=False: W.init_cache(cfg, b, s, abstract)

        def prefill(params, batch, cache):
            return W.prefill(cfg, params, batch["tokens"], batch["frames"], cache)

        def decode_step(params, cache, batch):
            return W.decode_step(cfg, params, cache, batch["tokens"])

    else:
        raise ValueError(f"unknown family {fam!r}")

    def input_specs(shape_name: str) -> Dict:
        sc = SHAPES[shape_name]
        return _common_specs(cfg, sc, sc.kind)

    return ModelApi(
        cfg=cfg,
        init=lambda rng: make(cfg, rng=rng, abstract=False),
        abstract_params=lambda: make(cfg, rng=None, abstract=True),
        loss=lambda params, batch: loss(cfg, params, batch),
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        input_specs=input_specs,
    )
