"""Shared neural building blocks for the model zoo.

Everything here is pure-jnp and shape-polymorphic; attention is the chunked
memory-efficient (online-softmax) formulation that doubles as the oracle for
the Pallas flash kernels. Parameter construction uses ``ParamFactory`` so that
every weight carries its logical sharding axes.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding.context import constrain
from repro.sharding.logical import ParamFactory

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms / projections
# ---------------------------------------------------------------------------


def make_rmsnorm(pf: ParamFactory, d: int, stack: int = 0):
    return {"scale": pf((d,), ("embed",), init="ones", dtype=jnp.float32, stack=stack)}


def rmsnorm(p, x, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def head_rmsnorm(scale, x, eps: float = 1e-5) -> Array:
    """QK-norm: rmsnorm over the head_dim axis (qwen3)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * lax.rsqrt(var + eps)) * scale).astype(x.dtype)


def make_linear(pf: ParamFactory, d_in: int, d_out: int, axes: Tuple, bias: bool = False,
                stack: int = 0):
    p = {"w": pf((d_in, d_out), axes, init="fan_in", stack=stack)}
    if bias:
        p["b"] = pf((d_out,), (axes[-1],), init="zeros", stack=stack)
    return p


def linear(p, x) -> Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float, sections: Tuple[int, ...]) -> Array:
    """Qwen2-VL multi-dimensional RoPE.

    ``positions3``: (3, ..., seq) — temporal/height/width position ids. The
    head_dim/2 frequency slots are partitioned into ``sections`` (t, h, w);
    each slot takes its angle from the corresponding position stream.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                               # (hd/2,)
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=hd // 2)
    # angles per stream: (3, ..., seq, hd/2); select each slot's stream
    angles_all = positions3[..., None].astype(jnp.float32) * freqs
    sel = jax.nn.one_hot(sec_ids, 3, axis=0, dtype=jnp.float32)  # (3, hd/2)
    sel = sel.reshape((3,) + (1,) * (angles_all.ndim - 2) + (hd // 2,))
    angles = (angles_all * sel).sum(axis=0)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    """Whisper-style fixed sinusoidal position embeddings."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# Memory-efficient attention (online softmax) — the Pallas kernel oracle
# ---------------------------------------------------------------------------


def _chunk_scan(q, k, v, q_positions, k_positions, causal, window, scale,
                k_limit=None):
    """One q-chunk against all kv chunks with a running (m, l, acc)."""
    bq, h, cq, hd = q.shape
    num_kv = k.shape[2]

    def body(carry, kv_chunk):
        m, l, acc = carry
        kc, vc, kpos = kv_chunk
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kc, preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((cq, kc.shape[2]), bool)
        if k_limit is not None:
            mask &= (kpos[None, :] < k_limit)
        if causal:
            mask &= kpos[None, :] <= q_positions[:, None]
        if window > 0:
            mask &= kpos[None, :] > q_positions[:, None] - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + p.sum(axis=-1)
        acc_new = acc * correction[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((bq, h, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, h, cq), jnp.float32)
    acc0 = jnp.zeros((bq, h, cq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, acc0), (k, v, k_positions))
    return acc / jnp.maximum(l, 1e-30)[..., None]


def mea_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset=0,
    query_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Array:
    """Chunked flash attention in pure jnp.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H a multiple of KV (GQA).
    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    Memory is O(chunk^2) instead of O(S^2) — this is what lets the 88-layer
    x 4k-seq train configs fit, and it is bit-matched by the Pallas kernel.
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    groups = h // kvh
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    cq = min(query_chunk, sq)
    ck = min(kv_chunk, sk)
    # pad ragged sequence lengths up to the chunk grid; padded kv positions
    # are pushed past every real query so the causal mask removes them, and
    # padded query rows are sliced off the output
    sq_pad = (-sq) % cq
    sk_pad = (-sk) % ck
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
    sq_full, sk_full = sq + sq_pad, sk + sk_pad
    nq, nk = sq_full // cq, sk_full // ck

    # (B, H, S, hd) layout, GQA via repeat of kv heads
    qh = q.transpose(0, 2, 1, 3)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), groups, axis=1)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), groups, axis=1)

    kh = kh.reshape(b, h, nk, ck, hd).transpose(2, 0, 1, 3, 4)   # (nk, B, H, ck, hd)
    vh = vh.reshape(b, h, nk, ck, hd).transpose(2, 0, 1, 3, 4)
    kpos = (jnp.arange(sk_full)).reshape(nk, ck)
    k_limit = sk if sk_pad else None

    def per_q_chunk(iq):
        qc = lax.dynamic_slice_in_dim(qh, iq * cq, cq, axis=2)
        qpos = q_offset + iq * cq + jnp.arange(cq)
        return _chunk_scan(qc, kh, vh, qpos, kpos, causal, window, scale, k_limit)

    # checkpoint per q-chunk: the backward otherwise stacks every chunk's
    # probability matrix (full S^2 scores in f32); rematerialising per chunk
    # caps the attention backward working set at one (cq x ck) tile
    per_q_chunk = jax.checkpoint(per_q_chunk, prevent_cse=False)
    out = lax.map(per_q_chunk, jnp.arange(nq))                   # (nq, B, H, cq, hd)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sq_full, h, hd)
    if sq_pad:
        out = out[:, :sq]
    return out.astype(q.dtype)


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0, **_):
    """Quadratic reference (small shapes only)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qh = q.reshape(b, sq, kvh, h // kvh, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qh, k, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(hd)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, hd)


def decode_attention(q, k_cache, v_cache, k_positions, q_position, *, window: int = 0) -> Array:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    q: (B, H, hd); caches: (B, KV, S, hd); k_positions: (S,) absolute positions
    of each cache slot (-1 for empty). Pure jnp; the sharded flash-decode path
    wraps this per-shard with an LSE merge (repro.models.decode).
    """
    b, h, hd = q.shape
    kvh = k_cache.shape[1]
    qh = q.reshape(b, kvh, h // kvh, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qh, k_cache, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(hd)
    valid = (k_positions >= 0) & (k_positions <= q_position)
    if window > 0:
        valid &= k_positions > q_position - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, h, hd)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Per-layer-stacked KV cache.

    k, v: (L, B, KV, S, hd). ``S`` is the full max length for dense attention
    or the window size for SWA (ring buffer). ``pos``: scalar int32, number of
    tokens already written.
    """

    k: Array
    v: Array
    pos: Array

    @property
    def capacity(self) -> int:
        return self.k.shape[3]


def make_kv_cache(num_layers, batch, kv_heads, capacity, head_dim, dtype=jnp.bfloat16,
                  abstract=False) -> KVCache:
    shape = (num_layers, batch, kv_heads, capacity, head_dim)
    if abstract:
        arr = jax.ShapeDtypeStruct(shape, dtype)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return KVCache(arr, arr, pos)
    z = jnp.zeros(shape, dtype)
    return KVCache(z, z, jnp.zeros((), jnp.int32))


def cache_slot_positions(pos: Array, capacity: int, ring: bool) -> Array:
    """Absolute position held by each cache slot (-1 if empty)."""
    idx = jnp.arange(capacity)
    if not ring:
        return jnp.where(idx < pos, idx, -1)
    # ring: slot i holds position p = last write to that slot
    p = pos - 1 - ((pos - 1 - idx) % capacity)
    return jnp.where((p >= 0) & (p < pos), p, -1)


def cache_write(k_layer: Array, v_layer: Array, pos: Array, k_new: Array, v_new: Array,
                ring: bool) -> Tuple[Array, Array]:
    """Write one token's K/V (B, KV, hd) at position ``pos`` (mod cap if ring).

    Implemented as a predicated elementwise select on the sequence axis
    rather than dynamic_update_slice: a DUS at a traced offset on a SHARDED
    seq axis triggers SPMD "involuntary full rematerialization" (the cache is
    replicated per device, ~17 GiB/layer at deepseek decode_32k scale). The
    select shards elementwise and updates in place under buffer donation.
    """
    cap = k_layer.shape[2]
    slot = (pos % cap) if ring else pos
    hit = (jnp.arange(cap) == slot)[None, None, :, None]
    k_layer = jnp.where(hit, k_new[:, :, None].astype(k_layer.dtype), k_layer)
    v_layer = jnp.where(hit, v_new[:, :, None].astype(v_layer.dtype), v_layer)
    return k_layer, v_layer


# ---------------------------------------------------------------------------
# Gated MLP + MoE
# ---------------------------------------------------------------------------


def make_mlp(pf: ParamFactory, d: int, ff: int, stack: int = 0):
    return {
        "wi": pf((d, ff), ("embed", "ffn"), stack=stack),
        "wg": pf((d, ff), ("embed", "ffn"), stack=stack),
        "wo": pf((ff, d), ("ffn", "embed"), stack=stack),
    }


def mlp(p, x) -> Array:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


def make_moe(pf: ParamFactory, d: int, ff: int, num_experts: int, stack: int = 0):
    return {
        "router": pf((d, num_experts), ("embed", "experts"), stack=stack),
        "wi": pf((num_experts, d, ff), ("experts", "embed", "ffn"), stack=stack),
        "wg": pf((num_experts, d, ff), ("experts", "embed", "ffn"), stack=stack),
        "wo": pf((num_experts, ff, d), ("experts", "ffn", "embed"), stack=stack),
    }


class MoEStats(NamedTuple):
    aux_loss: Array          # load-balance loss (Switch-style)
    expert_tokens: Array     # (E,) tokens routed per expert (pre-capacity)


def moe(p, x, *, num_experts: int, top_k: int, capacity_factor: float,
        deterministic_capacity: int = 0, token_chunk: int = 0) -> Tuple[Array, MoEStats]:
    """Dropping MoE with scatter-based dispatch (TPU-friendly, no (T,E,C) one-hot).

    x: (B, S, d). Tokens pick top-k experts; each expert processes at most
    C = ceil(k*T*cf/E) tokens per (B*S) block; overflow tokens are dropped
    (their combine weight contribution is zero), matching the standard
    capacity-based TPU MoE formulation.

    ``token_chunk``: process tokens in chunks of this many (per batch row
    group) through a scanned dispatch — the (E, C, d) buffers then scale with
    the chunk, not the full sequence (capacity becomes per-chunk; same
    dropping policy at finer granularity). This is the §Perf fix for the
    prefill-scale dispatch-buffer blowup.
    """
    b, s, d = x.shape
    if token_chunk and b * s > token_chunk and (b * s) % token_chunk == 0:
        nc = (b * s) // token_chunk
        chunks = x.reshape(nc, token_chunk, d)

        def one(xc):
            y, stats = moe(p, xc[None], num_experts=num_experts, top_k=top_k,
                           capacity_factor=capacity_factor,
                           deterministic_capacity=deterministic_capacity)
            return y[0], stats

        ys, stats = lax.map(one, chunks)
        out = ys.reshape(b, s, d)
        return out, MoEStats(stats.aux_loss.mean(), stats.expert_tokens.sum(0))
    t = b * s
    xt = x.reshape(t, d)
    e = num_experts

    logits = (xt @ p["router"]).astype(jnp.float32)              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, top_k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch/Mixtral): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                       # (E,)
    onehot_top1 = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    fe = onehot_top1.mean(axis=0)
    aux = e * jnp.sum(fe * me)

    cap = deterministic_capacity or int(max(1, capacity_factor * top_k * t / e))

    # flatten (token, k) assignments
    flat_exp = expert_ids.reshape(-1)                             # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)

    # position of each assignment within its expert, via cumsum over one-hot
    onehot = jax.nn.one_hot(flat_exp, e, dtype=jnp.int32)         # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
    keep = pos_in_expert < cap
    expert_tokens = onehot.sum(axis=0)

    # dispatch: (E, C, d) buffer
    safe_pos = jnp.where(keep, pos_in_expert, 0)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_exp, safe_pos].add(
        jnp.where(keep, 1.0, 0.0)[:, None].astype(x.dtype) * xt[flat_tok], mode="drop"
    )

    # expert computation, batched einsum over experts
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wi"]
    )
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"])                    # (E, C, d)

    # combine: gather back and weight
    gathered = y[flat_exp, safe_pos]                              # (T*k, d)
    weighted = gathered * (flat_gate * keep).astype(y.dtype)[:, None]
    out = jnp.zeros((t, d), y.dtype).at[flat_tok].add(weighted)
    return out.reshape(b, s, d), MoEStats(aux, expert_tokens)
