"""The paper's evaluation models: LR (MovieLens rating), LSTM (Sent140
sentiment), DIN (Amazon/Alibaba CTR).

These are the models FedSubAvg was originally validated on — small, sparse-
embedding-dominated, exactly the hot/cold-feature regime. Each exposes the
same (make_params, loss_fn, predict_fn) surface; feature-keyed leaves carry
the "vocab" logical axis so the heat machinery applies unchanged.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.logical import ParamFactory, unbox

Array = jax.Array


def _bce(logit, label):
    return jnp.maximum(logit, 0) - logit * label + jnp.log1p(jnp.exp(-jnp.abs(logit)))


# ---------------------------------------------------------------------------
# LR over sparse one-hot features (MovieLens rating classification)
# ---------------------------------------------------------------------------


def make_lr_params(num_features: int, rng=None, abstract: bool = False):
    pf = ParamFactory(rng=rng, abstract=abstract, dtype=jnp.float32)
    return {
        "w": pf((num_features, 1), ("vocab", "embed"), init="zeros", dtype=jnp.float32),
        "b": pf((1,), (None,), init="zeros", dtype=jnp.float32),
    }


def lr_logits(params, feature_ids: Array) -> Array:
    """feature_ids: (B, F) int32 active feature ids (-1 = padding)."""
    p = unbox(params)
    w = p["w"][..., 0]
    valid = (feature_ids >= 0).astype(jnp.float32)
    vals = w[jnp.maximum(feature_ids, 0)] * valid
    return vals.sum(-1) + p["b"][0]


def lr_loss(params, batch: Dict) -> Array:
    logit = lr_logits(params, batch["features"])
    per = _bce(logit, batch["label"].astype(jnp.float32))
    m = batch.get("sample_mask", jnp.ones_like(per))
    return (per * m).sum() / jnp.maximum(m.sum(), 1.0)


# ---------------------------------------------------------------------------
# Two-layer LSTM classifier (Sent140)
# ---------------------------------------------------------------------------


def make_lstm_params(vocab: int, emb_dim: int = 25, hidden: int = 100,
                     layers: int = 2, rng=None, abstract: bool = False):
    pf = ParamFactory(rng=rng, abstract=abstract, dtype=jnp.float32)
    cells = []
    for i in range(layers):
        d_in = emb_dim if i == 0 else hidden
        cells.append({
            "wx": pf((d_in, 4 * hidden), ("embed", "ffn"), dtype=jnp.float32),
            "wh": pf((hidden, 4 * hidden), (None, "ffn"), dtype=jnp.float32),
            "b": pf((4 * hidden,), ("ffn",), init="zeros", dtype=jnp.float32),
        })
    return {
        "embedding": pf((vocab, emb_dim), ("vocab", "embed"), init="normal", dtype=jnp.float32),
        "cells": tuple(cells),
        "head_w": pf((hidden, 1), (None, None), dtype=jnp.float32),
        "head_b": pf((1,), (None,), init="zeros", dtype=jnp.float32),
    }


def _lstm_layer(cell, xs, mask):
    """xs: (B, S, d_in); mask: (B, S). Standard LSTM, masked steps carry state."""
    b, s, _ = xs.shape
    hdim = cell["wh"].shape[0]

    def step(carry, inp):
        h, c = carry
        x_t, m_t = inp
        z = x_t @ cell["wx"] + h @ cell["wh"] + cell["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        keep = m_t[:, None]
        return (h_new * keep + h * (1 - keep), c_new * keep + c * (1 - keep)), h_new

    init = (jnp.zeros((b, hdim)), jnp.zeros((b, hdim)))
    (h, _), hs = lax.scan(step, init, (xs.transpose(1, 0, 2), mask.T))
    return h, hs.transpose(1, 0, 2)


def lstm_logits(params, tokens: Array, mask: Array) -> Array:
    p = unbox(params)
    x = p["embedding"][jnp.maximum(tokens, 0)] * (tokens >= 0)[..., None]
    for cell in p["cells"]:
        h, x = _lstm_layer(cell, x, mask)
    return (h @ p["head_w"])[:, 0] + p["head_b"][0]


def lstm_loss(params, batch: Dict) -> Array:
    mask = (batch["tokens"] >= 0).astype(jnp.float32)
    logit = lstm_logits(params, batch["tokens"], mask)
    per = _bce(logit, batch["label"].astype(jnp.float32))
    m = batch.get("sample_mask", jnp.ones_like(per))
    return (per * m).sum() / jnp.maximum(m.sum(), 1.0)


# ---------------------------------------------------------------------------
# DIN (Deep Interest Network) for CTR prediction
# ---------------------------------------------------------------------------


def make_din_params(num_items: int, emb_dim: int = 18, hidden: int = 36,
                    rng=None, abstract: bool = False):
    pf = ParamFactory(rng=rng, abstract=abstract, dtype=jnp.float32)
    return {
        "item_emb": pf((num_items, emb_dim), ("vocab", "embed"), init="normal",
                       dtype=jnp.float32),
        # attention unit over (hist, target, hist*target, hist-target)
        "att_w1": pf((4 * emb_dim, hidden), (None, None), dtype=jnp.float32),
        "att_b1": pf((hidden,), (None,), init="zeros", dtype=jnp.float32),
        "att_w2": pf((hidden, 1), (None, None), dtype=jnp.float32),
        # output MLP over [pooled_hist, target, pooled*target]
        "mlp_w1": pf((3 * emb_dim, hidden), (None, None), dtype=jnp.float32),
        "mlp_b1": pf((hidden,), (None,), init="zeros", dtype=jnp.float32),
        "mlp_w2": pf((hidden, 1), (None, None), dtype=jnp.float32),
        "mlp_b2": pf((1,), (None,), init="zeros", dtype=jnp.float32),
    }


def din_logits(params, hist: Array, target: Array) -> Array:
    """hist: (B, H) item ids (-1 pad); target: (B,) item id."""
    p = unbox(params)
    emb = p["item_emb"]
    hmask = (hist >= 0).astype(jnp.float32)
    he = emb[jnp.maximum(hist, 0)] * hmask[..., None]            # (B,H,e)
    te = emb[target]                                             # (B,e)
    tb = jnp.broadcast_to(te[:, None], he.shape)
    att_in = jnp.concatenate([he, tb, he * tb, he - tb], axis=-1)
    a = jax.nn.relu(att_in @ p["att_w1"] + p["att_b1"]) @ p["att_w2"]
    a = a[..., 0] + (hmask - 1.0) * 1e9                          # mask pads
    w = jax.nn.softmax(a, axis=-1) * (hmask.sum(-1, keepdims=True) > 0)
    pooled = jnp.einsum("bh,bhe->be", w, he)
    feat = jnp.concatenate([pooled, te, pooled * te], axis=-1)
    h = jax.nn.relu(feat @ p["mlp_w1"] + p["mlp_b1"])
    return (h @ p["mlp_w2"])[:, 0] + p["mlp_b2"][0]


def din_loss(params, batch: Dict) -> Array:
    logit = din_logits(params, batch["hist"], batch["target"])
    per = _bce(logit, batch["label"].astype(jnp.float32))
    m = batch.get("sample_mask", jnp.ones_like(per))
    return (per * m).sum() / jnp.maximum(m.sum(), 1.0)


PAPER_MODELS = {
    "movielens_lr": (make_lr_params, lr_loss),
    "sent140_lstm": (make_lstm_params, lstm_loss),
    "din_ctr": (make_din_params, din_loss),
}
