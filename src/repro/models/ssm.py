"""Mamba2 blocks via the chunked SSD (state-space duality) formulation.

The selective SSM recurrence per head h with scalar decay:

    S_t = a_t * S_{t-1} + dt_t * x_t (outer) B_t        S in R^{p x n}
    y_t = S_t C_t + D * x_t

is computed in chunks: within-chunk terms form a decay-masked quadratic
(attention-like) matmul — MXU-friendly — while cross-chunk terms carry the
running state through a ``lax.scan``. Decode is the O(1) single-step update.
This is the TPU-native adaptation: the CUDA kernel's warp-parallel scan
becomes chunked matmuls sized for the MXU (128-aligned chunk length).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.logical import ParamFactory

Array = jax.Array


def make_mamba2_params(pf: ParamFactory, cfg: ModelConfig, stack: int = 0):
    d = cfg.d_model
    e = cfg.ssm_expand
    di = e * d                        # inner dim
    h = cfg.ssm_heads
    n = cfg.ssm_state
    p_dim = di // h                   # head dim
    conv_dim = di + 2 * n             # x, B, C go through the depthwise conv
    return {
        "norm": L.make_rmsnorm(pf, d, stack=stack),
        "in_proj": pf((d, 2 * di + 2 * n + h), ("embed", "ffn"), stack=stack),
        "conv_w": pf((cfg.ssm_conv_width, conv_dim), ("conv", "ffn"), stack=stack),
        "conv_b": pf((conv_dim,), ("ffn",), init="zeros", stack=stack),
        "a_log": pf((h,), (None,), init="ssm_a", dtype=jnp.float32, stack=stack),
        "dt_bias": pf((h,), (None,), init="zeros", dtype=jnp.float32, stack=stack),
        "d_skip": pf((h,), (None,), init="ones", dtype=jnp.float32, stack=stack),
        "out_norm": L.make_rmsnorm(pf, di, stack=stack),
        "out_proj": pf((di, d), ("ffn", "embed"), stack=stack),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    h = cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(xbc, w, b, state: Optional[Array] = None):
    """Depthwise causal conv over seq. xbc: (B, S, C); w: (W, C).

    ``state``: (B, W-1, C) trailing context from previous tokens (decode) —
    returns (out, new_state).
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros(xbc.shape[:1] + (width - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)                   # (B, S+W-1, C)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(width))
    out = jax.nn.silu(out + b.astype(out.dtype))
    new_state = full[:, -(width - 1):]
    return out, new_state


class SSDState(NamedTuple):
    state: Array        # (B, H, p, n)
    conv: Array         # (B, W-1, conv_dim)


def ssd_chunked(x, a_log_dt, b_mat, c_mat, chunk: int,
                initial_state: Optional[Array] = None) -> Tuple[Array, Array]:
    """Chunked scan. x: (B,S,H,p); a_log_dt: (B,S,H) = log decay per step
    (negative); b_mat, c_mat: (B,S,N). Returns (y, final_state (B,H,p,n))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        # zero-padded tail: a=0 (decay 1, state preserved) and B=0 (no input),
        # so the final state is exact; padded outputs are sliced off
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log_dt = jnp.pad(a_log_dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // c

    xr = x.reshape(bsz, nc, c, h, p).transpose(1, 0, 2, 3, 4)           # (nc,B,c,H,p)
    ar = a_log_dt.reshape(bsz, nc, c, h).transpose(1, 0, 2, 3)          # (nc,B,c,H)
    br = b_mat.reshape(bsz, nc, c, n).transpose(1, 0, 2, 3)
    cr = c_mat.reshape(bsz, nc, c, n).transpose(1, 0, 2, 3)

    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def body(state, inp):
        xc, ac, bc, cc = inp
        cum = jnp.cumsum(ac, axis=1)                                    # (B,c,H)
        total = cum[:, -1]                                              # (B,H)
        # within-chunk: decay(i,j) = exp(cum_i - cum_j), j <= i. Mask BEFORE
        # the exp: exp of the (large positive) upper triangle would be inf and
        # poison the backward pass with 0*inf = NaN cotangents.
        dec = cum[:, :, None, :] - cum[:, None, :, :]                   # (B,c,c,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.exp(jnp.where(tri[None, :, :, None], dec, -1e30))
        scores = jnp.einsum("bin,bjn->bij", cc, bc,
                            preferred_element_type=jnp.float32)          # (B,c,c)
        w = scores[..., None] * dmat                                     # (B,c,c,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xc.astype(jnp.float32))
        # cross-chunk: y_i += C_i . (exp(cum_i) * state)
        y_inter = jnp.einsum("bin,bhpn->bihp", cc, state) * \
            jnp.exp(cum)[..., None]
        # state update: state' = exp(total) * state + sum_j exp(total-cum_j) B_j x_j
        carry_dec = jnp.exp(total[:, None] - cum)                        # (B,c,H)
        contrib = jnp.einsum("bjn,bjhp,bjh->bhpn", bc, xc.astype(jnp.float32), carry_dec)
        new_state = jnp.exp(total)[:, :, None, None] * state + contrib
        return new_state, (y_intra + y_inter)

    final_state, ys = lax.scan(body, initial_state, (xr, ar, br, cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    if pad:
        y = y[:, :s_orig]
    return y.astype(x.dtype), final_state


def mamba2_block(cfg: ModelConfig, mp, x, *, chunk: int = 256,
                 state: Optional[SSDState] = None, single_step: bool = False):
    """Full Mamba2 mixer. x: (B, S, d). Returns (out, new_state)."""
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    h = cfg.ssm_heads
    p_dim = di // h
    bsz, s, _ = x.shape

    zxbcdt = x @ mp["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    conv_state = state.conv if state is not None else None
    xbc, new_conv = _causal_conv(xbc, mp["conv_w"], mp["conv_b"], conv_state)
    xs = xbc[..., :di].reshape(bsz, s, h, p_dim)
    b_mat = xbc[..., di:di + n]
    c_mat = xbc[..., di + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + mp["dt_bias"])         # (B,S,H)
    a = -jnp.exp(mp["a_log"])                                            # (H,) negative
    a_log_dt = a * dt                                                    # log decay
    x_in = xs * dt[..., None].astype(xs.dtype)

    if single_step:
        # O(1) recurrence for decode: S' = exp(a dt) S + dt x (outer) B
        prev = state.state if state is not None else jnp.zeros((bsz, h, p_dim, n), jnp.float32)
        decay = jnp.exp(a_log_dt[:, 0])                                  # (B,H)
        contrib = jnp.einsum("bn,bhp->bhpn", b_mat[:, 0].astype(jnp.float32),
                             x_in[:, 0].astype(jnp.float32))
        new_s = decay[..., None, None] * prev + contrib
        y = jnp.einsum("bhpn,bn->bhp", new_s, c_mat[:, 0].astype(jnp.float32))
        y = y[:, None].transpose(0, 1, 2, 3)                             # (B,1,H,p)
        y = y.reshape(bsz, 1, h, p_dim)
    else:
        prev = state.state if state is not None else None
        y, new_s = ssd_chunked(x_in, a_log_dt, b_mat.astype(jnp.float32),
                               c_mat.astype(jnp.float32), chunk, prev)

    y = y + xs * mp["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, s if not single_step else 1, di)
    y = L.rmsnorm(mp["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ mp["out_proj"]
    return out.astype(x.dtype), SSDState(new_s.astype(jnp.float32), new_conv)
