"""Decoder-only transformer covering the dense / MoE / VLM assigned archs.

One parameterised implementation serves mixtral-8x22b, llama4-maverick,
mistral-large-123b, qwen3-32b, qwen2.5-14b, deepseek-67b and qwen2-vl-7b:
GQA (+ optional qk_norm / qkv bias / sliding window), gated MLP or dropping
MoE, RoPE or M-RoPE, and early-fusion patch embeddings for the VLM/llama4
frontend carve-out.

Layers are ``lax.scan``'d over stacked parameters (compile-time sanity for
56-95 layer configs) with ``jax.checkpoint`` on the layer body for training.
The LM loss is computed in sequence chunks against the vocab-sharded head so
full (B, S, V) logits are never materialised.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.context import constrain
from repro.sharding.logical import ParamFactory, unbox

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def make_params(cfg: ModelConfig, rng: Optional[Array] = None, abstract: bool = False):
    pf = ParamFactory(rng=rng, abstract=abstract, dtype=jnp.dtype(cfg.dtype))
    d, hd = cfg.d_model, cfg.head_dim
    nl = cfg.num_layers
    q_dim = cfg.num_heads * hd
    kv_dim = cfg.num_kv_heads * hd

    attn = {
        "norm": L.make_rmsnorm(pf, d, stack=nl),
        "wq": L.make_linear(pf, d, q_dim, ("embed", "heads"), bias=cfg.qkv_bias, stack=nl),
        "wk": L.make_linear(pf, d, kv_dim, ("embed", "kv"), bias=cfg.qkv_bias, stack=nl),
        "wv": L.make_linear(pf, d, kv_dim, ("embed", "kv"), bias=cfg.qkv_bias, stack=nl),
        "wo": L.make_linear(pf, q_dim, d, ("heads", "embed"), stack=nl),
    }
    if cfg.qk_norm:
        attn["q_norm"] = pf((hd,), (None,), init="ones", dtype=jnp.float32, stack=nl)
        attn["k_norm"] = pf((hd,), (None,), init="ones", dtype=jnp.float32, stack=nl)

    if cfg.is_moe:
        ffn = L.make_moe(pf, d, cfg.d_ff, cfg.num_experts, stack=nl)
    else:
        ffn = L.make_mlp(pf, d, cfg.d_ff, stack=nl)

    params = {
        "embedding": pf((cfg.vocab_size, d), ("vocab", "embed"), init="normal"),
        "layers": {"attn": attn, "ffn_norm": L.make_rmsnorm(pf, d, stack=nl), "ffn": ffn},
        "final_norm": L.make_rmsnorm(pf, d),
        "lm_head": pf((d, cfg.vocab_size), ("embed", "vocab")),
    }
    return params


# ---------------------------------------------------------------------------
# Attention block (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _project_qkv(cfg: ModelConfig, ap, x, positions, mrope_pos=None):
    b = x.shape[0]
    s = x.shape[1]
    q = L.linear(ap["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = L.linear(ap["wk"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = L.linear(ap["wv"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.head_rmsnorm(ap["q_norm"], q, cfg.norm_eps)
        k = L.head_rmsnorm(ap["k_norm"], k, cfg.norm_eps)
    if cfg.mrope and mrope_pos is not None:
        q = L.apply_mrope(q, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    # pin attention activation layouts: either cleanly head-sharded (when the
    # head count divides the model axis) or replicated — never partial-head
    q = constrain(q, ("batch", None, "heads_act", None))
    k = constrain(k, ("batch", None, "kv_act", None))
    v = constrain(v, ("batch", None, "kv_act", None))
    return q, k, v


def attention_block(cfg: ModelConfig, ap, x, positions, mrope_pos=None):
    """Full-sequence (train / prefill) attention. Returns (out, (k, v))."""
    q, k, v = _project_qkv(cfg, ap, x, positions, mrope_pos)
    if cfg.attn_impl == "naive":
        o = L.naive_attention(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        o = L.mea_attention(
            q, k, v, causal=True, window=cfg.sliding_window,
            query_chunk=cfg.query_chunk, kv_chunk=cfg.kv_chunk,
        )
    b, s = x.shape[:2]
    out = L.linear(ap["wo"], o.reshape(b, s, cfg.num_heads * cfg.head_dim))
    return out, (k, v)


# ---------------------------------------------------------------------------
# Embedding / early fusion
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params, tokens, patch_embeds=None):
    emb = params["embedding"]
    x = emb[tokens] * jnp.asarray(jnp.sqrt(cfg.d_model), emb.dtype)
    if patch_embeds is not None and cfg.num_patches > 0:
        # early fusion: the first num_patches positions carry modality embeds
        p = patch_embeds.shape[1]
        pos_is_patch = (jnp.arange(x.shape[1]) < p)[None, :, None]
        padded = jnp.zeros_like(x).at[:, :p].set(patch_embeds.astype(x.dtype))
        x = jnp.where(pos_is_patch, padded, x)
    return constrain(x, ("batch", None, None))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


class ForwardOut(NamedTuple):
    hidden: Array            # (B, S, d) final-norm'd hidden states
    aux_loss: Array          # MoE load-balance aux (0 for dense)
    kv: Optional[Tuple]      # stacked (L, B, KV, S, hd) when collect_kv


def forward(cfg: ModelConfig, params, tokens, *, patch_embeds=None, mrope_pos=None,
            positions=None, collect_kv: bool = False, remat: bool = True) -> ForwardOut:
    p = unbox(params)
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = embed_tokens(cfg, p, tokens, patch_embeds)

    def layer(x, lp):
        h, kv = attention_block(cfg, lp["attn"], L.rmsnorm(lp["attn"]["norm"], x, cfg.norm_eps),
                                positions, mrope_pos)
        x = constrain(x + h, ("batch", None, None))
        hn = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        if cfg.is_moe:
            f, stats = L.moe(lp["ffn"], hn, num_experts=cfg.num_experts,
                             top_k=cfg.experts_per_token,
                             capacity_factor=cfg.moe_capacity_factor,
                             token_chunk=cfg.moe_token_chunk)
            aux = stats.aux_loss
        else:
            f = L.mlp(lp["ffn"], hn)
            aux = jnp.zeros((), jnp.float32)
        x = constrain(x + f, ("batch", None, None))
        if collect_kv:
            # cache layout: seq-sharded over the model axis from the moment of
            # collection, so the stacked (L,B,S,KV,hd) tensor never exists
            # replicated per device
            kv = tuple(constrain(t, ("batch", "kv_seq", None, None)) for t in kv)
            ys = (aux, kv)
        else:
            ys = (aux, None)
        return x, ys

    body = jax.checkpoint(layer, prevent_cse=False) if remat else layer
    g = cfg.remat_groups
    if remat and g > 1 and cfg.num_layers % g == 0 and not collect_kv:
        # two-level remat: outer scan over G groups (saves G carries), inner
        # scan over L/G layers inside a checkpointed group body (its stack is
        # rematerialised during the group's backward). Residual footprint
        # ~ (G + L/G) activations instead of L.
        per = cfg.num_layers // g
        grouped = jax.tree.map(lambda a: a.reshape((g, per) + a.shape[1:]), p["layers"])

        def group(x, gp):
            x, (aux, _) = lax.scan(body, x, gp)
            return x, aux

        group = jax.checkpoint(group, prevent_cse=False)
        x, aux_all = lax.scan(group, x, grouped)
        aux_all = aux_all.reshape(-1)
        kvs = None
    else:
        x, (aux_all, kvs) = lax.scan(body, x, p["layers"])
    hidden = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return ForwardOut(hidden, aux_all.mean(), kvs)


def chunked_xent(cfg: ModelConfig, params, hidden, targets, mask, chunk: int = 512):
    """Next-token cross-entropy in seq chunks against the vocab-sharded head.

    Never materialises (B, S, V) logits: per chunk (B, c, V) is constrained to
    the model axis on V, so each device holds (B, c, V/16).
    """
    p = unbox(params)
    head = p["lm_head"]
    b, s, d = hidden.shape
    c = min(chunk, s)
    n = s // c
    assert s % c == 0

    def one(i):
        h = lax.dynamic_slice_in_dim(hidden, i * c, c, axis=1)
        t = lax.dynamic_slice_in_dim(targets, i * c, c, axis=1)
        m = lax.dynamic_slice_in_dim(mask, i * c, c, axis=1)
        logits = constrain((h @ head).astype(jnp.float32), ("batch", None, "vocab"))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return ((lse - gold) * m).sum(), m.sum()

    losses, counts = lax.map(one, jnp.arange(n))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = True):
    """Causal LM loss (mean over cohort tokens) + MoE aux."""
    tokens = batch["tokens"]
    targets = batch.get("labels", jnp.pad(tokens[:, 1:], ((0, 0), (0, 1))))
    mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
    out = forward(cfg, params, tokens,
                  patch_embeds=batch.get("patch_embeds"),
                  mrope_pos=batch.get("mrope_pos"),
                  remat=remat)
    ce = chunked_xent(cfg, params, out.hidden, targets, mask)
    return ce + cfg.router_aux_weight * out.aux_loss


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract: bool = False) -> L.KVCache:
    cap = min(cfg.sliding_window, max_seq) if cfg.sliding_window > 0 else max_seq
    return L.make_kv_cache(cfg.num_layers, batch, cfg.num_kv_heads, cap, cfg.head_dim,
                           dtype=jnp.dtype(cfg.dtype), abstract=abstract)


def prefill(cfg: ModelConfig, params, tokens, cache: L.KVCache, *, patch_embeds=None,
            mrope_pos=None):
    """Run the prompt, fill the cache, return last-token logits."""
    p = unbox(params)
    out = forward(cfg, params, tokens, patch_embeds=patch_embeds, mrope_pos=mrope_pos,
                  collect_kv=True, remat=False)
    k, v = out.kv                                   # (L, B, S, KV, hd)
    k = k.transpose(0, 1, 3, 2, 4)                  # -> (L, B, KV, S, hd)
    v = v.transpose(0, 1, 3, 2, 4)
    s = tokens.shape[1]
    cap = cache.capacity
    if cfg.sliding_window > 0 and s > cap:
        # ring semantics: keep the last `cap` tokens at their mod-cap slots
        k, v = k[:, :, :, -cap:], v[:, :, :, -cap:]
        shift = s % cap
        k = jnp.roll(k, shift, axis=3)
        v = jnp.roll(v, shift, axis=3)
        newk = constrain(k.astype(cache.k.dtype), ("layers", "batch", "kv_heads", "kv_seq", None))
        newv = constrain(v.astype(cache.v.dtype), ("layers", "batch", "kv_heads", "kv_seq", None))
    else:
        newk = lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, axis=3)
        newv = lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, axis=3)
        newk = constrain(newk, ("layers", "batch", "kv_heads", "kv_seq", None))
        newv = constrain(newv, ("layers", "batch", "kv_heads", "kv_seq", None))
    logits = (out.hidden[:, -1] @ p["lm_head"]).astype(jnp.float32)
    logits = constrain(logits, ("batch", "vocab"))
    new_cache = L.KVCache(newk, newv, jnp.asarray(s, jnp.int32))
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, cache: L.KVCache, tokens, *, mrope_pos=None):
    """One decode step: tokens (B,), cache position = cache.pos."""
    p = unbox(params)
    b = tokens.shape[0]
    pos = cache.pos
    ring = cfg.sliding_window > 0
    positions = jnp.broadcast_to(pos, (b, 1))
    x = embed_tokens(cfg, p, tokens[:, None])
    slot_pos = L.cache_slot_positions(pos + 1, cache.capacity, ring)  # incl. current

    def layer_body(x, lp, k_layer, v_layer):
        ap = lp["attn"]
        h = L.rmsnorm(ap["norm"], x, cfg.norm_eps)
        if cfg.mrope and mrope_pos is not None:
            q, k, v = _project_qkv(cfg, ap, h, positions, mrope_pos)
        else:
            q, k, v = _project_qkv(cfg, ap, h, positions)
        k_layer, v_layer = L.cache_write(k_layer, v_layer, pos, k[:, 0], v[:, 0], ring)
        o = L.decode_attention(q[:, 0], k_layer, v_layer, slot_pos, pos,
                               window=cfg.sliding_window)
        h = L.linear(ap["wo"], o.reshape(b, 1, -1)[:, 0])[:, None]
        x = x + h
        hn = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        if cfg.is_moe:
            # decode is drop-free: with a single token per sequence the whole
            # assignment set fits (capacity = B*k), keeping decode bit-stable
            # regardless of routing skew
            f, _ = L.moe(lp["ffn"], hn, num_experts=cfg.num_experts,
                         top_k=cfg.experts_per_token,
                         capacity_factor=cfg.moe_capacity_factor,
                         deterministic_capacity=b * cfg.experts_per_token)
        else:
            f = L.mlp(lp["ffn"], hn)
        x = x + f
        return x, k_layer, v_layer

    # fori_loop with the cache in the carry: while-loop carries alias their
    # buffers across iterations, so the (L,B,KV,S,hd) stacks are updated in
    # place instead of living twice as scan xs + ys (a full extra KV cache
    # per step at decode_32k scale — see EXPERIMENTS.md §Perf pair 2)
    def body(i, carry):
        x, k_all, v_all = carry
        lp = jax.tree.map(lambda a: a[i], p["layers"])
        x, k_layer, v_layer = layer_body(x, lp, k_all[i], v_all[i])
        k_all = lax.dynamic_update_index_in_dim(k_all, k_layer, i, 0)
        v_all = lax.dynamic_update_index_in_dim(v_all, v_layer, i, 0)
        return x, k_all, v_all

    x, nk, nv = lax.fori_loop(0, cfg.num_layers, body, (x, cache.k, cache.v))
    nk = constrain(nk, ("layers", "batch", "kv_heads", "kv_seq", None))
    nv = constrain(nv, ("layers", "batch", "kv_heads", "kv_seq", None))
    hidden = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = (hidden[:, 0] @ p["lm_head"]).astype(jnp.float32)
    logits = constrain(logits, ("batch", "vocab"))
    return logits, L.KVCache(nk, nv, pos + 1)
