"""Whisper-style encoder-decoder (transformer backbone only).

The mel/conv frontend is stubbed per the assignment carve-out: the encoder
consumes precomputed frame embeddings (B, enc_seq, d). Decoder: causal self-
attention + cross-attention to the encoder output. Serving caches both the
self-attn KV (grows) and the cross-attn KV (computed once at prefill).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.sharding.context import constrain
from repro.sharding.logical import ParamFactory, unbox

Array = jax.Array


def make_params(cfg: ModelConfig, rng=None, abstract: bool = False):
    pf = ParamFactory(rng=rng, abstract=abstract, dtype=jnp.dtype(cfg.dtype))
    d = cfg.d_model
    q_dim = cfg.num_heads * cfg.head_dim
    kv_dim = cfg.num_kv_heads * cfg.head_dim

    def attn_params(stack):
        return {
            "norm": L.make_rmsnorm(pf, d, stack=stack),
            "wq": L.make_linear(pf, d, q_dim, ("embed", "heads"), bias=True, stack=stack),
            "wk": L.make_linear(pf, d, kv_dim, ("embed", "kv"), stack=stack),
            "wv": L.make_linear(pf, d, kv_dim, ("embed", "kv"), bias=True, stack=stack),
            "wo": L.make_linear(pf, q_dim, d, ("heads", "embed"), bias=True, stack=stack),
        }

    ne, nd = cfg.encoder_layers, cfg.num_layers
    return {
        "encoder": {
            "attn": attn_params(ne),
            "ffn_norm": L.make_rmsnorm(pf, d, stack=ne),
            "ffn": L.make_mlp(pf, d, cfg.d_ff, stack=ne),
        },
        "encoder_norm": L.make_rmsnorm(pf, d),
        "decoder": {
            "self_attn": attn_params(nd),
            "cross_attn": attn_params(nd),
            "ffn_norm": L.make_rmsnorm(pf, d, stack=nd),
            "ffn": L.make_mlp(pf, d, cfg.d_ff, stack=nd),
        },
        "embedding": pf((cfg.vocab_size, d), ("vocab", "embed"), init="normal"),
        "final_norm": L.make_rmsnorm(pf, d),
        "lm_head": pf((d, cfg.vocab_size), ("embed", "vocab")),
    }


def _mha(cfg, ap, xq, xkv, *, causal, q_offset=0):
    b, sq = xq.shape[:2]
    skv = xkv.shape[1]
    q = L.linear(ap["wq"], xq).reshape(b, sq, cfg.num_heads, cfg.head_dim)
    k = L.linear(ap["wk"], xkv).reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    v = L.linear(ap["wv"], xkv).reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    q = constrain(q, ("batch", None, "heads_act", None))
    k = constrain(k, ("batch", None, "kv_act", None))
    v = constrain(v, ("batch", None, "kv_act", None))
    o = L.mea_attention(q, k, v, causal=causal, q_offset=q_offset,
                        query_chunk=cfg.query_chunk, kv_chunk=cfg.kv_chunk)
    return L.linear(ap["wo"], o.reshape(b, sq, -1)), (k, v)


def encode(cfg: ModelConfig, params, frames) -> Array:
    """frames: (B, enc_seq, d) precomputed frame embeddings (frontend stub)."""
    p = unbox(params)
    b, s, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + L.sinusoidal_positions(s, d).astype(cfg.dtype)
    x = constrain(x, ("batch", None, None))

    def layer(x, lp):
        h, _ = _mha(cfg, lp["attn"], L.rmsnorm(lp["attn"]["norm"], x, cfg.norm_eps),
                    L.rmsnorm(lp["attn"]["norm"], x, cfg.norm_eps), causal=False)
        x = x + h
        x = x + L.mlp(lp["ffn"], L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps))
        return constrain(x, ("batch", None, None)), None

    x, _ = lax.scan(jax.checkpoint(layer, prevent_cse=False), x, p["encoder"])
    return L.rmsnorm(p["encoder_norm"], x, cfg.norm_eps)


def decode_train(cfg: ModelConfig, params, tokens, enc_out, *, remat=True,
                 collect_kv=False):
    p = unbox(params)
    b, s = tokens.shape
    x = p["embedding"][tokens] * jnp.asarray(jnp.sqrt(cfg.d_model), jnp.dtype(cfg.dtype))
    x = x + L.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    x = constrain(x, ("batch", None, None))

    def layer(x, lp):
        h, self_kv = _mha(cfg, lp["self_attn"],
                          L.rmsnorm(lp["self_attn"]["norm"], x, cfg.norm_eps),
                          L.rmsnorm(lp["self_attn"]["norm"], x, cfg.norm_eps), causal=True)
        x = x + h
        h, cross_kv = _mha(cfg, lp["cross_attn"],
                           L.rmsnorm(lp["cross_attn"]["norm"], x, cfg.norm_eps),
                           enc_out, causal=False)
        x = x + h
        x = x + L.mlp(lp["ffn"], L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps))
        if collect_kv:
            self_kv = tuple(constrain(t, ("batch", "kv_seq", None, None)) for t in self_kv)
            cross_kv = tuple(constrain(t, ("batch", None, None, None)) for t in cross_kv)
            ys = (self_kv, cross_kv)
        else:
            ys = None
        return constrain(x, ("batch", None, None)), ys

    body = jax.checkpoint(layer, prevent_cse=False) if remat else layer
    x, kvs = lax.scan(body, x, p["decoder"])
    return L.rmsnorm(p["final_norm"], x, cfg.norm_eps), kvs


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = True):
    tokens = batch["tokens"]
    targets = batch.get("labels", jnp.pad(tokens[:, 1:], ((0, 0), (0, 1))))
    mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
    enc_out = encode(cfg, params, batch["frames"])
    hidden, _ = decode_train(cfg, params, tokens, enc_out, remat=remat)
    return T.chunked_xent(cfg, params, hidden, targets, mask)


class WhisperCache(NamedTuple):
    k: Array            # (L, B, KV, S, hd) decoder self-attn
    v: Array
    ck: Array           # (L, B, KV, enc_seq, hd) cross-attn (static post-prefill)
    cv: Array
    pos: Array


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract: bool = False) -> WhisperCache:
    dt = jnp.dtype(cfg.dtype)
    nl = cfg.num_layers
    s_shape = (nl, batch, cfg.num_kv_heads, max_seq, cfg.head_dim)
    c_shape = (nl, batch, cfg.num_kv_heads, cfg.encoder_seq, cfg.head_dim)
    if abstract:
        return WhisperCache(jax.ShapeDtypeStruct(s_shape, dt), jax.ShapeDtypeStruct(s_shape, dt),
                            jax.ShapeDtypeStruct(c_shape, dt), jax.ShapeDtypeStruct(c_shape, dt),
                            jax.ShapeDtypeStruct((), jnp.int32))
    z = jnp.zeros(s_shape, dt)
    c = jnp.zeros(c_shape, dt)
    return WhisperCache(z, z, c, c, jnp.zeros((), jnp.int32))


def prefill(cfg: ModelConfig, params, tokens, frames, cache: WhisperCache):
    p = unbox(params)
    enc_out = encode(cfg, params, frames)
    hidden, kvs = decode_train(cfg, params, tokens, enc_out, remat=False, collect_kv=True)
    (sk, sv), (ck, cv) = kvs
    sk = sk.transpose(0, 1, 3, 2, 4)
    sv = sv.transpose(0, 1, 3, 2, 4)
    ck = ck.transpose(0, 1, 3, 2, 4)
    cv = cv.transpose(0, 1, 3, 2, 4)
    nk = lax.dynamic_update_slice_in_dim(cache.k, sk.astype(cache.k.dtype), 0, axis=3)
    nv = lax.dynamic_update_slice_in_dim(cache.v, sv.astype(cache.v.dtype), 0, axis=3)
    logits = (hidden[:, -1] @ p["lm_head"]).astype(jnp.float32)
    return logits, WhisperCache(nk, nv, ck.astype(cache.ck.dtype), cv.astype(cache.cv.dtype),
                                jnp.asarray(tokens.shape[1], jnp.int32))


def decode_step(cfg: ModelConfig, params, cache: WhisperCache, tokens):
    p = unbox(params)
    b = tokens.shape[0]
    pos = cache.pos
    x = p["embedding"][tokens[:, None]] * jnp.asarray(jnp.sqrt(cfg.d_model), jnp.dtype(cfg.dtype))
    # sinusoidal position for this step
    pos_emb = L.sinusoidal_positions(cache.k.shape[3], cfg.d_model)
    x = x + lax.dynamic_slice_in_dim(pos_emb, pos, 1, axis=0)[None].astype(x.dtype)
    slot_pos = L.cache_slot_positions(pos + 1, cache.k.shape[3], ring=False)
    enc_pos = jnp.arange(cfg.encoder_seq)

    def layer(carry, inp):
        x = carry
        lp, kc, vc, ckc, cvc = inp
        ap = lp["self_attn"]
        h = L.rmsnorm(ap["norm"], x, cfg.norm_eps)
        q = L.linear(ap["wq"], h).reshape(b, 1, cfg.num_heads, cfg.head_dim)
        k = L.linear(ap["wk"], h).reshape(b, 1, cfg.num_kv_heads, cfg.head_dim)
        v = L.linear(ap["wv"], h).reshape(b, 1, cfg.num_kv_heads, cfg.head_dim)
        kc, vc = L.cache_write(kc, vc, pos, k[:, 0], v[:, 0], ring=False)
        o = L.decode_attention(q[:, 0], kc, vc, slot_pos, pos)
        x = x + L.linear(ap["wo"], o.reshape(b, -1))[:, None]
        cp = lp["cross_attn"]
        h = L.rmsnorm(cp["norm"], x, cfg.norm_eps)
        q = L.linear(cp["wq"], h).reshape(b, 1, cfg.num_heads, cfg.head_dim)
        o = L.decode_attention(q[:, 0], ckc, cvc, enc_pos, jnp.asarray(cfg.encoder_seq, jnp.int32))
        x = x + L.linear(cp["wo"], o.reshape(b, -1))[:, None]
        x = x + L.mlp(lp["ffn"], L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps))
        return x, (kc, vc)

    x, (nk, nv) = lax.scan(layer, x, (p["decoder"], cache.k, cache.v, cache.ck, cache.cv))
    hidden = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = (hidden[:, 0] @ p["lm_head"]).astype(jnp.float32)
    return logits, WhisperCache(nk, nv, cache.ck, cache.cv, pos + 1)
