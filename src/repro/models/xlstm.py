"""xLSTM blocks: mLSTM (matrix-memory, chunk-parallel) and sLSTM (scalar,
sequential scan with exponential gating + stabilizer state). [arXiv:2405.04517]

TPU adaptation: the mLSTM recurrence

    C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

is a gated linear attention; we compute it chunk-parallel exactly like the
Mamba2 SSD path (decay-masked quadratic within chunks, scanned state across
chunks) with the gate products tracked in log space for stability. sLSTM is
inherently sequential (the stabilizer max is non-associative) -> lax.scan over
time with block-diagonal recurrent weights per head.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.logical import ParamFactory

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def make_mlstm_params(pf: ParamFactory, cfg: ModelConfig, stack: int = 0):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.ssm_heads
    hd = di // h
    return {
        "norm": L.make_rmsnorm(pf, d, stack=stack),
        "up_z": pf((d, di), ("embed", "ffn"), stack=stack),
        "up_x": pf((d, di), ("embed", "ffn"), stack=stack),
        "wq": pf((di, di), (None, "heads"), stack=stack),
        "wk": pf((di, di), (None, "heads"), stack=stack),
        "wv": pf((di, di), (None, "heads"), stack=stack),
        "w_i": pf((di, h), ("ffn", None), stack=stack),       # input gate (per head)
        "w_f": pf((di, h), ("ffn", None), stack=stack),       # forget gate
        "b_i": pf((h,), (None,), init="zeros", dtype=jnp.float32, stack=stack),
        "b_f": pf((h,), (None,), init="ones", dtype=jnp.float32, stack=stack),
        "out_norm": L.make_rmsnorm(pf, di, stack=stack),
        "down": pf((di, d), ("ffn", "embed"), stack=stack),
    }


class MLSTMState(NamedTuple):
    c: Array       # (B, H, hd, hd)  matrix memory
    n: Array       # (B, H, hd)      normalizer
    m: Array       # (B, H)          stabilizer (log-space running max)


def mlstm_cell_chunked(q, k, v, log_i, log_f, chunk: int,
                       state: Optional[MLSTMState] = None) -> Tuple[Array, MLSTMState]:
    """Chunk-parallel mLSTM. q,k,v: (B,S,H,hd); log_i, log_f: (B,S,H).

    Exact log-space formulation: weight of (key j -> query i) is
    exp(log_i_j + sum_{j<t<=i} log_f_t - m_i) with a per-position stabilizer
    m_i = max(running max of candidate log weights). We use the standard
    chunkwise derivation (within-chunk quadratic + carried state).
    """
    bsz, s, h, hd = q.shape
    c = min(chunk, s)
    nc = s // c
    assert s % c == 0
    scale = 1.0 / jnp.sqrt(hd)

    def resh(x):
        return x.reshape(bsz, nc, c, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    qr, kr, vr = resh(q), resh(k), resh(v)
    lir, lfr = resh(log_i), resh(log_f)

    if state is None:
        c0 = jnp.zeros((bsz, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((bsz, h, hd), jnp.float32)
        m0 = jnp.full((bsz, h), -1e30, jnp.float32)
        state = MLSTMState(c0, n0, m0)

    def body(carry, inp):
        cmat, nvec, m_prev = carry
        qc, kc, vc, lic, lfc = inp
        fcum = jnp.cumsum(lfc, axis=1)                       # (B,c,H)
        ftot = fcum[:, -1]
        # log weight of in-chunk key j for query i: li_j + fcum_i - fcum_j
        lw = lic[:, None, :, :] + fcum[:, :, None, :] - fcum[:, None, :, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        lw = jnp.where(tri[None, :, :, None], lw, -1e30)     # (B,i,j,H)
        # carried-state log weight for query i: m_prev + fcum_i
        lw_state = m_prev[:, None] + fcum                    # (B,c,H)
        m_i = jnp.maximum(lw.max(axis=2), lw_state)          # (B,c,H)
        m_i = jnp.maximum(m_i, -1e30)
        w = jnp.exp(lw - m_i[:, :, None, :])                 # (B,i,j,H)
        scores = jnp.einsum("bihd,bjhd->bijh", qc.astype(jnp.float32),
                            kc.astype(jnp.float32)) * scale
        num_intra = jnp.einsum("bijh,bijh,bjhd->bihd", scores, w, vc.astype(jnp.float32))
        den_intra = jnp.einsum("bijh,bijh->bih", w, scores)
        w_state = jnp.exp(lw_state - m_i)                    # (B,c,H)
        q_state = jnp.einsum("bihd,bhde->bihe", qc.astype(jnp.float32), cmat) * scale
        num_inter = q_state * w_state[..., None]
        den_inter = jnp.einsum("bihd,bhd->bih", qc.astype(jnp.float32), nvec) * scale * w_state
        num = num_intra + num_inter
        den = den_intra + den_inter
        hout = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # state update in the new stabilizer frame m_new = m_prev + ftot vs max li
        m_new = jnp.maximum(m_prev + ftot, (lic + ftot[:, None] - fcum).max(axis=1))
        carry_w = jnp.exp(m_prev + ftot - m_new)             # (B,H)
        key_w = jnp.exp(lic + ftot[:, None] - fcum - m_new[:, None])   # (B,c,H)
        cmat_new = carry_w[..., None, None] * cmat + jnp.einsum(
            "bjhd,bjh,bjhe->bhde", kc.astype(jnp.float32), key_w, vc.astype(jnp.float32))
        nvec_new = carry_w[..., None] * nvec + jnp.einsum(
            "bjhd,bjh->bhd", kc.astype(jnp.float32), key_w)
        return (cmat_new, nvec_new, m_new), hout

    (cm, nv, mm), hs = lax.scan(body, tuple(state), (qr, kr, vr, lir, lfr))
    hout = hs.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, hd)
    return hout.astype(q.dtype), MLSTMState(cm, nv, mm)


def mlstm_cell_step(q, k, v, log_i, log_f, state: MLSTMState) -> Tuple[Array, MLSTMState]:
    """O(1) decode step. q,k,v: (B,H,hd); log_i/log_f: (B,H)."""
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(hd)
    m_new = jnp.maximum(log_f + state.m, log_i)
    f_s = jnp.exp(log_f + state.m - m_new)
    i_s = jnp.exp(log_i - m_new)
    kf, vf, qf = (x.astype(jnp.float32) for x in (k, v, q))
    c_new = f_s[..., None, None] * state.c + i_s[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n_new = f_s[..., None] * state.n + i_s[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, c_new) * scale
    den = jnp.einsum("bhd,bhd->bh", qf, n_new) * scale
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return h.astype(q.dtype), MLSTMState(c_new, n_new, m_new)


def mlstm_block(cfg: ModelConfig, mp, x, *, chunk: int = 256,
                state: Optional[MLSTMState] = None, single_step: bool = False):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.ssm_heads
    hd = di // h
    bsz, s, _ = x.shape

    z = jax.nn.silu(x @ mp["up_z"])
    u = x @ mp["up_x"]
    q = (u @ mp["wq"]).reshape(bsz, s, h, hd)
    k = (u @ mp["wk"]).reshape(bsz, s, h, hd)
    v = (u @ mp["wv"]).reshape(bsz, s, h, hd)
    log_i = (u @ mp["w_i"]).astype(jnp.float32) + mp["b_i"]
    log_f = jax.nn.log_sigmoid((u @ mp["w_f"]).astype(jnp.float32) + mp["b_f"])

    if single_step:
        assert state is not None
        hout, new_state = mlstm_cell_step(q[:, 0], k[:, 0], v[:, 0],
                                          log_i[:, 0], log_f[:, 0], state)
        hout = hout[:, None]
    else:
        hout, new_state = mlstm_cell_chunked(q, k, v, log_i, log_f, chunk, state)

    y = L.rmsnorm(mp["out_norm"], hout.reshape(bsz, -1, di) * z, cfg.norm_eps)
    return (y @ mp["down"]).astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def make_slstm_params(pf: ParamFactory, cfg: ModelConfig, stack: int = 0):
    d = cfg.d_model
    h = cfg.ssm_heads
    hd = d // h
    return {
        "norm": L.make_rmsnorm(pf, d, stack=stack),
        "w_in": pf((d, 4 * d), ("embed", "ffn"), stack=stack),     # z,i,f,o pre-acts
        "r": pf((h, hd, 4 * hd), (None, None, None), stack=stack),  # block-diag recurrent
        "b": pf((4 * d,), ("ffn",), init="zeros", dtype=jnp.float32, stack=stack),
        "out_norm": L.make_rmsnorm(pf, d, stack=stack),
        "up": pf((d, 2 * d), ("embed", "ffn"), stack=stack),
        "down": pf((d, d), ("ffn", "embed"), stack=stack),
    }


class SLSTMState(NamedTuple):
    c: Array    # (B, d) cell
    n: Array    # (B, d) normalizer
    h: Array    # (B, d) hidden
    m: Array    # (B, d) stabilizer


def slstm_scan(cfg: ModelConfig, sp, x, state: Optional[SLSTMState] = None,
               unroll: int = 1):
    """x: (B, S, d) -> (B, S, d). Sequential over S (non-associative update)."""
    d = cfg.d_model
    h_heads = cfg.ssm_heads
    hd = d // h_heads
    bsz, s, _ = x.shape
    pre_all = (x @ sp["w_in"]).astype(jnp.float32) + sp["b"]       # (B,S,4d)

    if state is None:
        z = jnp.zeros((bsz, d), jnp.float32)
        state = SLSTMState(z, z, z, jnp.full((bsz, d), -1e30))

    def step(st, pre_t):
        rh = jnp.einsum("bhx,hxy->bhy", st.h.reshape(bsz, h_heads, hd),
                        sp["r"].astype(jnp.float32)).reshape(bsz, 4 * d)
        pre = pre_t + rh
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + st.m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(log_f + st.m - m_new)
        c_new = f_s * st.c + i_s * zt
        n_new = f_s * st.n + i_s
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return SLSTMState(c_new, n_new, h_new, m_new), h_new

    new_state, hs = lax.scan(step, state, pre_all.transpose(1, 0, 2), unroll=unroll)
    return hs.transpose(1, 0, 2).astype(x.dtype), new_state


def slstm_block(cfg: ModelConfig, sp, x, *, state: Optional[SLSTMState] = None,
                single_step: bool = False):
    bsz, s, d = x.shape
    xin = L.rmsnorm(sp["norm"], x, cfg.norm_eps)
    hs, new_state = slstm_scan(cfg, sp, xin, state)
    hs = L.rmsnorm(sp["out_norm"], hs, cfg.norm_eps)
    # post-up/down projection (paper's post-up-proj sLSTM block, expand 2)
    a, b = jnp.split(hs @ sp["up"], 2, axis=-1)
    y = (jax.nn.gelu(a) * b) @ sp["down"]
    return y.astype(x.dtype), new_state
