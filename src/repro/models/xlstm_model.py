"""Full xLSTM language model: pattern of mLSTM / sLSTM blocks.

Consecutive runs of the same block type are grouped and ``lax.scan``'d over
stacked parameters (the pattern is static config), so a 24-layer [7:1] model
compiles as a handful of scans.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import xlstm as X
from repro.sharding.context import constrain
from repro.sharding.logical import ParamFactory, unbox

Array = jax.Array


def pattern_runs(pattern) -> List[Tuple[str, int]]:
    runs = []
    for b in pattern:
        if runs and runs[-1][0] == b:
            runs[-1] = (b, runs[-1][1] + 1)
        else:
            runs.append((b, 1))
    return runs


def make_params(cfg: ModelConfig, rng=None, abstract: bool = False):
    pf = ParamFactory(rng=rng, abstract=abstract, dtype=jnp.dtype(cfg.dtype))
    runs = pattern_runs(cfg.block_pattern)
    blocks = []
    for kind, n in runs:
        if kind == "m":
            blocks.append(("m", X.make_mlstm_params(pf, cfg, stack=n)))
        else:
            blocks.append(("s", X.make_slstm_params(pf, cfg, stack=n)))
    return {
        "embedding": pf((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="normal"),
        "runs": tuple(dict([bp]) for bp in blocks),   # ({'m': params} | {'s': params}, ...)
        "final_norm": L.make_rmsnorm(pf, cfg.d_model),
        "lm_head": pf((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


class XLSTMCache(NamedTuple):
    m_states: Tuple            # per m-run: stacked MLSTMState
    s_states: Tuple            # per s-run: stacked SLSTMState
    pos: Array


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract: bool = False) -> XLSTMCache:
    runs = pattern_runs(cfg.block_pattern)
    di = cfg.ssm_expand * cfg.d_model
    h = cfg.ssm_heads
    hd_m = di // h
    d = cfg.d_model

    def mk(shape, dtype=jnp.float32):
        return jax.ShapeDtypeStruct(shape, dtype) if abstract else jnp.zeros(shape, dtype)

    m_states, s_states = [], []
    for kind, n in runs:
        if kind == "m":
            m_states.append(X.MLSTMState(
                mk((n, batch, h, hd_m, hd_m)), mk((n, batch, h, hd_m)), mk((n, batch, h))))
        else:
            s_states.append(X.SLSTMState(
                mk((n, batch, d)), mk((n, batch, d)), mk((n, batch, d)), mk((n, batch, d))))
    return XLSTMCache(tuple(m_states), tuple(s_states), mk((), jnp.int32))


def _run_layers(cfg, run_params, kind, x, states=None, single_step=False, remat=True):
    """Scan a homogeneous run of stacked blocks; returns (x, stacked new states)."""

    def layer(x, inp):
        lp, st = inp
        if kind == "m":
            h, new = X.mlstm_block(cfg, lp, L.rmsnorm(lp["norm"], x, cfg.norm_eps),
                                   chunk=min(cfg.query_chunk, 256),
                                   state=st, single_step=single_step)
        else:
            h, new = X.slstm_block(cfg, lp, x, state=st, single_step=single_step)
        return constrain(x + h, ("batch", None, None)), new

    body = jax.checkpoint(layer, prevent_cse=False) if (remat and not single_step) else layer
    if states is None:
        n = jax.tree.leaves(run_params)[0].shape[0]
        b = x.shape[0]
        di = cfg.ssm_expand * cfg.d_model
        h = cfg.ssm_heads
        if kind == "m":
            states = X.MLSTMState(
                jnp.zeros((n, b, h, di // h, di // h), jnp.float32),
                jnp.zeros((n, b, h, di // h), jnp.float32),
                jnp.full((n, b, h), -1e30, jnp.float32))
        else:
            d = cfg.d_model
            z = jnp.zeros((n, b, d), jnp.float32)
            states = X.SLSTMState(z, z, z, jnp.full((n, b, d), -1e30, jnp.float32))
    x, new_states = lax.scan(layer if single_step else body, x, (run_params, states))
    return x, new_states


def forward(cfg: ModelConfig, params, tokens, *, remat: bool = True,
            cache: Optional[XLSTMCache] = None, single_step: bool = False):
    p = unbox(params)
    runs = pattern_runs(cfg.block_pattern)
    x = T.embed_tokens(cfg, p, tokens)
    mi = si = 0
    new_m, new_s = [], []
    for (kind, _), rp in zip(runs, p["runs"]):
        run_params = rp[kind]
        if kind == "m":
            st = cache.m_states[mi] if cache is not None else None
            x, ns = _run_layers(cfg, run_params, "m", x, st, single_step, remat)
            new_m.append(ns)
            mi += 1
        else:
            st = cache.s_states[si] if cache is not None else None
            x, ns = _run_layers(cfg, run_params, "s", x, st, single_step, remat)
            new_s.append(ns)
            si += 1
    hidden = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return hidden, (tuple(new_m), tuple(new_s))


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = True):
    tokens = batch["tokens"]
    targets = batch.get("labels", jnp.pad(tokens[:, 1:], ((0, 0), (0, 1))))
    mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
    hidden, _ = forward(cfg, params, tokens, remat=remat)
    return T.chunked_xent(cfg, params, hidden, targets, mask)


def prefill(cfg: ModelConfig, params, tokens, cache: XLSTMCache):
    p = unbox(params)
    hidden, (nm, ns) = forward(cfg, params, tokens, remat=False, cache=cache)
    logits = (hidden[:, -1] @ p["lm_head"]).astype(jnp.float32)
    return logits, XLSTMCache(nm, ns, jnp.asarray(tokens.shape[1], jnp.int32))


def decode_step(cfg: ModelConfig, params, cache: XLSTMCache, tokens):
    p = unbox(params)
    hidden, (nm, ns) = forward(cfg, params, tokens[:, None], remat=False,
                               cache=cache, single_step=True)
    logits = (hidden[:, 0] @ p["lm_head"]).astype(jnp.float32)
    return logits, XLSTMCache(nm, ns, cache.pos + 1)
