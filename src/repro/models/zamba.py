"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block applied
every ``attn_every`` layers (the shared-transformer design of [arXiv:2411.15242]).

The shared block has a single parameter set reused at every insertion point,
but each insertion point keeps its own KV cache during decode.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.sharding.context import constrain
from repro.sharding.logical import ParamFactory, unbox

Array = jax.Array


def make_params(cfg: ModelConfig, rng=None, abstract: bool = False):
    pf = ParamFactory(rng=rng, abstract=abstract, dtype=jnp.dtype(cfg.dtype))
    d = cfg.d_model
    nl = cfg.num_layers
    q_dim = cfg.num_heads * cfg.head_dim
    kv_dim = cfg.num_kv_heads * cfg.head_dim
    shared_attn = {
        "norm": L.make_rmsnorm(pf, d),
        "wq": L.make_linear(pf, d, q_dim, ("embed", "heads")),
        "wk": L.make_linear(pf, d, kv_dim, ("embed", "kv")),
        "wv": L.make_linear(pf, d, kv_dim, ("embed", "kv")),
        "wo": L.make_linear(pf, q_dim, d, ("heads", "embed")),
        "ffn_norm": L.make_rmsnorm(pf, d),
        "ffn": L.make_mlp(pf, d, cfg.d_ff),
    }
    return {
        "embedding": pf((cfg.vocab_size, d), ("vocab", "embed"), init="normal"),
        "mamba": S.make_mamba2_params(pf, cfg, stack=nl),
        "shared_attn": shared_attn,
        "final_norm": L.make_rmsnorm(pf, d),
        "lm_head": pf((d, cfg.vocab_size), ("embed", "vocab")),
    }


def num_attn_sites(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_every


class ZambaCache(NamedTuple):
    ssm_state: Array      # (L, B, H, p, n)
    conv_state: Array     # (L, B, W-1, conv_dim)
    k: Array              # (sites, B, KV, S, hd)
    v: Array
    pos: Array


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, abstract: bool = False) -> ZambaCache:
    di = cfg.ssm_expand * cfg.d_model
    p_dim = di // cfg.ssm_heads
    conv_dim = di + 2 * cfg.ssm_state
    sites = num_attn_sites(cfg)
    shapes = {
        "ssm_state": ((cfg.num_layers, batch, cfg.ssm_heads, p_dim, cfg.ssm_state), jnp.float32),
        "conv_state": ((cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_dim), jnp.dtype(cfg.dtype)),
        "k": ((sites, batch, cfg.num_kv_heads, max_seq, cfg.head_dim), jnp.dtype(cfg.dtype)),
        "v": ((sites, batch, cfg.num_kv_heads, max_seq, cfg.head_dim), jnp.dtype(cfg.dtype)),
        "pos": ((), jnp.int32),
    }
    if abstract:
        vals = {k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in shapes.items()}
    else:
        vals = {k: jnp.zeros(s, dt) for k, (s, dt) in shapes.items()}
    return ZambaCache(**vals)


def _shared_attn_apply(cfg: ModelConfig, sp, x, positions):
    h, kv = T.attention_block(cfg, sp, L.rmsnorm(sp["norm"], x, cfg.norm_eps), positions)
    x = x + h
    x = x + L.mlp(sp["ffn"], L.rmsnorm(sp["ffn_norm"], x, cfg.norm_eps))
    return x, kv


def forward(cfg: ModelConfig, params, tokens, *, remat: bool = True,
            collect_cache: bool = False, positions=None):
    p = unbox(params)
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = T.embed_tokens(cfg, p, tokens)
    k = cfg.attn_every
    sp = p["shared_attn"]

    def layer(carry, inp):
        x = carry
        idx, mp = inp
        h, st = S.mamba2_block(cfg, mp, L.rmsnorm(mp["norm"], x, cfg.norm_eps),
                               chunk=min(cfg.query_chunk, 256))
        x = constrain(x + h, ("batch", None, None))

        def with_attn(x):
            y, kv = _shared_attn_apply(cfg, sp, x, positions)
            return y, kv

        def without(x):
            zkv = (jnp.zeros((b, s, cfg.num_kv_heads, cfg.head_dim), x.dtype),) * 2
            return x, zkv

        x, kv = lax.cond((idx + 1) % k == 0, with_attn, without, x)
        if collect_cache:
            kv = tuple(constrain(t, ("batch", "kv_seq", None, None)) for t in kv)
            ys = (st, kv)
        else:
            ys = None
        return x, ys

    body = jax.checkpoint(layer, prevent_cse=False) if remat else layer
    idxs = jnp.arange(cfg.num_layers)
    x, ys = lax.scan(body, x, (idxs, p["mamba"]))
    hidden = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return hidden, ys


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = True):
    tokens = batch["tokens"]
    targets = batch.get("labels", jnp.pad(tokens[:, 1:], ((0, 0), (0, 1))))
    mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
    hidden, _ = forward(cfg, params, tokens, remat=remat)
    return T.chunked_xent(cfg, params, hidden, targets, mask)


def prefill(cfg: ModelConfig, params, tokens, cache: ZambaCache):
    p = unbox(params)
    b, s = tokens.shape
    hidden, ys = forward(cfg, params, tokens, remat=False, collect_cache=True)
    states, kvs = ys
    kk, vv = kvs                                       # (L, B, S, KV, hd) incl. zeros
    site_idx = jnp.arange(cfg.attn_every - 1, cfg.num_layers, cfg.attn_every)
    kk = kk[site_idx].transpose(0, 1, 3, 2, 4)         # (sites, B, KV, S, hd)
    vv = vv[site_idx].transpose(0, 1, 3, 2, 4)
    newk = lax.dynamic_update_slice_in_dim(cache.k, kk.astype(cache.k.dtype), 0, axis=3)
    newv = lax.dynamic_update_slice_in_dim(cache.v, vv.astype(cache.v.dtype), 0, axis=3)
    logits = (hidden[:, -1] @ p["lm_head"]).astype(jnp.float32)
    new_cache = ZambaCache(states.state, states.conv, newk, newv, jnp.asarray(s, jnp.int32))
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, cache: ZambaCache, tokens):
    p = unbox(params)
    b = tokens.shape[0]
    pos = cache.pos
    x = T.embed_tokens(cfg, p, tokens[:, None])
    k_every = cfg.attn_every
    sp = p["shared_attn"]
    positions = jnp.broadcast_to(pos, (b, 1))
    slot_pos = L.cache_slot_positions(pos + 1, cache.k.shape[3], ring=False)

    def site_attend(x, kc, vc):
        ap = sp
        h = L.rmsnorm(ap["norm"], x, cfg.norm_eps)
        q, k, v = T._project_qkv(cfg, ap, h, positions)
        kc, vc = L.cache_write(kc, vc, pos, k[:, 0], v[:, 0], ring=False)
        o = L.decode_attention(q[:, 0], kc, vc, slot_pos, pos)
        x = x + L.linear(ap["wo"], o.reshape(b, -1))[:, None]
        x = x + L.mlp(ap["ffn"], L.rmsnorm(ap["ffn_norm"], x, cfg.norm_eps))
        return x, kc, vc

    def layer(carry, inp):
        x, kall, vall = carry
        idx, mp, sst, cst = inp
        h, st = S.mamba2_block(cfg, mp, L.rmsnorm(mp["norm"], x, cfg.norm_eps),
                               state=S.SSDState(sst, cst), single_step=True)
        x = x + h
        site = (idx + 1) // k_every - 1

        def with_attn(args):
            x, kall, vall = args
            kc = kall[jnp.maximum(site, 0)]
            vc = vall[jnp.maximum(site, 0)]
            x, kc, vc = site_attend(x, kc, vc)
            kall = lax.dynamic_update_index_in_dim(kall, kc, jnp.maximum(site, 0), 0)
            vall = lax.dynamic_update_index_in_dim(vall, vc, jnp.maximum(site, 0), 0)
            return x, kall, vall

        carry_out = lax.cond((idx + 1) % k_every == 0, with_attn,
                             lambda a: a, (x, kall, vall))
        return carry_out, (st.state, st.conv)

    idxs = jnp.arange(cfg.num_layers)
    (x, nk, nv), (nss, ncs) = lax.scan(
        layer, (x, cache.k, cache.v), (idxs, p["mamba"], cache.ssm_state, cache.conv_state))
    hidden = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = (hidden[:, 0] @ p["lm_head"]).astype(jnp.float32)
    return logits, ZambaCache(nss, ncs, nk, nv, pos + 1)
