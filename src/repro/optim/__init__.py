from repro.optim.optimizers import sgd, adam, Optimizer  # noqa: F401
