"""Minimal functional optimizers (no optax in the container)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_zeros_like


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]   # (grads, state, params) -> (updates, state)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    if momentum == 0.0:
        return Optimizer(
            init=lambda p: (),
            update=lambda g, s, p: (jax.tree.map(lambda x: -lr * x, g), s),
        )

    def update(g, s, p):
        s = jax.tree.map(lambda m, x: momentum * m + x, s, g)
        return jax.tree.map(lambda m: -lr * m, s), s

    return Optimizer(init=tree_zeros_like, update=update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(p):
        return (tree_zeros_like(p), tree_zeros_like(p), jnp.zeros((), jnp.int32))

    def update(g, s, p):
        m, v, t = s
        t = t + 1
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        tf = t.astype(jnp.float32)
        up = jax.tree.map(
            lambda m_, v_: -lr * (m_ / (1 - b1**tf)) / (jnp.sqrt(v_ / (1 - b2**tf)) + eps),
            m, v)
        return up, (m, v, t)

    return Optimizer(init=init, update=update)
