from repro.sharding.logical import (  # noqa: F401
    Param,
    ParamFactory,
    axes_tree,
    boxed_like,
    unbox,
)
from repro.sharding.context import (  # noqa: F401
    constrain,
    get_rules,
    set_rules,
    clear_rules,
    sharding_for_axes,
    param_shardings,
)
from repro.sharding.rules import (  # noqa: F401
    DECODE_RULES,
    TRAIN_RULES,
    make_rules,
)
