"""Mesh/rules context threading for model code.

Model code never mentions mesh axes directly; it calls ``constrain(x, logical)``
with logical axis names. The launcher installs (mesh, rules) here; on CPU
tests nothing is installed and ``constrain`` is the identity — the same model
code runs in unit tests and in the 512-chip dry-run.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.logical import Param, axes_tree, is_param

_state = threading.local()


def set_rules(mesh: Mesh, rules: Dict[str, Optional[Tuple[str, ...]]]):
    _state.mesh = mesh
    _state.rules = rules


def clear_rules():
    _state.mesh = None
    _state.rules = None


def get_rules():
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", None)
    return mesh, rules


def spec_for_axes(axes, rules) -> P:
    parts = []
    for a in axes:
        m = rules.get(a) if a is not None else None
        if m is None:
            parts.append(None)
        elif len(m) == 1:
            parts.append(m[0])
        else:
            parts.append(tuple(m))
    return P(*parts)


def sharding_for_axes(axes) -> Optional[NamedSharding]:
    mesh, rules = get_rules()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for_axes(axes, rules))


def constrain(x, logical_axes):
    """with_sharding_constraint by logical axis names (identity off-mesh)."""
    s = sharding_for_axes(logical_axes)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def param_shardings(boxed_tree):
    """NamedSharding tree for a boxed parameter tree (for jit in_shardings)."""
    mesh, rules = get_rules()
    if mesh is None:
        raise RuntimeError("no mesh installed; call set_rules() first")

    def one(p):
        if is_param(p):
            return NamedSharding(mesh, spec_for_axes(p.axes, rules))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, boxed_tree, is_leaf=is_param)
