"""Logical-axis-annotated parameters.

``Param`` is a transparent pytree box pairing an array (or ShapeDtypeStruct in
abstract mode) with a tuple of logical axis names — the single source of truth
consumed by (a) the sharding rules that turn logical axes into mesh
``PartitionSpec``s and (b) the FedSubAvg ``HeatSpec`` that finds feature-keyed
leaves ("vocab", "experts").

Because Param registers its axes as pytree aux data, trees of Params flow
through jit/grad/optimizers unchanged: gradients come back boxed with the same
axes, so heat correction and sharding never need a second bookkeeping tree.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

AxisNames = Tuple[Optional[str], ...]


class Param:
    """Array + logical axis names; transparent single-child pytree node."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes: AxisNames):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Param(shape={shape}, axes={self.axes})"


def _param_flatten(p: Param):
    return (p.value,), p.axes


def _param_unflatten(axes, children):
    return Param(children[0], axes)


jax.tree_util.register_pytree_node(Param, _param_flatten, _param_unflatten)


def is_param(x) -> bool:
    return isinstance(x, Param)


def unbox(tree):
    """Strip Param boxes -> plain array tree (used at apply-fn entry)."""
    return jax.tree.map(lambda p: p.value if is_param(p) else p, tree, is_leaf=is_param)


def axes_tree(tree):
    """Extract the logical-axes tree (leaves: tuples of axis names)."""
    return jax.tree.map(lambda p: p.axes if is_param(p) else None, tree, is_leaf=is_param)


def boxed_like(values, boxed_template):
    """Re-box a plain value tree using the axes of a boxed template."""
    return jax.tree.map(
        lambda v, p: Param(v, p.axes) if is_param(p) else v,
        values,
        boxed_template,
        is_leaf=lambda x: x is None,
    )


class ParamFactory:
    """Creates initialized or abstract parameters with logical axes.

    ``abstract=True`` produces ``jax.ShapeDtypeStruct`` leaves — no device
    allocation, which is how the dry-run instantiates 100B+ configurations on
    a 35 GB host.
    """

    def __init__(self, rng: Optional[jax.Array] = None, abstract: bool = False,
                 dtype=jnp.bfloat16):
        self.rng = rng
        self.abstract = abstract
        self.dtype = dtype
        self._count = 0

    def _next_rng(self):
        self._count += 1
        return jax.random.fold_in(self.rng, self._count)

    def __call__(self, shape, axes: AxisNames, init: str = "fan_in",
                 dtype=None, stack: int = 0) -> Param:
        """``stack`` > 0 prepends a scan-stacked layer dimension (axis "layers")."""
        dtype = dtype or self.dtype
        if stack:
            shape = (stack,) + tuple(shape)
            axes = ("layers",) + tuple(axes)
        assert len(shape) == len(axes), (shape, axes)
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(shape), dtype), axes)
        if init == "zeros":
            v = jnp.zeros(shape, dtype)
        elif init == "ones":
            v = jnp.ones(shape, dtype)
        elif init == "normal":
            v = (0.02 * jax.random.normal(self._next_rng(), shape, jnp.float32)).astype(dtype)
        elif init == "fan_in":
            fan = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / np.sqrt(max(fan, 1))
            v = (std * jax.random.normal(self._next_rng(), shape, jnp.float32)).astype(dtype)
        elif init == "ssm_a":
            # mamba2 A_log init: log of uniform [1, 16]
            u = jax.random.uniform(self._next_rng(), shape, jnp.float32, 1.0, 16.0)
            v = jnp.log(u).astype(jnp.float32)  # keep fp32 for stability
        else:
            raise ValueError(init)
        return Param(v, axes)
