"""Logical axis -> mesh axis rules per workload kind.

Baseline layout (single pod, mesh ("data","model"); multi-pod prepends "pod"):

    batch/clients   -> ("pod","data")     cohort / request parallelism
    vocab rows      -> "model"            the paper's huge embedding layer
    ffn hidden      -> "model"            Megatron-style MLP TP
    fused q heads   -> "model"
    fused kv dim    -> "model"            (fused KV*head_dim is divisible by 16)
    experts         -> None (TP baseline) | "model" (expert-parallel variant)
    kv cache seq    -> "model" (decode)   flash-decode seq sharding
    everything else -> replicated

Rules are plain dicts so perf iterations can swap entries and re-lower.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

MeshAxes = Optional[Tuple[str, ...]]


def make_rules(kind: str, multi_pod: bool = False, expert_parallel: bool = False,
               seq_shard_decode: bool = True) -> Dict[str, MeshAxes]:
    batch = ("pod", "data") if multi_pod else ("data",)
    rules: Dict[str, MeshAxes] = {
        "batch": batch,
        "clients": batch,
        "vocab": ("model",),
        # expert parallelism moves the model axis to the expert dim; each
        # expert's FFN then lives intact on one shard group
        "ffn": None if expert_parallel else ("model",),
        "heads": ("model",),       # fused H*head_dim projection columns
        "kv": ("model",),          # fused KV*head_dim projection columns
        "embed": None,
        "layers": None,
        "state": None,
        "conv": None,
        "experts": ("model",) if expert_parallel else None,
        # attention ACTIVATION head axes: set to ("model",) per-arch by the
        # launcher when num_heads divides the model axis; otherwise heads stay
        # replicated in activations (partial-head sharding makes XLA contract
        # over a sharded head_dim -> per-chunk all-reduces, see §Perf iter 7)
        "heads_act": None,
        "kv_act": None,
        "seq": None,
        "kv_seq": ("model",) if (kind in ("decode", "prefill") and seq_shard_decode) else None,
        "kv_heads": None,           # cache head axis (8 heads % 16 != 0 -> replicated)
    }
    return rules


TRAIN_RULES = make_rules("train")
DECODE_RULES = make_rules("decode")
