"""Sparse submodel update plane (row-sparse deltas end-to-end).

The dense FedAvg-style server materialises a (V, D) update per client; this
package keeps every feature-keyed leaf in ``(ids, rows)`` form from client
encoding through server aggregation to the parameter apply — the systems half
of the paper's submodel story. See DESIGN.md for the architecture.
"""
from repro.sparse.rowsparse import (  # noqa: F401
    PAD_ID,
    RowSparse,
    count_unique_ids,
    is_rowsparse,
    remap_ids,
    unique_ids_padded,
)
from repro.sparse.encode import (  # noqa: F401
    DEFAULT_SPARSE_SPACES,
    batch_union_ids,
    decode_delta_tree,
    encode_delta_tree,
    gather_submodel_tree,
    remap_feature_batch,
    sparse_eligible,
    submodel_delta_tree,
    submodel_value_and_grad,
    tree_leaf_at,
)
from repro.sparse.aggregate import (  # noqa: F401
    aggregate_rowsparse,
    aggregate_rowsparse_dense,
    aggregate_rowsparse_partial,
    apply_rowsparse,
    combine_rowsparse_partials,
    heat_factor_at,
    pick_combine,
    sparse_cohort_aggregate,
)
from repro.sparse.compress import (  # noqa: F401
    QuantRows,
    dequantize_rows,
    quantize_rows_int8,
    quantize_tree_int8,
    topk_rows,
)
from repro.sparse.comm import (  # noqa: F401
    CommStats,
    leaf_wire_bytes,
    round_comm_stats,
    tree_wire_bytes,
)
