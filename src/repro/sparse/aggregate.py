"""Server-side aggregation over row-sparse cohort updates.

The FedSubAvg server step on the sparse plane is a segment-sum: every client
contributes ``(ids_i, rows_i)``; the server sums rows landing on the same
feature id, scales by ``1/K`` (cohort mean) and fuses the heat correction
``N / n_m`` — one pass over the non-zeros, never touching cold rows.

Three union backends, selected at runtime (``union_backend="auto"``):

``bitmap``  mark touched rows in a (V,) bitmap, rank by cumsum — O(V)
            streamed vector work, the CPU fast path for moderate V.
``sort``    sort/searchsorted — O(T log T), for huge feature spaces.
``pallas``  the fused ``union_segsum`` kernel (``repro.kernels``): union
            build, segment-sum and heat scaling in one blocked TPU program —
            the server hot-loop path whenever the union fits VMEM (compiled
            on TPU; interpret-mode parity elsewhere).

``aggregate_rowsparse_dense`` additionally routes through the dense-output
``rowsparse_scatter`` kernel when the server applies into a dense table.

Cohort-sharded rounds split the segment-sum in two: each device shard runs
``aggregate_rowsparse_partial`` over its own clients (a plain union
segment-sum — no heat, no cohort scale), and ``combine_rowsparse_partials``
reduces the per-shard partial unions across the mesh axis inside
``shard_map`` — either a ``psum`` of the densified rows (small tables) or a
gathered union-of-unions that stays RowSparse (large tables), with the heat
correction and cohort mean fused exactly once at the combine.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.aggregate import HeatSpec, correct_dense_leaf
from repro.core.heat import heat_correction_factors
from repro.sparse.encode import DEFAULT_SPARSE_SPACES
from repro.sparse.rowsparse import RowSparse, is_rowsparse, remap_ids, unique_ids_padded

Array = jax.Array


def heat_factor_at(heat: Array, ids: Array, total: float,
                   scale: float = 1.0) -> Array:
    """Per-row ``scale * N / n_m`` gathered at ``ids`` (0 for cold/pad rows).

    The single source of the FedSubAvg correction in gathered (row-sparse)
    form — the dense-broadcast twin is ``heat_correction_factors``.
    """
    h = jnp.take(heat, jnp.maximum(ids, 0))
    f = jnp.where(h > 0, total / jnp.maximum(h, 1.0), 0.0)
    return jnp.where(ids >= 0, f * scale, 0.0)


def correct_rowsparse(rs: RowSparse, heat: Optional[Array], total: float,
                      scale: float = 1.0) -> RowSparse:
    """Scale an unbatched RowSparse by ``scale * N / n_m`` (heat given) or by
    ``scale`` with padding rows zeroed (heat ``None`` — the FedAvg baseline).

    The RowSparse twin of ``correct_dense_leaf``: both sparse server paths
    (fused aggregation and the flat fedsgd-on-sparse plan) route through it,
    so the correction can never drift between them.
    """
    if heat is not None:
        factor = heat_factor_at(jnp.asarray(heat), rs.ids, total, scale)
    else:
        factor = jnp.where(rs.ids >= 0, scale, 0.0)
    bshape = factor.shape + (1,) * (rs.rows.ndim - rs.ids.ndim)
    return RowSparse(rs.ids, rs.rows * factor.reshape(bshape), rs.num_rows)


#: dense-bitmap union is O(V) vectorised work and V bits of scratch — the
#: fast path whenever the feature space fits comfortably in cache-adjacent
#: memory; beyond this the O(T log T) sort path takes over.
_BITMAP_MAX_ROWS = 1 << 22


def _resolve_backend(backend: str, num_rows: int, cap: int,
                     row_elems: int, num_elems: int) -> str:
    """Runtime union-backend selection for ``"auto"``.

    On TPU the fused ``union_segsum`` kernel wins whenever its VMEM-resident
    union fits the budget; otherwise (and everywhere on CPU, where the
    interpreter would crawl) the jnp backends split by feature-space size.
    ``num_rows``/``num_elems`` are forwarded so the budget check uses the
    same block sizes the kernel will actually pick.
    """
    if backend != "auto":
        return backend
    from repro.kernels.heat_scatter import on_tpu
    from repro.kernels.union_segsum import fits_vmem
    # the kernel's grid scales with V/v_blk, so beyond the bitmap regime the
    # sort backend wins regardless of how small the union is
    if (on_tpu() and num_rows <= _BITMAP_MAX_ROWS
            and fits_vmem(cap, row_elems, num_rows=num_rows, t=num_elems)):
        return "pallas"
    return "bitmap" if num_rows <= _BITMAP_MAX_ROWS else "sort"


def _union_and_slots(flat_ids: Array, num_rows: int, cap: int, backend: str):
    """(union ids (cap,), per-element slot (T,)) under either jnp backend.

    ``bitmap``: mark touched rows in a (V,) bitmap, rank by cumsum, compact
    with size-bounded ``nonzero`` — no sort, everything streams. ``sort``:
    the generic O(T log T) path for huge feature spaces. (The ``pallas``
    backend never materialises slots — ``aggregate_rowsparse`` dispatches to
    the fused ``union_segsum`` kernel before reaching here.)
    """
    if backend == "auto":
        backend = "bitmap" if num_rows <= _BITMAP_MAX_ROWS else "sort"
    if backend == "bitmap":
        safe = jnp.where(flat_ids >= 0, flat_ids, num_rows)
        mark = jnp.zeros((num_rows,), bool).at[safe].set(True, mode="drop")
        rank = jnp.cumsum(mark.astype(jnp.int32)) - 1
        union = jnp.nonzero(mark, size=cap, fill_value=-1)[0].astype(jnp.int32)
        pos = jnp.take(rank, jnp.minimum(safe, num_rows - 1))
        pos = jnp.where(flat_ids >= 0, pos, cap)         # pads -> dropped
        return union, pos
    if backend == "sort":
        union = unique_ids_padded(flat_ids, cap)
        pos = remap_ids(flat_ids, union)
        return union, jnp.where(flat_ids >= 0, pos, cap)
    raise ValueError(backend)


def aggregate_rowsparse(stacked: RowSparse, heat: Optional[Array] = None,
                        total: float = 1.0, scale: float = 1.0,
                        union_capacity: Optional[int] = None,
                        union_backend: str = "auto") -> RowSparse:
    """Segment-sum a stacked cohort ``RowSparse`` into its union-id rows.

    ``stacked``: ids ``(K, R)``, rows ``(K, R, ...)``. Returns an unbatched
    RowSparse on the cohort's union ids (capacity ``min(V, K*R)`` unless
    given), rows scaled by ``scale`` and — when ``heat`` is provided — by the
    fused FedSubAvg correction ``total / n_m``. O(K R D) on the payload plus
    the union cost (bitmap: O(V) streamed; sort: O(K R log K R)); the dense
    ``(V, D)`` update is never materialised.
    """
    k, r = stacked.ids.shape
    cap = union_capacity or min(stacked.num_rows, k * r)
    flat_ids = stacked.ids.reshape(-1)
    flat_rows = stacked.rows.reshape((k * r,) + tuple(stacked.rows.shape[2:]))
    row_elems = int(flat_rows.size) // max(k * r, 1)

    union_backend = _resolve_backend(union_backend, stacked.num_rows, cap,
                                     row_elems, k * r)
    if union_backend == "pallas":
        from repro.kernels import ops
        # total/scale pass through untouched — the kernel takes them as
        # traced scalar operands, so they may be tracers (no recompile)
        union, summed = ops.union_segsum(
            flat_ids, flat_rows, heat, total, cap, stacked.num_rows,
            scale=scale)
        return RowSparse(union, summed, stacked.num_rows)

    union, pos = _union_and_slots(flat_ids, stacked.num_rows, cap, union_backend)
    summed = jnp.zeros((cap,) + tuple(flat_rows.shape[1:]), jnp.float32)
    summed = summed.at[pos].add(flat_rows.astype(jnp.float32), mode="drop")
    return correct_rowsparse(RowSparse(union, summed, stacked.num_rows),
                             heat, total, scale)


#: psum-densify combine budget (bytes of one dense ``(V, row_elems)`` f32
#: buffer). Mirrors the ``fits_vmem`` philosophy of the pallas backend pick:
#: below the budget an all-reduce of the densified union rows is one fused
#: collective; above it the gathered union-of-unions keeps the RowSparse form
#: and never materialises the (V, D) table — every shard would otherwise pay
#: an O(V * D) densify + all-reduce per table per round (V=65k x D=16 is
#: already 4 MiB of dense traffic for a union of a few hundred rows).
_PSUM_COMBINE_MAX_BYTES = 1 << 21


def pick_combine(num_rows: int, row_elems: int, combine: str = "auto") -> str:
    """Resolve the cross-shard combine strategy for a sharded aggregation.

    ``"psum"``: densify each shard's partial union to ``(V, ...)`` and
    all-reduce — cheapest when the dense buffer is small. ``"union"``:
    all-gather the per-shard partial unions and run a second (replicated)
    union segment-sum — the RowSparse form survives, so huge feature spaces
    never pay a dense ``(V, D)`` collective. ``"auto"`` picks by the dense
    buffer's byte size, the same budget-style heuristic the union backend
    uses for its VMEM fit.
    """
    if combine != "auto":
        if combine not in ("psum", "union"):
            raise ValueError(f"unknown combine strategy {combine!r}: "
                             "expected 'auto', 'psum' or 'union'")
        return combine
    dense_bytes = int(num_rows) * max(int(row_elems), 1) * 4
    return "psum" if dense_bytes <= _PSUM_COMBINE_MAX_BYTES else "union"


def aggregate_rowsparse_partial(stacked: RowSparse,
                                union_capacity: Optional[int] = None,
                                union_backend: str = "auto") -> RowSparse:
    """Per-shard partial reduction: union segment-sum with NO heat, NO scale.

    One device shard's half of the sharded cohort aggregation: its clients'
    stacked ``(K_shard, R)`` deltas collapse onto the shard's union ids.
    The FedSubAvg correction and the ``1/K`` cohort mean are deliberately NOT
    applied — they are per-row multiplicative and must enter exactly once, at
    :func:`combine_rowsparse_partials`, after the cross-shard sum.
    """
    return aggregate_rowsparse(stacked, heat=None, total=1.0, scale=1.0,
                               union_capacity=union_capacity,
                               union_backend=union_backend)


def combine_rowsparse_partials(partial: RowSparse, axis_name: str,
                               num_shards: int, heat: Optional[Array],
                               total: float, scale: float = 1.0,
                               combine: str = "auto",
                               union_backend: str = "auto"):
    """Cross-device combine of per-shard partial unions (shard_map only).

    ``partial`` is this shard's :func:`aggregate_rowsparse_partial` output;
    the return value is the SAME on every shard (the replicated global
    aggregate), so the server apply that follows is identical everywhere:

    ``psum``   densify the shard partial and all-reduce; returns the dense
               corrected ``(V, ...)`` update (cold rows are exact zeros).
    ``union``  all-gather the shard unions into a ``(num_shards, cap)`` stack
               and run the ordinary :func:`aggregate_rowsparse` over it —
               every shard computes the same global union; returns RowSparse.

    Either way the heat correction (``total / n_m``) and ``scale`` are fused
    here, once, exactly as the single-device fused aggregation applies them.
    """
    row_elems = 1
    for d in partial.rows.shape[1:]:
        row_elems *= int(d)
    mode = pick_combine(partial.num_rows, row_elems, combine)
    if mode == "psum":
        dense = lax.psum(partial.to_dense().astype(jnp.float32), axis_name)
        if heat is not None:
            factors = heat_correction_factors(heat, total) * scale
        else:
            factors = jnp.full((partial.num_rows,), scale, jnp.float32)
        return dense * factors.reshape((-1,) + (1,) * (dense.ndim - 1))
    ids_g = lax.all_gather(partial.ids, axis_name)        # (ndev, cap)
    rows_g = lax.all_gather(partial.rows, axis_name)      # (ndev, cap, ...)
    stacked = RowSparse(ids_g, rows_g, partial.num_rows)
    cap = min(partial.num_rows, int(num_shards) * partial.capacity)
    return aggregate_rowsparse(stacked, heat, total, scale,
                               union_capacity=cap, union_backend=union_backend)


def aggregate_rowsparse_dense(stacked: RowSparse, heat: Array, total: float,
                              scale: float = 1.0, backend: str = "auto") -> Array:
    """Cohort aggregation to a *dense* corrected update ``(V, ...)``.

    ``backend="pallas"`` routes through the fused ``rowsparse_scatter`` TPU
    kernel (interpret-mode on CPU); ``"jnp"`` segment-sums into the union and
    scatters once; ``"auto"`` picks pallas on TPU, jnp elsewhere.
    """
    if backend == "auto":
        from repro.kernels.heat_scatter import on_tpu
        backend = "pallas" if on_tpu() else "jnp"
    if backend == "pallas":
        from repro.kernels import ops
        k, r = stacked.ids.shape
        flat_ids = stacked.ids.reshape(-1)
        rows = stacked.rows.reshape(k * r, -1)
        out = ops.rowsparse_scatter(flat_ids, rows, jnp.asarray(heat, jnp.float32),
                                    total, stacked.num_rows, scale=scale)
        return out.reshape((stacked.num_rows,) + tuple(stacked.rows.shape[2:]))
    if backend == "jnp":
        return aggregate_rowsparse(stacked, heat, total, scale).to_dense()
    raise ValueError(backend)


def sparse_cohort_aggregate(updates, heat_spec: HeatSpec,
                            heat_counts: Dict[str, Array], total: float,
                            num_clients_in_cohort: int, correct: bool = True,
                            spaces: Sequence[str] = DEFAULT_SPARSE_SPACES,
                            union_backend: str = "auto"):
    """Tree-level cohort aggregation mixing RowSparse and dense leaves.

    ``updates``: per-client stack — RowSparse leaves carry ``(K, R)`` ids,
    dense leaves are ``(K, ...)``. Returns the corrected cohort-mean update:
    RowSparse union leaves for the sparse plane; dense leaves are cohort
    means, with the broadcast heat correction applied to the ones that still
    carry a feature space (e.g. an LM head with vocab on a trailing axis) —
    exactly matching the dense server's ``correct_update_tree``.

    With ``correct=False`` this is sparse FedAvg — identical execution path,
    no heat scaling — so baselines stay comparable.
    """
    scale = 1.0 / float(num_clients_in_cohort)

    def agg(leaf, space):
        if is_rowsparse(leaf):
            heat = None
            if correct and space is not None and space[0] in heat_counts:
                heat = heat_counts[space[0]]
            return aggregate_rowsparse(leaf, heat, total, scale,
                                       union_backend=union_backend)
        mean = leaf.mean(axis=0)
        if correct:
            mean = correct_dense_leaf(mean, space, heat_counts, total)
        return mean

    def is_leaf(x):
        return x is None or is_rowsparse(x)

    return jax.tree.map(agg, updates, heat_spec.leaf_spaces, is_leaf=is_leaf)


def apply_rowsparse(table: Array, rs: RowSparse, scale: float = 1.0) -> Array:
    """``table + scale * rs`` without densifying the update."""
    safe = jnp.where(rs.ids >= 0, rs.ids, rs.num_rows)
    add = (rs.rows * scale).astype(table.dtype)
    return table.at[safe].add(add, mode="drop")
