"""Communication-cost accounting for federated rounds.

FedSubAvg's systems win is bytes-on-wire: clients download and upload rows
for their submodel only. This module prices a round in bytes — dense baseline
vs the sparse plane, uplink (client -> server deltas) and downlink (server ->
client submodels) — from static shapes plus the actual non-padding id counts,
so the numbers are exact, not estimates.

``CommStats`` per round is surfaced through ``FederatedTrainer.comm_log`` and
summarised by ``repro.federated.metrics.comm_summary``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence

import jax
import numpy as np

from repro.sparse.compress import QuantRows
from repro.sparse.rowsparse import RowSparse, is_rowsparse

_ID_BYTES = 4          # int32 row ids
_SCALE_BYTES = 4       # f32 per-row dequant scale


@dataclass
class CommStats:
    """Bytes-on-wire for one federated round (cohort of ``clients``)."""

    round: int
    clients: int
    bytes_up_dense: float        # dense baseline: every client ships (V, D)
    bytes_up_sparse: float       # sparse plane: ids + touched rows (+ scales)
    bytes_down_dense: float      # dense baseline: full model broadcast
    bytes_down_sparse: float     # submodel download: touched rows + dense leaves
    rows_total: int              # sum over clients of dense feature rows
    rows_sent: int               # sum over clients of rows actually shipped

    @property
    def density(self) -> float:
        return self.rows_sent / max(self.rows_total, 1)

    @property
    def up_ratio(self) -> float:
        """Dense/sparse uplink compression factor (>1 means sparse wins)."""
        return self.bytes_up_dense / max(self.bytes_up_sparse, 1.0)

    def as_dict(self) -> Dict[str, float]:
        return {
            "round": self.round, "clients": self.clients,
            "bytes_up_dense": self.bytes_up_dense,
            "bytes_up_sparse": self.bytes_up_sparse,
            "bytes_down_dense": self.bytes_down_dense,
            "bytes_down_sparse": self.bytes_down_sparse,
            "density": self.density, "up_ratio": self.up_ratio,
        }


def _row_payload_bytes(shape: Sequence[int], itemsize: int) -> int:
    """Bytes of one row of a (V, ...) leaf."""
    n = 1
    for d in shape[1:]:
        n *= int(d)
    return max(n, 1) * itemsize


def leaf_wire_bytes(leaf: Any) -> float:
    """On-wire bytes of one update leaf in its current representation."""
    if isinstance(leaf, QuantRows):
        valid = int(np.asarray((leaf.ids >= 0).sum()))
        per_row = _row_payload_bytes((0,) + tuple(leaf.q.shape[leaf.ids.ndim:]), 1)
        return valid * (_ID_BYTES + per_row + _SCALE_BYTES)
    if is_rowsparse(leaf):
        valid = int(np.asarray((leaf.ids >= 0).sum()))
        per_row = _row_payload_bytes((0,) + tuple(leaf.rows.shape[leaf.ids.ndim:]),
                                     np.dtype(leaf.rows.dtype).itemsize)
        return valid * (_ID_BYTES + per_row)
    arr = np.asarray(jax.tree.leaves(leaf)[0]) if not hasattr(leaf, "dtype") else leaf
    return float(np.prod(arr.shape)) * np.dtype(arr.dtype).itemsize


def tree_wire_bytes(tree: Any) -> float:
    """Total on-wire bytes of an update tree (RowSparse/QuantRows aware)."""
    total = 0.0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: is_rowsparse(x) or isinstance(x, QuantRows)):
        total += leaf_wire_bytes(leaf)
    return total


def round_comm_stats(rnd: int, dense_model_bytes: float,
                     sparse_static_bytes: float, row_payload_bytes: float,
                     valid_ids_per_client: np.ndarray, num_features: int,
                     int8: bool = False, row_elems: Optional[int] = None,
                     uplink_rows_per_client: Optional[np.ndarray] = None) -> CommStats:
    """Price one round from host-side metadata (exact, no estimation).

    ``dense_model_bytes``: full parameter tree size — the per-client payload
    of the dense baseline in both directions. ``sparse_static_bytes``: the
    dense (non-feature-keyed) leaves, which the sparse plane still ships
    whole. ``row_payload_bytes``: bytes per feature row summed over the
    sparse-plane tables; ``row_elems``: elements per row (for int8 pricing
    at 1 byte/element regardless of the table dtype). ``valid_ids_per_client``:
    (K,) per-client unique-feature counts — the *submodel* size, which prices
    the downlink and the density. ``uplink_rows_per_client`` (defaults to the
    same) prices the uplink delta, which top-k sparsification can shrink
    below the submodel size.
    """
    k = len(valid_ids_per_client)
    rows_down = int(np.asarray(valid_ids_per_client).sum())
    rows_up = (rows_down if uplink_rows_per_client is None
               else int(np.asarray(uplink_rows_per_client).sum()))
    up_row = row_payload_bytes
    if int8:
        # int8 payload (1 byte/element) + one f32 scale per row
        up_row = float(row_elems if row_elems is not None
                       else row_payload_bytes / 4.0) + _SCALE_BYTES
    sparse_up = k * sparse_static_bytes + rows_up * (_ID_BYTES + up_row)
    sparse_down = k * sparse_static_bytes + rows_down * (_ID_BYTES + row_payload_bytes)

    return CommStats(
        round=rnd, clients=k,
        bytes_up_dense=k * dense_model_bytes,
        bytes_up_sparse=sparse_up,
        bytes_down_dense=k * dense_model_bytes,
        bytes_down_sparse=sparse_down,
        rows_total=k * num_features,
        rows_sent=rows_down,
    )
