"""Communication-cost accounting for federated rounds.

FedSubAvg's systems win is bytes-on-wire: clients download and upload rows
for their submodel only. This module prices a round in bytes — dense baseline
vs the sparse plane, uplink (client -> server deltas) and downlink (server ->
client submodels) — from static shapes plus the actual non-padding id counts,
so the numbers are exact, not estimates.

``CommStats`` per round is surfaced through ``FederatedTrainer.comm_log`` and
summarised by ``repro.federated.metrics.comm_summary``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Sequence, Set

import jax
import numpy as np

from repro.common.pytree import tree_path_keys
from repro.sparse.compress import QuantRows
from repro.sparse.rowsparse import RowSparse, is_rowsparse

_ID_BYTES = 4          # int32 row ids
_SCALE_BYTES = 4       # f32 per-row dequant scale


class CommMeta(NamedTuple):
    """Static byte geometry of one model, the input to per-round pricing.

    ``dense_bytes``: full parameter tree (the dense baseline's per-client
    payload). ``sparse_static_bytes``: the non-feature-keyed leaves the
    sparse plane still ships whole. ``row_payload_bytes``: bytes of one row
    summed over the sparse-plane tables; ``row_elems``: elements of one row
    (for int8 pricing at 1 byte/element regardless of table dtype).
    """

    dense_bytes: float
    sparse_static_bytes: float
    row_payload_bytes: float
    row_elems: int


def model_comm_meta(plain_params, sparse_paths: Set) -> CommMeta:
    """Derive :class:`CommMeta` from an (unboxed) parameter tree.

    ``sparse_paths``: set of ``tree_path_keys`` paths of the leaves riding
    the sparse plane (axis-0 feature tables).
    """
    dense_bytes = sparse_static = row_payload = 0.0
    row_elems = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(plain_params)[0]:
        nbytes = float(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        dense_bytes += nbytes
        if tree_path_keys(path) in sparse_paths:
            row_payload += nbytes / leaf.shape[0]
            row_elems += int(np.prod(leaf.shape)) // leaf.shape[0]
        else:
            sparse_static += nbytes
    return CommMeta(dense_bytes, sparse_static, row_payload, row_elems)


@dataclass
class CommStats:
    """Bytes-on-wire for one federated round (cohort of ``clients``).

    The dense baseline is the I=1 (FedSGD-equivalent) dense protocol at
    equal local compute: one full-model round-trip per local step, i.e.
    ``clients * model_bytes * local_iters`` each way (``local_iters`` factor
    1 unless the caller prices an I>1 round). The sparse plane amortises a
    single submodel download/upload over all I local steps.
    """

    round: int
    clients: int
    bytes_up_dense: float        # dense I=1 baseline: K * model * local_iters
    bytes_up_sparse: float       # sparse plane: ids + touched rows (+ scales)
    bytes_down_dense: float      # dense I=1 baseline: K * model * local_iters
    bytes_down_sparse: float     # submodel download: shipped rows + dense leaves
    rows_total: int              # sum over clients of dense feature rows
    rows_sent: int               # sum over clients of submodel (valid) rows

    @property
    def density(self) -> float:
        return self.rows_sent / max(self.rows_total, 1)

    @property
    def up_ratio(self) -> float:
        """Dense/sparse uplink compression factor (>1 means sparse wins)."""
        return self.bytes_up_dense / max(self.bytes_up_sparse, 1.0)

    def as_dict(self) -> Dict[str, float]:
        return {
            "round": self.round, "clients": self.clients,
            "bytes_up_dense": self.bytes_up_dense,
            "bytes_up_sparse": self.bytes_up_sparse,
            "bytes_down_dense": self.bytes_down_dense,
            "bytes_down_sparse": self.bytes_down_sparse,
            "density": self.density, "up_ratio": self.up_ratio,
        }


def _row_payload_bytes(shape: Sequence[int], itemsize: int) -> int:
    """Bytes of one row of a (V, ...) leaf."""
    n = 1
    for d in shape[1:]:
        n *= int(d)
    return max(n, 1) * itemsize


def leaf_wire_bytes(leaf: Any) -> float:
    """On-wire bytes of one update leaf in its current representation.

    Accepts RowSparse/QuantRows leaves, plain arrays, scalars, and arbitrary
    containers (priced as the sum of their sub-leaves; an empty container is
    0 bytes).
    """
    if isinstance(leaf, QuantRows):
        valid = int(np.asarray((leaf.ids >= 0).sum()))
        per_row = _row_payload_bytes((0,) + tuple(leaf.q.shape[leaf.ids.ndim:]), 1)
        return valid * (_ID_BYTES + per_row + _SCALE_BYTES)
    if is_rowsparse(leaf):
        valid = int(np.asarray((leaf.ids >= 0).sum()))
        per_row = _row_payload_bytes((0,) + tuple(leaf.rows.shape[leaf.ids.ndim:]),
                                     np.dtype(leaf.rows.dtype).itemsize)
        return valid * (_ID_BYTES + per_row)
    if hasattr(leaf, "dtype") and hasattr(leaf, "shape"):
        return float(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    sub = jax.tree.leaves(
        leaf, is_leaf=lambda x: is_rowsparse(x) or isinstance(x, QuantRows))
    if len(sub) == 1 and sub[0] is leaf:        # atomic scalar (int/float)
        arr = np.asarray(leaf)
        return float(np.prod(arr.shape)) * arr.dtype.itemsize
    return float(sum(leaf_wire_bytes(l) for l in sub))


def tree_wire_bytes(tree: Any) -> float:
    """Total on-wire bytes of an update tree (RowSparse/QuantRows aware)."""
    total = 0.0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: is_rowsparse(x) or isinstance(x, QuantRows)):
        total += leaf_wire_bytes(leaf)
    return total


def sharded_combine_bytes(meta: CommMeta, vocab: int, union_capacity: int,
                          num_shards: int, mode: str, *, num_tables: int = 1,
                          count_gather_ids: bool = False) -> Dict[str, float]:
    """Predicted cross-shard combine bytes of one sharded sparse round.

    The comm-plane half of the hlo_audit drift check: prices, per device and
    per HLO collective kind, the combine that ``combine_rowsparse_partials``
    emits for a cohort-sharded round — from the same :class:`CommMeta` that
    prices the client wire. ``mode`` is the resolved combine ("psum" or
    "union", see ``pick_combine``); ``union_capacity`` is the per-shard
    partial capacity whose ids/rows the union path all-gathers.
    ``count_gather_ids`` adds the flat path's extra ``used_ids`` all-gather
    (the cross-shard union count). Dense non-table leaves always ride an
    all-reduce; payloads are priced as f32 (the update-tree dtype).

    Loss / sub-row scalar reductions (a few bytes) are deliberately not
    priced — the drift check absorbs them in its absolute tolerance.
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0}
    row_bytes = float(meta.row_elems) * 4.0
    if mode == "psum":
        # per-table densified partial: (V, row_elems_t) f32, summed over
        # tables = V * row_elems * 4
        out["all-reduce"] += float(vocab) * row_bytes
    elif mode == "union":
        # per-table all-gather of the partial's ids (s32) + rows (f32)
        out["all-gather"] += float(num_shards) * float(union_capacity) * (
            float(num_tables) * _ID_BYTES + row_bytes)
    else:
        raise ValueError(f"unknown combine mode: {mode!r}")
    out["all-reduce"] += float(meta.sparse_static_bytes)
    if count_gather_ids:
        out["all-gather"] += (float(num_shards) * float(union_capacity)
                              * _ID_BYTES)
    return out


def round_comm_stats(rnd: int, dense_model_bytes: float,
                     sparse_static_bytes: float, row_payload_bytes: float,
                     valid_ids_per_client: np.ndarray, num_features: int,
                     int8: bool = False, row_elems: Optional[int] = None,
                     uplink_rows_per_client: Optional[np.ndarray] = None,
                     downlink_rows_per_client: Optional[np.ndarray] = None,
                     local_iters: int = 1) -> CommStats:
    """Price one round from host-side metadata (exact, no estimation).

    ``dense_model_bytes``: full parameter tree size — the per-client payload
    of the dense baseline in both directions. ``sparse_static_bytes``: the
    dense (non-feature-keyed) leaves, which the sparse plane still ships
    whole. ``row_payload_bytes``: bytes per feature row summed over the
    sparse-plane tables; ``row_elems``: elements per row (for int8 pricing
    at 1 byte/element regardless of the table dtype). ``valid_ids_per_client``:
    (K,) per-client unique-feature counts — the *submodel* size, which sets
    the density. ``uplink_rows_per_client`` (defaults to the same) prices the
    uplink delta, which top-k sparsification can shrink below the submodel
    size. ``downlink_rows_per_client`` (defaults to the same) prices the
    submodel download with the rows the server *actually ships* — e.g. the
    gathered ``capacity``-row replica buffer of sparse-replicated local
    training, or the full table for dense-replica local training. A client
    receiving the complete table (``rows == num_features``) gets no per-row
    id bytes: a full-table broadcast ships no row indices, only the
    contiguous payload.

    ``local_iters``: the dense baseline is the I=1 (FedSGD-style) dense
    protocol, which needs ``local_iters`` model round-trips to match one
    round of I local steps — so the baseline bytes scale by it. The sparse
    plane amortises the single submodel download/upload over all I steps.
    """
    k = len(valid_ids_per_client)
    rows_sent = int(np.asarray(valid_ids_per_client).sum())
    rows_up = (rows_sent if uplink_rows_per_client is None
               else int(np.asarray(uplink_rows_per_client).sum()))
    down = np.asarray(valid_ids_per_client if downlink_rows_per_client is None
                      else downlink_rows_per_client)
    rows_down = int(down.sum())
    # per-row ids accompany a submodel download only; a full-table broadcast
    # is a contiguous payload with no row indices
    id_bytes_down = float((np.where(down < num_features, down, 0)).sum()) * _ID_BYTES
    up_row = row_payload_bytes
    if int8:
        # int8 payload (1 byte/element) + one f32 scale per row
        up_row = float(row_elems if row_elems is not None
                       else row_payload_bytes / 4.0) + _SCALE_BYTES
    sparse_up = k * sparse_static_bytes + rows_up * (_ID_BYTES + up_row)
    sparse_down = (k * sparse_static_bytes + rows_down * row_payload_bytes
                   + id_bytes_down)
    dense_bytes = k * dense_model_bytes * max(int(local_iters), 1)

    return CommStats(
        round=rnd, clients=k,
        bytes_up_dense=dense_bytes,
        bytes_up_sparse=sparse_up,
        bytes_down_dense=dense_bytes,
        bytes_down_sparse=sparse_down,
        rows_total=k * num_features,
        rows_sent=rows_sent,
    )
