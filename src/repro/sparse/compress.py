"""Optional row compression for the sparse update plane.

Both schemes compose with the aggregator because they stay in the
``(ids, rows)`` format:

``topk_rows``       keep only the k rows with the largest payload norm —
                    magnitude-based sparsification of an already-sparse
                    update (biased, like all top-k schemes; the classic
                    error-feedback remedy lives client-side and is out of
                    scope here).
``quantize_rows_int8``  per-row symmetric int8 with *stochastic rounding*,
                    so dequantisation is unbiased: E[dq(q(x))] = x. The
                    wire payload drops 4x (plus one f32 scale per row).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sparse.rowsparse import PAD_ID, RowSparse, is_rowsparse

Array = jax.Array


def topk_rows(rs: RowSparse, k: int) -> RowSparse:
    """Keep the k largest-L2 rows of an unbatched RowSparse (capacity -> k)."""
    assert rs.ids.ndim == 1, "topk_rows expects an unbatched RowSparse"
    r = rs.capacity
    k = min(int(k), r)
    flat = rs.rows.reshape(r, -1).astype(jnp.float32)
    norms = jnp.where(rs.ids >= 0, (flat * flat).sum(-1), -1.0)
    _, keep = jax.lax.top_k(norms, k)
    keep = jnp.sort(keep)                       # preserve ascending-id order
    ids = jnp.take(rs.ids, keep)
    rows = jnp.take(rs.rows, keep, axis=0)
    # slots whose norm was the -1 padding sentinel stay padding
    valid = jnp.take(norms, keep) >= 0
    ids = jnp.where(valid, ids, PAD_ID)
    rows = rows * valid.reshape((k,) + (1,) * (rows.ndim - 1)).astype(rows.dtype)
    return RowSparse(ids, rows, rs.num_rows)


class QuantRows:
    """int8-quantised RowSparse payload: (ids, q, scales) pytree."""

    __slots__ = ("ids", "q", "scales", "num_rows")

    def __init__(self, ids, q, scales, num_rows: int):
        self.ids = ids
        self.q = q
        self.scales = scales
        self.num_rows = int(num_rows)

    def __repr__(self):
        return (f"QuantRows(ids={getattr(self.ids, 'shape', None)}, "
                f"q={getattr(self.q, 'shape', None)}, num_rows={self.num_rows})")


jax.tree_util.register_pytree_node(
    QuantRows,
    lambda qr: ((qr.ids, qr.q, qr.scales), qr.num_rows),
    lambda num_rows, c: QuantRows(c[0], c[1], c[2], num_rows),
)


def quantize_rows_int8(rs: RowSparse, key: Array) -> QuantRows:
    """Per-row symmetric int8 quantisation with stochastic rounding.

    ``q = floor(x / s + u)`` with ``u ~ U[0, 1)`` satisfies ``E[q * s] = x``;
    the scale ``s`` is ``max|row| / 127`` (1 for all-zero rows).
    """
    shape = rs.rows.shape
    lead = rs.ids.shape                          # (..., R)
    flat = rs.rows.reshape(lead + (-1,)).astype(jnp.float32)
    maxabs = jnp.abs(flat).max(axis=-1)
    scales = jnp.where(maxabs > 0, maxabs / 127.0, 1.0)
    u = jax.random.uniform(key, flat.shape)
    q = jnp.floor(flat / scales[..., None] + u)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return QuantRows(rs.ids, q.reshape(shape), scales, rs.num_rows)


def dequantize_rows(qr: QuantRows, dtype=jnp.float32) -> RowSparse:
    lead = qr.ids.shape
    flat = qr.q.reshape(lead + (-1,)).astype(jnp.float32)
    rows = (flat * qr.scales[..., None]).reshape(qr.q.shape).astype(dtype)
    return RowSparse(qr.ids, rows, qr.num_rows)


def topk_tree(tree, k: int):
    """Apply ``topk_rows`` to every RowSparse leaf of an update tree.

    Leaves may be unbatched ``(R,)`` ids or a per-client stack ``(K, R)``
    (vmapped). Dense leaves pass through unchanged.
    """

    def cut(leaf):
        if not is_rowsparse(leaf):
            return leaf
        if leaf.ids.ndim == 1:
            return topk_rows(leaf, k)
        return jax.vmap(lambda rs: topk_rows(rs, k))(leaf)

    return jax.tree.map(cut, tree, is_leaf=is_rowsparse)


def compress_delta_tree(tree, topk: int = 0, int8: bool = False,
                        key: Optional[Array] = None):
    """Wire-format compression of an update tree, RowSparse leaves only.

    The single client->server compression pipeline shared by every sparse
    execution path: optional top-k row selection, then optional int8
    stochastic-rounding quantisation *immediately dequantised* — what reaches
    the aggregator is exactly what a real wire round-trip would deliver, while
    the comm accounting prices the compressed form. Identity when both knobs
    are off.
    """
    if topk:
        tree = topk_tree(tree, topk)
    if int8:
        if key is None:
            raise ValueError("int8 compression draws stochastic-rounding "
                             "noise: pass a PRNG key")
        tree = jax.tree.map(
            lambda l: dequantize_rows(l) if isinstance(l, QuantRows) else l,
            quantize_tree_int8(tree, key),
            is_leaf=lambda x: isinstance(x, QuantRows))
    return tree


def quantize_tree_int8(tree, key: Array):
    """Quantize every RowSparse leaf of ``tree`` with an independent key.

    Each leaf's key is ``fold_in(key, leaf_index)``: reusing one key across
    leaves would draw the SAME stochastic-rounding noise for every feature
    table of a round, correlating their quantization errors (two tables with
    equal rows would round identically instead of independently). Dense
    leaves pass through unchanged.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_rowsparse)
    out = [quantize_rows_int8(l, jax.random.fold_in(key, i))
           if is_rowsparse(l) else l for i, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)
