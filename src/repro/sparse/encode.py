"""Encoders: dense update trees -> row-sparse submodel updates.

Two paths onto the sparse plane:

``encode_delta_tree``
    Post-hoc: a dense delta (or per-client stack of deltas) already exists;
    gather the rows its support lives on. Exact whenever the gather ids cover
    the delta's support — true by construction for lookup-table leaves
    (row_axis 0), whose gradient is zero outside the batch's feature ids
    ("the local gradient of X_{S\\S(i)} will always be zero", paper §3.1).

``submodel_value_and_grad``
    Ahead-of-time: never materialise the dense ``(V, D)`` gradient at all.
    The feature-keyed table is swapped for its gathered ``(R, D)`` rows and
    the batch's feature ids are remapped to row slots before the backward
    pass, so autodiff produces the row gradient directly — the paper's
    "download the submodel, train the submodel" made literal in JAX.

Output-head style leaves (vocab on a non-leading axis, dense softmax
gradients) are left dense; the sparse plane is for lookup tables.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregate import HeatSpec
from repro.sharding.logical import Param, is_param, unbox
from repro.sparse.rowsparse import RowSparse, is_rowsparse, remap_ids, unique_ids_padded

Array = jax.Array

#: feature spaces the sparse plane encodes by default (expert-keyed leaves are
#: typically fully touched per cohort; encoding them sparsely buys nothing)
DEFAULT_SPARSE_SPACES = ("vocab",)


def sparse_eligible(space: Optional[Tuple[str, int]],
                    spaces: Sequence[str] = DEFAULT_SPARSE_SPACES) -> bool:
    """A leaf rides the sparse plane iff it is feature-keyed on axis 0.

    Axis-0 feature leaves are lookup tables (grad support == batch ids);
    feature axes elsewhere (e.g. an LM head's trailing vocab axis) carry dense
    softmax gradients and must stay dense for exactness.
    """
    return space is not None and space[0] in spaces and space[1] == 0


def encode_delta_tree(delta, heat_spec: HeatSpec, ids: Array,
                      spaces: Sequence[str] = DEFAULT_SPARSE_SPACES):
    """Replace eligible feature-keyed leaves of ``delta`` with RowSparse.

    ``delta`` may be a single update (leaves ``(V, ...)``) or a per-client
    stack (leaves ``(K, V, ...)`` with ``ids`` of shape ``(K, R)``); boxed
    Param trees are unboxed. Dense leaves pass through unchanged.
    """
    plain = unbox(delta)
    batched = ids.ndim == 2

    def enc(leaf, space):
        if not sparse_eligible(space, spaces):
            return leaf
        if batched:
            return jax.vmap(RowSparse.from_dense)(leaf, ids)
        return RowSparse.from_dense(leaf, ids)

    return jax.tree.map(enc, plain, heat_spec.leaf_spaces,
                        is_leaf=lambda x: x is None)


def decode_delta_tree(tree):
    """Densify every RowSparse leaf (the parity/debug inverse of encode)."""
    return jax.tree.map(lambda l: l.to_dense() if is_rowsparse(l) else l, tree,
                        is_leaf=is_rowsparse)


# ---------------------------------------------------------------------------
# Gather-before-backward fast path
# ---------------------------------------------------------------------------


def tree_leaf_at(tree, path: Sequence):
    """Walk a nested dict/tuple/list tree to the leaf at ``path``."""
    node = tree
    for k in path:
        node = node[k]
    return node


def _set_leaf(tree, path: Sequence, value):
    if not path:
        return value
    k = path[0]
    if isinstance(tree, dict):
        out = dict(tree)
        out[k] = _set_leaf(tree[k], path[1:], value)
        return out
    if isinstance(tree, (tuple, list)):
        out = list(tree)
        out[k] = _set_leaf(tree[k], path[1:], value)
        return type(tree)(out) if isinstance(tree, tuple) else out
    raise TypeError(f"cannot set path {path!r} in {type(tree)}")


def submodel_value_and_grad(loss_fn: Callable, params, batch: Dict,
                            table_path: Sequence, feature_keys: Sequence[str],
                            ids: Array):
    """Loss + gradients with the table at ``table_path`` never densified.

    ``ids`` is the (sorted, -1-padded) union of the batch's feature ids for
    that table. The table leaf is swapped for its gathered ``(R, ...)`` rows,
    every ``batch[k]`` for k in ``feature_keys`` is remapped to row slots, and
    autodiff runs on the submodel — the returned gradient tree carries a
    ``RowSparse`` at ``table_path`` and dense gradients elsewhere.

    Exactness requires the model to consume the table only through lookups by
    those feature keys (true for every lookup-table leaf; not for tied
    embeddings doubling as an output head).
    """
    leaf = tree_leaf_at(params, table_path)
    boxed = is_param(leaf)
    table = leaf.value if boxed else leaf
    num_rows = table.shape[0]

    rows0 = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    sub_batch = dict(batch)
    for k in feature_keys:
        sub_batch[k] = remap_ids(batch[k], ids)

    # the dense table is removed from the differentiated tree entirely (its
    # slot becomes an empty subtree), so the single backward pass below never
    # allocates a (V, ...) gradient — only the (R, ...) row gradient.
    p_rest = _set_leaf(params, table_path, ())

    def joint_loss(rows, p):
        sub_leaf = Param(rows, leaf.axes) if boxed else rows
        return loss_fn(_set_leaf(p, table_path, sub_leaf), sub_batch)

    loss, (row_grad, rest_grad) = jax.value_and_grad(
        joint_loss, argnums=(0, 1))(rows0, p_rest)
    row_grad = row_grad * (ids >= 0).reshape(
        (-1,) + (1,) * (row_grad.ndim - 1)).astype(row_grad.dtype)
    grads = _set_leaf(rest_grad, table_path, RowSparse(ids, row_grad, num_rows))
    return loss, grads


def flat_feature_ids(batch: Dict, feature_keys: Sequence[str]) -> Array:
    """Every feature id of the batch as one flat vector (padding ids kept).

    The single source of "which ids does this cohort touch" for the flat
    pooled-batch layout — consumed by :func:`batch_union_ids` and by the
    telemetry plane's capacity-drop accounting, so the two can never
    disagree about what counts as a touched id.
    """
    return jnp.concatenate(
        [jnp.asarray(batch[k]).reshape(-1) for k in feature_keys])


def stacked_feature_ids(batch: Dict, feature_keys: Sequence[str]) -> Array:
    """Per-client ``(K, M)`` concatenation of the feature-id columns.

    The stacked-cohort sibling of :func:`flat_feature_ids`: row k holds every
    id client k's batch touches (across all feature keys, padding ids kept).
    Consumed by per-client sub-id derivation and by the telemetry plane's
    per-client drop accounting.
    """
    k = batch[feature_keys[0]].shape[0]
    return jnp.concatenate(
        [jnp.asarray(batch[fk]).reshape(k, -1) for fk in feature_keys], axis=1)


def batch_union_ids(batch: Dict, feature_keys: Sequence[str], capacity: int) -> Array:
    """Union of the batch's feature ids across keys, padded to ``capacity``."""
    return unique_ids_padded(flat_feature_ids(batch, feature_keys), capacity)


def pin_labels(data: Dict, feature_key: str = "tokens") -> Dict:
    """Pin CE targets to the ORIGINAL feature ids before a submodel remap.

    Every LM family's loss falls back to next-token targets derived from
    ``batch["tokens"]``; once ``remap_feature_batch`` (or the gather-before-
    backward swap) rewrites the token ids to submodel row slots, those derived
    targets would be row slots too — silently wrong. The fix is the same for
    every layout: when ``"labels"`` is absent, materialise them from the
    un-remapped ids by shifting the sequence (last) axis left and
    zero-padding, so ``(B, S)`` and ``(K, I, B, S)`` batches produce identical
    labels for identical sequences. No-op when labels are already present or
    the feature leaf has no sequence axis.
    """
    if "labels" in data or feature_key not in data:
        return data
    tokens = data[feature_key]
    if getattr(tokens, "ndim", 0) < 2:
        return data
    pad = [(0, 0)] * (tokens.ndim - 1) + [(0, 1)]
    return {**data, "labels": jnp.pad(tokens[..., 1:], pad)}


# ---------------------------------------------------------------------------
# Submodel replicas (shared by mode="sparse_replicated" and the trainer)
# ---------------------------------------------------------------------------


def gather_submodel_tree(params, table_paths: Sequence[Sequence], ids: Array):
    """Swap every table at ``table_paths`` for its gathered ``(R, ...)`` rows.

    ``ids`` is one client's sorted-unique, -1-padded submodel id vector; each
    feature-keyed table is replaced by ``RowSparse.from_dense`` row semantics
    (rows gathered at the ids, padding slots zeroed). Param boxes are kept so
    the gathered rows carry the table's logical axes. This is the "download
    the submodel" half of the paper's protocol: the resulting tree is the
    client's entire replica — O(capacity) feature rows instead of O(V).
    """
    out = params
    for path in table_paths:
        leaf = tree_leaf_at(params, path)
        boxed = is_param(leaf)
        table = leaf.value if boxed else leaf
        rows = RowSparse.from_dense(table, ids).rows
        out = _set_leaf(out, path, Param(rows, leaf.axes) if boxed else rows)
    return out


def remap_feature_batch(batch: Dict, feature_keys: Sequence[str],
                        ids: Array) -> Dict:
    """Remap each feature-carrying batch leaf to submodel row slots.

    Negative (padding) ids stay negative — the models' own masking
    convention; every non-negative id must appear in ``ids`` (true by
    construction when ``ids`` is derived from the same client's batch).
    """
    out = dict(batch)
    for k in feature_keys:
        out[k] = remap_ids(batch[k], ids)
    return out


def submodel_delta_tree(delta, table_paths: Sequence[Sequence], ids: Array,
                        num_rows: Sequence[int]):
    """Repackage a submodel-replica delta as a wire-format update tree.

    ``delta`` is a (possibly boxed) tree whose table leaves are gathered
    ``(R, ...)`` row deltas; the result is the unboxed tree with a
    ``RowSparse`` at each table path (padding rows zeroed) — exactly the
    shape ``encode_delta_tree`` produces, with no dense ``(V, ...)`` delta
    ever existing.
    """
    plain = unbox(delta)
    valid = ids >= 0
    for path, n in zip(table_paths, num_rows):
        rows = tree_leaf_at(plain, path)
        rows = rows * valid.reshape(
            valid.shape + (1,) * (rows.ndim - ids.ndim)).astype(rows.dtype)
        plain = _set_leaf(plain, path, RowSparse(ids, rows, n))
    return plain
