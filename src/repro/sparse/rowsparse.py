"""Row-sparse representation of feature-keyed update leaves.

A client (or cohort) update to a feature-keyed table ``(V, ...)`` touches only
the rows in its submodel S(i) — the paper's core observation. ``RowSparse``
stores exactly those rows as an ``(ids, rows)`` pair:

    ids  : (R,) int32, sorted ascending, ``-1`` marks padding slots
    rows : (R, ...)   the touched rows' values (padding rows are zero)

``num_rows`` (the dense leading-dim size V) rides along as static pytree aux
data, so RowSparse leaves flow through ``jit`` / ``vmap`` / ``grad`` like any
array pair while ``to_dense``/``wire_bytes`` still know the dense geometry.
Stacking under ``vmap`` simply adds leading axes to both children (a cohort of
K client updates is ``ids (K, R)``, ``rows (K, R, ...)``).

The on-wire cost of a RowSparse leaf is ``R * 4`` id bytes plus the row
payload — the quantity the comm accounting in ``repro.sparse.comm`` tracks.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

#: id value marking an unused (padding) slot
PAD_ID = -1


class RowSparse:
    """(ids, rows) pair for one feature-keyed leaf; transparent pytree node."""

    __slots__ = ("ids", "rows", "num_rows")

    def __init__(self, ids, rows, num_rows: int):
        self.ids = ids
        self.rows = rows
        self.num_rows = int(num_rows)

    # -- pytree ------------------------------------------------------------
    def __repr__(self):
        ids_s = getattr(self.ids, "shape", None)
        rows_s = getattr(self.rows, "shape", None)
        return f"RowSparse(ids={ids_s}, rows={rows_s}, num_rows={self.num_rows})"

    @property
    def capacity(self) -> int:
        """Number of id slots R (static)."""
        return int(self.ids.shape[-1])

    @property
    def dense_shape(self) -> Tuple[int, ...]:
        batch = tuple(self.ids.shape[:-1])
        return batch + (self.num_rows,) + tuple(self.rows.shape[len(batch) + 1:])

    # -- conversions -------------------------------------------------------
    @staticmethod
    def from_dense(dense: Array, ids: Array) -> "RowSparse":
        """Gather rows of ``dense`` at ``ids`` (axis 0); ``-1`` slots get zeros."""
        valid = ids >= 0
        rows = jnp.take(dense, jnp.maximum(ids, 0), axis=0)
        rows = rows * valid.reshape(valid.shape + (1,) * (rows.ndim - ids.ndim)).astype(rows.dtype)
        return RowSparse(ids.astype(jnp.int32), rows, dense.shape[0])

    def to_dense(self) -> Array:
        """Scatter-add rows into the dense ``(V, ...)`` leaf (unbatched only)."""
        assert self.ids.ndim == 1, "to_dense expects an unbatched RowSparse"
        out = jnp.zeros((self.num_rows,) + tuple(self.rows.shape[1:]), self.rows.dtype)
        safe = jnp.where(self.ids >= 0, self.ids, self.num_rows)  # pads -> dropped
        return out.at[safe].add(self.rows, mode="drop")

    # -- arithmetic helpers used by the server plane -----------------------
    def scale(self, s) -> "RowSparse":
        return RowSparse(self.ids, self.rows * s, self.num_rows)

    def astype(self, dtype) -> "RowSparse":
        return RowSparse(self.ids, self.rows.astype(dtype), self.num_rows)

    def valid_count(self) -> Array:
        """Number of non-padding ids (traced scalar; sums over batch dims)."""
        return (self.ids >= 0).sum()

    def density(self) -> Array:
        """Fraction of dense rows carried per stacked update."""
        n_updates = 1
        for d in self.ids.shape[:-1]:
            n_updates *= int(d)
        return self.valid_count() / (n_updates * self.num_rows)


def _rs_flatten(rs: RowSparse):
    return (rs.ids, rs.rows), rs.num_rows


def _rs_unflatten(num_rows, children):
    ids, rows = children
    return RowSparse(ids, rows, num_rows)


jax.tree_util.register_pytree_node(RowSparse, _rs_flatten, _rs_unflatten)


def is_rowsparse(x: Any) -> bool:
    return isinstance(x, RowSparse)


def unique_ids_padded(ids: Array, capacity: int) -> Array:
    """Sorted unique non-negative ids, padded with ``-1`` to ``capacity``.

    Pure jnp (static output shape, jit-safe). Ids beyond ``capacity`` distinct
    values are dropped — callers size capacity from host-side knowledge (e.g.
    a cohort batch can touch at most ``K * tokens_per_client`` rows).
    """
    flat = ids.reshape(-1).astype(jnp.int32)
    sentinel = jnp.iinfo(jnp.int32).max
    s = jnp.sort(jnp.where(flat >= 0, flat, sentinel))
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    first = first & (s != sentinel)
    slot = jnp.where(first, jnp.cumsum(first) - 1, capacity)  # capacity = drop
    out = jnp.full((capacity,), PAD_ID, jnp.int32)
    return out.at[slot].set(jnp.where(first, s, PAD_ID), mode="drop")


def count_unique_ids(ids: Array) -> Array:
    """Number of distinct non-negative ids in ``ids`` (traced scalar).

    The counting half of :func:`unique_ids_padded` — same sort +
    first-occurrence convention, single-sourced so the sub-id counters
    (``count_sub_ids``, the sharded union statistics) can never drift from
    the union builder. O(T log T) in the input size, never in the feature
    space.
    """
    flat = ids.reshape(-1).astype(jnp.int32)
    sentinel = jnp.iinfo(jnp.int32).max
    s = jnp.sort(jnp.where(flat >= 0, flat, sentinel))
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    return (first & (s != sentinel)).sum(dtype=jnp.int32)


def membership(tokens: Array, ids: Array) -> Array:
    """Boolean mask: is each token present in ``ids``?

    ``ids`` follows the ``unique_ids_padded`` convention (sorted ascending,
    ``-1`` pads). Negative tokens are never members. The exact-membership
    sibling of :func:`remap_ids` (which assumes coverage): binary search plus
    an equality check, so absent tokens report ``False`` instead of an
    arbitrary slot — this is what lets the telemetry plane price capacity
    drops exactly.
    """
    sentinel = jnp.iinfo(jnp.int32).max
    key = jnp.where(ids >= 0, ids, sentinel)
    t = tokens.astype(jnp.int32)
    pos = jnp.searchsorted(key, t)
    hit = jnp.take(key, jnp.minimum(pos, key.shape[-1] - 1)) == t
    return hit & (t >= 0)


def remap_ids(tokens: Array, ids: Array) -> Array:
    """Map feature ids to their slot in ``ids`` (sorted uniques then -1 pads).

    Negative tokens stay negative (the models' own padding convention).
    Tokens absent from ``ids`` produce an arbitrary slot — callers guarantee
    coverage (ids are derived from the same batch).
    """
    sentinel = jnp.iinfo(jnp.int32).max
    key = jnp.where(ids >= 0, ids, sentinel)
    pos = jnp.searchsorted(key, tokens.astype(jnp.int32))
    return jnp.where(tokens >= 0, pos, tokens).astype(jnp.int32)
