"""Observability plane: in-jit round telemetry, trace sink, phase timing.

Deliberately a sibling package of ``repro.federated`` (whose public API
surface is pinned): the execution plane imports nothing from here except
``repro.telemetry.round``'s pure-jnp helpers, and everything host-side
(sink, timer, JSONL readers) lives behind this namespace.
"""
from repro.telemetry.round import (HEAT_BUCKETS, RoundTelemetry, drop_stats,
                                   heat_histogram, split_rounds,
                                   telemetry_to_host, tree_agg_rows,
                                   tree_sq_per_client, tree_sq_sum,
                                   union_ids_vec, valid_feature_ids)
from repro.telemetry.sink import TraceSink, read_events
from repro.telemetry.timer import PhaseTimer

__all__ = [
    "HEAT_BUCKETS",
    "PhaseTimer",
    "RoundTelemetry",
    "TraceSink",
    "drop_stats",
    "heat_histogram",
    "read_events",
    "split_rounds",
    "telemetry_to_host",
    "tree_agg_rows",
    "tree_sq_per_client",
    "tree_sq_sum",
    "union_ids_vec",
    "valid_feature_ids",
]
