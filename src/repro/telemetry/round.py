"""In-jit round telemetry: the observability plane of a federated round.

FedSubAvg's claim is about *which rows move and how they are weighted*
(Ding et al., NeurIPS 2022); losses and comm bytes alone cannot show it.
:class:`RoundTelemetry` is a pytree of counters computed INSIDE the jitted
round step — it rides the step's ``metrics`` dict, stacks along the scan
axis under the ``run_rounds`` engine, and crosses ``shard_map`` boundaries
via psums/all-gathers — so the numbers describe exactly the program that
ran, not a host-side re-derivation:

``dropped_ids`` / ``dropped_mass`` / ``dropped_per_client``
    The ``unique_ids_padded`` capacity contract drops the largest ids when a
    client's distinct-feature count exceeds its sub-id capacity — silently,
    before this plane existed. ``dropped_ids`` counts the distinct ids lost,
    ``dropped_mass`` the batch occurrences referencing them (how much data
    pointed at rows the submodel never carried).
``union_size`` / ``shard_union_sizes`` / ``agg_rows``
    Distinct ids across the cohort's submodels; the per-shard partial-union
    sizes on a :class:`~repro.federated.plan.CohortSharding` mesh; and the
    valid rows of the aggregated RowSparse update (post top-k).
``delta_norm_pre`` / ``delta_norm_post``
    L2 of the transported update stack before and after wire compression
    (top-k + int8) — the live distortion measurement.
``heat_hist``
    Per-bucket histogram (log2 heat buckets) of the round's touched union
    ids — the paper's hot/cold dichotomy as a per-round metric.
``density``
    Effective table density this round: ``union_size / V``.
``staleness_hist`` / ``buffer_occupancy``
    Buffered-async engine only (:mod:`repro.federated.async_engine`): the
    per-fire histogram of the aggregated arrivals' staleness (server
    versions elapsed between a delta's dispatch and its arrival) and the
    number of in-flight dispatched-but-unarrived deltas at the fire event.
    ``None`` on every synchronous path (a barrier round has neither).

Fields that do not apply to a given execution layout are ``None`` (an empty
pytree subtree, so scan/vmap/shard_map handle them transparently); scalar
drop counters are zero on layouts with no capacity contract (dense
transport), so the JSONL schema stays stable.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.rowsparse import (count_unique_ids, is_rowsparse,
                                    membership, unique_ids_padded)

Array = jax.Array

#: log2 heat buckets: bucket b holds union ids with heat in [2^b, 2^{b+1})
#: (bucket 0 also holds h <= 1); 16 buckets cover cohorts of 65k clients.
HEAT_BUCKETS = 16

#: linear staleness buckets: bucket s counts buffered arrivals that were
#: dispatched s server versions ago (the last bucket absorbs the tail).
STALENESS_BUCKETS = 16


class RoundTelemetry(NamedTuple):
    """One round's in-jit counters (see module docstring for semantics)."""

    dropped_ids: Any            # i32 scalar: distinct ids dropped by capacity
    dropped_mass: Any           # f32 scalar: batch occurrences of dropped ids
    dropped_per_client: Any     # (K,) i32 | None (per-client layouts only)
    union_size: Any             # i32 scalar: distinct ids across submodels
    agg_rows: Any               # i32 scalar | None: aggregated RowSparse rows
    shard_union_sizes: Any      # (ndev,) i32 | None (sharded rounds only)
    delta_norm_pre: Any         # f32 scalar: L2 of the raw update stack
    delta_norm_post: Any        # f32 scalar: L2 after top-k / int8
    heat_hist: Any              # (HEAT_BUCKETS,) f32 over touched union ids
    density: Any                # f32 scalar: union_size / V
    # buffered-async fields (None on every synchronous path; defaulted so
    # existing constructors stay source-compatible)
    staleness_hist: Any = None  # (STALENESS_BUCKETS,) f32 | None: per fire
    buffer_occupancy: Any = None  # i32 scalar | None: in-flight deltas at fire


def valid_feature_ids(ids: Array, vocab: int) -> Array:
    """Ids outside ``[0, vocab)`` become -1 (the padding convention)."""
    ids = ids.astype(jnp.int32)
    return jnp.where((ids >= 0) & (ids < vocab), ids, -1)


def _drop_stats_one(feats: Array, sub_ids: Array, vocab: int):
    """(dropped distinct ids, dropped occurrence mass) for one id vector."""
    f = valid_feature_ids(feats.reshape(-1), vocab)
    distinct = count_unique_ids(f)
    kept = (sub_ids >= 0).sum(dtype=jnp.int32)
    dropped = jnp.maximum(distinct - kept, 0)
    covered = membership(f, sub_ids)
    mass = ((f >= 0) & ~covered).sum(dtype=jnp.float32)
    return dropped, mass


def drop_stats(feats: Array, sub_ids: Array, vocab: int):
    """Capacity-overflow accounting against the sub-id contract.

    ``feats``: raw feature ids — ``(K, M)`` per-client or flat ``(M,)``;
    ``sub_ids``: the -1-padded sub-id vectors actually consumed — ``(K, R)``
    or ``(R,)`` matching. Returns ``(dropped, mass)`` per client (or flat):
    distinct ids the capacity dropped, and the number of valid feature
    occurrences referencing a dropped id. Exact when ``sub_ids`` came from
    ``unique_ids_padded`` over the same ``feats`` (every execution path's
    contract); zero when the capacity fit.
    """
    if sub_ids.ndim == 2:
        return jax.vmap(lambda f, s: _drop_stats_one(f, s, vocab))(
            feats, sub_ids)
    return _drop_stats_one(feats, sub_ids, vocab)


def union_ids_vec(ids: Array, vocab: int) -> Array:
    """Sorted distinct valid ids of ``ids`` (static capacity, -1 padded)."""
    flat = ids.reshape(-1)
    cap = min(int(vocab), int(flat.shape[0])) if vocab else 0
    return unique_ids_padded(valid_feature_ids(flat, vocab), max(cap, 1))


def heat_histogram(heat: Array, ids: Array,
                   nbuckets: int = HEAT_BUCKETS) -> Array:
    """Histogram of ``heat`` values gathered at the valid ids of ``ids``.

    Bucket ``b`` counts ids whose heat lies in ``[2^b, 2^{b+1})`` (``b = 0``
    also holds ``h <= 1``); padding ids fall in no bucket. The live form of
    the paper's hot/cold feature split: a cohort touching mostly-cold rows
    piles into the low buckets.
    """
    h = jnp.take(jnp.asarray(heat, jnp.float32), jnp.maximum(ids, 0),
                 mode="clip")
    b = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(h, 1.0))), 0,
                 nbuckets - 1).astype(jnp.int32)
    b = jnp.where(ids >= 0, b, nbuckets)          # pads -> dropped
    return jnp.zeros((nbuckets,), jnp.float32).at[b].add(1.0, mode="drop")


def staleness_histogram(staleness: Array,
                        nbuckets: int = STALENESS_BUCKETS) -> Array:
    """Histogram of the buffered arrivals' staleness values.

    ``staleness``: (M,) i32 server-versions-elapsed per buffered delta.
    Bucket ``s`` counts deltas with staleness exactly ``s``; the last bucket
    absorbs everything ``>= nbuckets - 1``. Negative entries (unused buffer
    slots, if a caller ever passes a partial buffer) fall in no bucket.
    """
    s = jnp.asarray(staleness, jnp.int32)
    b = jnp.where(s >= 0, jnp.minimum(s, nbuckets - 1), nbuckets)
    return jnp.zeros((nbuckets,), jnp.float32).at[b].add(1.0, mode="drop")


def tree_sq_sum(tree) -> Array:
    """Sum of squares over every leaf (RowSparse-aware), in float32.

    RowSparse padding rows are zero by construction on every encoder path,
    so no masking is needed.
    """
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(tree, is_leaf=is_rowsparse):
        rows = leaf.rows if is_rowsparse(leaf) else leaf
        total += jnp.sum(jnp.square(rows.astype(jnp.float32)))
    return total


def tree_sq_per_client(tree, k: int) -> Array:
    """Per-client sum of squares ``(K,)`` of a stacked update tree."""
    total = jnp.zeros((k,), jnp.float32)
    for leaf in jax.tree.leaves(tree, is_leaf=is_rowsparse):
        rows = leaf.rows if is_rowsparse(leaf) else leaf
        total += jnp.square(rows.astype(jnp.float32)).reshape(k, -1).sum(-1)
    return total


def tree_agg_rows(tree) -> Optional[Array]:
    """Valid rows summed over the RowSparse leaves of an aggregated update.

    ``None`` when no leaf is RowSparse (dense transport, or a psum-densified
    sharded combine) — there is no aggregation union to size.
    """
    counts = [leaf.valid_count()
              for leaf in jax.tree.leaves(tree, is_leaf=is_rowsparse)
              if is_rowsparse(leaf)]
    if not counts:
        return None
    total = counts[0]
    for c in counts[1:]:
        total = total + c
    return total.astype(jnp.int32)


def telemetry_to_host(tel: RoundTelemetry) -> dict:
    """One round's telemetry as plain Python (JSONL-ready; None fields kept).

    Works on a stacked telemetry too (each field gains a leading round axis
    under the scan engine) — use :func:`split_rounds` to slice it per round.
    """
    out = {}
    for name, v in tel._asdict().items():
        if v is None:
            out[name] = None
            continue
        a = np.asarray(jax.device_get(v))
        out[name] = a.item() if a.ndim == 0 else a.tolist()
    return out


def split_rounds(tel: RoundTelemetry, n: int) -> list:
    """Split a scan-stacked telemetry (leading axis ``n``) into host dicts."""
    host = {name: (None if v is None else np.asarray(jax.device_get(v)))
            for name, v in tel._asdict().items()}
    events = []
    for r in range(n):
        d = {}
        for name, a in host.items():
            if a is None:
                d[name] = None
            else:
                ar = a[r]
                d[name] = ar.item() if np.ndim(ar) == 0 else ar.tolist()
        events.append(d)
    return events
