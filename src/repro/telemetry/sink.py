"""Host-side trace sink: structured JSONL round events + a verbose reporter.

The in-jit :class:`~repro.telemetry.round.RoundTelemetry` counters are only
useful if they land somewhere analyzable. :class:`TraceSink` merges each
round's ``RoundRecord``, ``CommStats`` and telemetry into one flat JSON
object per line — the standard grep/pandas-friendly trace format — and also
owns the trainer's verbose reporting, routed through :mod:`logging` so test
harnesses (``caplog``) and real deployments can capture it.
"""
from __future__ import annotations

import json
import logging
from typing import IO, Any, Dict, List, Optional

logger = logging.getLogger("repro.telemetry")


def _json_default(obj: Any):
    """Coerce numpy / JAX leaves that ``json`` cannot serialise.

    Device scalars and 0-d arrays become Python scalars via ``.item()``;
    anything array-like with ``.tolist()`` (numpy arrays, device arrays)
    becomes a nested list. Everything else keeps json's TypeError so junk
    still fails loudly.
    """
    item = getattr(obj, "item", None)
    if item is not None and getattr(obj, "ndim", None) == 0:
        return item()
    tolist = getattr(obj, "tolist", None)
    if tolist is not None:
        return tolist()
    raise TypeError(f"Object of type {type(obj).__name__} is not JSON "
                    "serializable")


class TraceSink:
    """Collects structured round events; optionally persists them as JSONL.

    ``emit(event)`` appends a dict to the in-memory log and, when a path was
    given, writes it as one JSON line (flushed immediately, so a crashed run
    still leaves a readable trace). ``report(msg)`` is the human channel:
    it logs at INFO and falls back to ``print`` when no handler would show
    the message, preserving the old ``verbose=True`` console behaviour.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = str(path) if path is not None else None
        self.events: List[Dict[str, Any]] = []
        self._fh: Optional[IO[str]] = None
        if self.path is not None:
            self._fh = open(self.path, "w")

    # -- structured channel ------------------------------------------------
    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)
        if self._fh is not None:
            self._fh.write(json.dumps(event, default=_json_default) + "\n")
            self._fh.flush()

    # -- human channel -----------------------------------------------------
    def report(self, msg: str) -> None:
        logger.info(msg)
        # logging's root default (WARNING) swallows INFO: keep the verbose
        # console UX unless someone actually routed the logger somewhere.
        if not logger.isEnabledFor(logging.INFO):
            print(msg)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "TraceSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL trace written by :class:`TraceSink`."""
    events = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events
