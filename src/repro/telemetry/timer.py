"""Per-phase wall-clock accounting that separates compile from steady state.

jit'd programs have a bimodal cost profile: the first dispatch of a new
(shape, capacity) signature pays tracing + XLA compilation, every later one
pays only execution. Averaging across them (what ``RoundRecord.wall_time``
did before this plane existed) reports neither number. :class:`PhaseTimer`
keeps one duration list per ``(phase, compile?)`` bucket so callers can
report honest steady-state means alongside explicit compile cost.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, List, Tuple


class PhaseTimer:
    """Accumulates wall-time samples per phase, compile-tagged.

    Use ``with timer.phase("round"):`` around host-side work, or ``add``
    when the duration was measured elsewhere. ``compile=True`` samples are
    kept apart so ``mean()`` is a steady-state figure.
    """

    def __init__(self):
        self._samples: Dict[Tuple[str, bool], List[float]] = {}

    def add(self, name: str, seconds: float, compile: bool = False) -> None:
        self._samples.setdefault((name, bool(compile)), []).append(
            float(seconds))

    @contextmanager
    def phase(self, name: str, compile: bool = False):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0, compile)

    # -- queries -----------------------------------------------------------
    def total(self, name: str, compile: bool = False) -> float:
        return sum(self._samples.get((name, bool(compile)), []))

    def count(self, name: str, compile: bool = False) -> int:
        return len(self._samples.get((name, bool(compile)), []))

    def mean(self, name: str) -> float:
        """Steady-state mean seconds for ``name`` (0.0 if never sampled)."""
        xs = self._samples.get((name, False), [])
        return sum(xs) / len(xs) if xs else 0.0

    def summary(self) -> Dict[str, Dict[str, float]]:
        """``{phase: {mean_s, total_s, count, compile_s, compile_count}}``."""
        phases = sorted({name for name, _ in self._samples})
        return {
            name: {
                "mean_s": self.mean(name),
                "total_s": self.total(name),
                "count": self.count(name),
                "compile_s": self.total(name, compile=True),
                "compile_count": self.count(name, compile=True),
            }
            for name in phases
        }
