import numpy as np
import pytest

# NOTE: deliberately NO XLA_FLAGS / device-count manipulation here — tests run
# on the single real CPU device; only launch/dryrun.py requests 512 fake
# devices (in its own process).


@pytest.fixture
def rng():
    return np.random.default_rng(0)
