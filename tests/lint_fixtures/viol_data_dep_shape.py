"""Seeded violation: data-dependent output shape under jit.

``jnp.unique`` (and nonzero/argwhere/one-argument where) without ``size=``
produces a shape that depends on runtime values — untraceable. The repo's
union builders are all sort-based or ``size=``-bounded for exactly this
reason. The linter must flag the ``jnp.unique`` below.
"""
import jax
import jax.numpy as jnp


@jax.jit
def union_ids(tokens):
    return jnp.unique(tokens)       # VIOLATION: no size=


def safe_bounded_union(tokens, cap: int):
    # the jit-safe form: static output shape, -1 fill — must not fire
    mark = jnp.zeros((1024,), bool).at[jnp.maximum(tokens, 0)].set(True)
    return jnp.nonzero(mark, size=cap, fill_value=-1)[0]
