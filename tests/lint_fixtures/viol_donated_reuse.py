"""Seeded violation: re-reading a buffer after donating it.

The trainer donates ``ServerState`` through the round step so the feature
table updates in place; reading the donated holder after the call touches a
deleted buffer. The safe idiom rebinds the holder in the donating statement
(``state, m = step(state, ...)``). The linter must flag the re-reference
below.
"""
import jax
import jax.numpy as jnp


def _round_step(state, batch):
    return state + batch.sum()


donated_step = jax.jit(_round_step, donate_argnums=(0,))


def run_bad(state, batch):
    new_state = donated_step(state, batch)
    return new_state + state        # VIOLATION: state was donated above


def run_safe(state, batch):
    # rebinding in the donating statement: later reads see the new buffer
    state = donated_step(state, batch)
    return state * 2.0
