"""Seeded violation: host np.* call on traced values inside a jitted path.

The PR-2/PR-5 bug class: a host numpy op inside the round hot path forces a
device sync per call and silently falls out of the compiled program. The
linter must flag the ``np.unique`` below.
"""
import jax.numpy as jnp
import numpy as np


def derive_union(tokens):
    ids = np.unique(tokens)         # VIOLATION: tokens is traced here
    return jnp.asarray(ids)


def safe_static_geometry(batch):
    # shape-derived numpy is static at trace time and must not fire
    n = int(np.prod(batch.shape))
    return jnp.full((n,), 0.0)
