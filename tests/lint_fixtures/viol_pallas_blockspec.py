"""Seeded violation: pl.BlockSpec literal block shape off the (8, 128) tile.

TPU vector memory is tiled (8, 128) for f32: a literal block shape whose
lane dim is not a multiple of 128 (or sublane not a multiple of 8) makes
Mosaic pad or re-lay-out every window, silently wasting VMEM and HBM
bandwidth. Size-1 dims and computed block picks (which the kernel-audit
plane pins against the kernel's own guard) are exempt; only the marked
spec below must fire.
"""
from jax.experimental import pallas as pl


def specs(v_blk: int, d: int):
    aligned = pl.BlockSpec((8, 128), lambda i, j: (i, j))
    squeezed = pl.BlockSpec((1,), lambda i, j: (i,))
    leading_one = pl.BlockSpec((1, 512, 128), lambda i, j: (0, i, j))
    computed = pl.BlockSpec((v_blk, d), lambda i, j: (i, j))
    bad = pl.BlockSpec((16, 100), lambda i, j: (i, j))  # VIOLATION: lane dim 100
    return aligned, squeezed, leading_one, computed, bad
