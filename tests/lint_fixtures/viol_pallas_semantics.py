"""Seeded violation: pl.pallas_call without explicit dimension_semantics.

The union_segsum Megacore bug class: a kernel that carries state across a
grid dimension is corrupted when Mosaic partitions that dimension across
cores under the silent ``"parallel"`` default. Every ``pallas_call`` must
state its grid semantics via ``compiler_params``. The linter must flag the
call below.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double(x):
    return pl.pallas_call(          # VIOLATION: no compiler_params
        _kernel,
        grid=(x.shape[0] // 128,),
        in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
        out_specs=pl.BlockSpec((128,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)
