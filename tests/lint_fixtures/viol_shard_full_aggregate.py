"""Seeded fixture: full heat-fused aggregate inside a shard_map body.

The PR 5 bug class: under cohort sharding each shard holds a PARTIAL
cohort, so calling the fused ``aggregate_rowsparse`` (which applies the
N/n_m heat correction) per shard applies the correction to per-shard
counts, and the cross-shard psum then sums already-corrected partials —
a silent double correction. The partial/combine split
(``aggregate_rowsparse_partial`` + ``combine_rowsparse_partials``) is
the only sound decomposition.

This file is an AST-only lint fixture: it is never imported or executed,
so the imports need not resolve.
"""
import jax
from jax.experimental.shard_map import shard_map

from repro.sparse.aggregate import (aggregate_rowsparse,
                                    aggregate_rowsparse_partial,
                                    combine_rowsparse_partials)


def bad_shard_body(stacked, heat, total):
    agg = aggregate_rowsparse(stacked, heat, total)  # VIOLATION: full aggregate per shard
    return jax.lax.psum(agg.to_dense(), "data")


def good_shard_body(stacked, heat, total):
    partial = aggregate_rowsparse_partial(stacked)
    return combine_rowsparse_partials(partial, heat, total, axis="data")


def run(mesh, stacked, heat, total):
    bad = shard_map(bad_shard_body, mesh=mesh, in_specs=None, out_specs=None,
                    check_rep=False)
    good = shard_map(good_shard_body, mesh=mesh, in_specs=None,
                     out_specs=None, check_rep=False)
    return bad(stacked, heat, total), good(stacked, heat, total)
