"""Seeded fixture: per-shard jnp reduction with no psum/pmean in reach.

Inside a shard_map body, ``jnp.mean(losses)`` collapses THIS shard's
slice only; unless the result feeds a ``jax.lax.psum``/``pmean`` over
the mesh axis (or the per-shard intent is suppressed with a reason),
every device reports a different "mean" and downstream metrics silently
diverge from the replicated run.

This file is an AST-only lint fixture: it is never imported or executed,
so the imports need not resolve.
"""
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map


def bad_loss_body(losses):
    return jnp.mean(losses)  # VIOLATION: per-shard mean, never combined


def good_loss_body(losses):
    shard_sum = jnp.sum(losses)
    total = jax.lax.psum(shard_sum, "data")
    return total / losses.shape[0]


def run(mesh, losses):
    bad = shard_map(bad_loss_body, mesh=mesh, in_specs=None, out_specs=None,
                    check_rep=False)
    good = shard_map(good_loss_body, mesh=mesh, in_specs=None,
                     out_specs=None, check_rep=False)
    return bad(losses), good(losses)
