"""Seeded violation: array-valued static_argnames.

The bug class behind the heat-vector recompiles: marking an array argument
static makes it a jit-cache key — unhashable at best, one compile per
distinct value at worst. The linter must flag ``heat`` below.
"""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("heat", "vocab"))
def corrected_update(update, heat: jax.Array, vocab: int):
    # VIOLATION above: ``heat`` is annotated as an array
    return update * jnp.minimum(heat[:vocab], 1.0)


@functools.partial(jax.jit, static_argnames=("capacity",))
def safe_static_int(ids, capacity: int):
    # int-typed static args are the intended use and must not fire
    return jnp.sort(ids)[:capacity]
