"""Seeded violation: float() coercion of a traced value under jit.

This is the PR-2 bug class: coercing a traced scalar bakes its value into
the compiled program (one recompile per distinct value) or crashes with a
ConcretizationTypeError. The linter must flag the ``float(k)`` below.
"""
import jax
import jax.numpy as jnp


@jax.jit
def cohort_mean(deltas, k):
    scale = 1.0 / float(k)          # VIOLATION: k is traced here
    return jnp.sum(deltas, axis=0) * scale


def safe_variants(x, n: int):
    # none of these may fire: shape-derived and annotated-static coercions
    rows = float(x.shape[0])
    frac = 1.0 / float(n)
    return jnp.asarray(x) * rows * frac
