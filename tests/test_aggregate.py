"""The paper's core identity (§3.2): after the N/n_m correction, the expected
global update of parameter m equals the average of the local updates of the
clients that involve m."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.aggregate import HeatSpec, correct_update_tree, masked_cohort_mean
from repro.core.heat import compute_heat_exact
from repro.sharding.logical import Param, unbox


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 10_000))
def test_expected_update_equals_submodel_average(seed):
    """Enumerate all cohorts of size K: E_C[ (N/(n_m K)) sum_{i in C} d_i,m ]
    == (1/n_m) sum_{i: m in S(i)} d_i,m   (Alg. 1's expectation identity)."""
    rng = np.random.default_rng(seed)
    n, m = 5, 7
    involved = (rng.random((n, m)) < 0.6)
    involved[:, 0] = True                      # a hot feature
    involved[0, :] = True                      # ensure non-empty submodels
    deltas = rng.normal(size=(n, m)) * involved
    counts = involved.sum(axis=0)

    from itertools import combinations
    k = 3
    cohorts = list(combinations(range(n), k))
    # FedSubAvg expected update
    agg = np.zeros(m)
    for c in cohorts:
        cohort_sum = deltas[list(c)].sum(axis=0)
        agg += (n / (np.maximum(counts, 1) * k)) * cohort_sum
    agg /= len(cohorts)
    # average of involving clients' updates
    want = deltas.sum(axis=0) / np.maximum(counts, 1)
    np.testing.assert_allclose(agg, want, rtol=1e-9, atol=1e-12)


def test_correct_update_tree_plain_and_boxed():
    spec = HeatSpec({"emb": ("vocab", 0), "head": ("vocab", 1), "w": None})
    upd_plain = {
        "emb": jnp.ones((4, 2)),
        "head": jnp.ones((2, 4)),
        "w": jnp.ones((3,)),
    }
    counts = {"vocab": jnp.array([8.0, 4.0, 2.0, 0.0])}
    out = correct_update_tree(upd_plain, spec, counts, 8.0)
    np.testing.assert_allclose(out["emb"][:, 0], [1, 2, 4, 0])
    np.testing.assert_allclose(out["head"][0], [1, 2, 4, 0])
    np.testing.assert_allclose(out["w"], 1.0)

    boxed = {
        "emb": Param(jnp.ones((4, 2)), ("vocab", "embed")),
        "head": Param(jnp.ones((2, 4)), ("embed", "vocab")),
        "w": Param(jnp.ones((3,)), (None,)),
    }
    outb = correct_update_tree(boxed, spec, counts, 8.0)
    np.testing.assert_allclose(unbox(outb)["emb"][:, 0], [1, 2, 4, 0])
    assert outb["emb"].axes == ("vocab", "embed")


def test_unknown_space_passes_through():
    spec = HeatSpec({"e": ("expert", 0)})
    out = correct_update_tree({"e": jnp.ones((2, 2))}, spec, {}, 4.0)
    np.testing.assert_allclose(out["e"], 1.0)


def test_masked_cohort_mean():
    deltas = {"t": jnp.asarray([[1.0, 2.0], [3.0, 6.0]])[..., None]}
    inv = jnp.asarray([[1.0, 1.0], [1.0, 0.0]])
    out = masked_cohort_mean(deltas, inv)
    np.testing.assert_allclose(out["t"][:, 0], [2.0, 2.0])
