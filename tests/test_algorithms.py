"""Server algorithms + submodel machinery."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FedConfig
from repro.core.aggregate import HeatSpec
from repro.core.algorithms import make_server_algorithm
from repro.core.submodel import (count_token_rows, gather_rows,
                                 index_set_from_tokens, involvement_matrix,
                                 scatter_row_updates)


def _params():
    return {"w": jnp.zeros((3,)), "emb": jnp.zeros((4, 2))}


def test_fedavg_applies_mean_delta():
    alg = make_server_algorithm(FedConfig(algorithm="fedavg", server_lr=2.0))
    st = alg.init(_params())
    delta = {"w": jnp.ones((3,)), "emb": jnp.ones((4, 2))}
    st = alg.apply(st, delta)
    np.testing.assert_allclose(st.params["w"], 2.0)
    assert int(st.rounds) == 1


def test_fedsubavg_scales_feature_rows():
    spec = HeatSpec({"w": None, "emb": ("vocab", 0)})
    counts = {"vocab": jnp.array([4.0, 2.0, 1.0, 0.0])}
    cfg = FedConfig(algorithm="fedsubavg", num_clients=4)
    alg = make_server_algorithm(cfg, heat_spec=spec, heat_counts=counts, total=4.0)
    st = alg.init(_params())
    delta = {"w": jnp.ones((3,)), "emb": jnp.ones((4, 2))}
    st = alg.apply(st, delta)
    np.testing.assert_allclose(st.params["emb"][:, 0], [1.0, 2.0, 4.0, 0.0])
    np.testing.assert_allclose(st.params["w"], 1.0)


def test_scaffold_momentum_matches_eq47():
    cfg = FedConfig(algorithm="scaffold", num_clients=10, clients_per_round=2)
    alg = make_server_algorithm(cfg)
    st = alg.init(_params())
    d1 = {"w": jnp.ones((3,)), "emb": jnp.zeros((4, 2))}
    st = alg.apply(st, d1)
    # Delta = (1 - K/N)*0 + (K/N)*d1 = 0.2
    np.testing.assert_allclose(st.params["w"], 0.2)
    st = alg.apply(st, d1)
    # Delta = 0.8*0.2 + 0.2*1 = 0.36 ; cumulative 0.56
    np.testing.assert_allclose(st.params["w"], 0.56, rtol=1e-6)


def test_fedadam_first_step_is_lr_scaled_sign():
    cfg = FedConfig(algorithm="fedadam", server_lr=0.1, server_eps=1e-8)
    alg = make_server_algorithm(cfg)
    st = alg.init(_params())
    delta = {"w": jnp.array([1.0, -2.0, 0.5]), "emb": jnp.zeros((4, 2))}
    st = alg.apply(st, delta)
    # bias-corrected first Adam step ~ lr * sign(delta)
    np.testing.assert_allclose(st.params["w"], [0.1, -0.1, 0.1], rtol=1e-4)


def test_index_set_roundtrip():
    toks = jnp.array([[7, 3, 3, 9], [9, 7, 7, 7]])
    s = index_set_from_tokens(toks, max_ids=5)
    assert sorted(np.asarray(s.ids[s.ids >= 0]).tolist()) == [3, 7, 9]
    table = jnp.arange(24.0).reshape(12, 2)
    rows = gather_rows(table, s)
    back = scatter_row_updates(12, s, rows)
    for i in [3, 7, 9]:
        np.testing.assert_allclose(back[i], table[i])
    assert float(jnp.abs(back).sum()) == pytest.approx(
        float(jnp.abs(table[jnp.array([3, 7, 9])]).sum()))


def test_involvement_and_counts():
    ids = jnp.array([[1, 2, -1], [2, 2, 4]])
    inv = involvement_matrix(ids, 6)
    np.testing.assert_allclose(np.asarray(inv).sum(axis=0), [0, 1, 2, 0, 1, 0])
    c = count_token_rows(jnp.array([1, 2, 2, 4, -1]), 6)
    np.testing.assert_allclose(c, [0, 1, 2, 0, 1, 0])
