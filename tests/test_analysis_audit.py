"""Compiled-artifact audits: no dense (V, D) intermediates on sparse
plans, donation actually aliases, and the jit cache never grows under
traced-hyperparameter sweeps."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jaxpr_audit import (CompileCountError,
                                        DenseMaterializationError,
                                        assert_no_dense_intermediates,
                                        donation_aliased,
                                        find_dense_intermediates,
                                        jit_cache_guard)
from repro.configs.base import FedConfig
from repro.core.algorithms import ServerState
from repro.data import make_movielens_like
from repro.federated.plan import build_round_step, resolve_plan
from repro.federated.server import FederatedTrainer
from repro.federated.simulation import make_round_step
from repro.models.recsys import (lr_logits, lr_loss, lstm_loss,
                                 make_lr_params, make_lstm_params)
from repro.sparse.rowsparse import RowSparse

V, E = 65536, 4   # full-vocab scale: the audit traces, it never executes


@pytest.fixture(scope="module")
def params():
    return make_lstm_params(V, emb_dim=E, hidden=8, layers=1,
                            rng=jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def cfg():
    return FedConfig(num_clients=50, clients_per_round=4, lr=0.1,
                     server_lr=1.0, seed=0)


def _flat_batch():
    r = np.random.RandomState(0)
    return {"tokens": jnp.asarray(r.randint(0, V, (4, 8))),
            "label": jnp.asarray(r.randint(0, V, (4,))),
            "heat_vocab": jnp.ones((V,), jnp.float32)}


def _cohort_batch():
    r = np.random.RandomState(0)
    return {"tokens": jnp.asarray(r.randint(0, V, (3, 2, 2, 6))),
            "label": jnp.asarray(r.randint(0, V, (3, 2, 2))),
            "heat_vocab": jnp.ones((V,), jnp.float32)}


# ---------------------------------------------------------------------------
# dense-materialization detector
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,batch_fn", [("sparse", _flat_batch),
                                           ("sparse_replicated",
                                            _cohort_batch)])
def test_sparse_plans_have_no_dense_intermediates(params, cfg, mode,
                                                  batch_fn):
    """The paper's core claim, checked on the built artifact: a RowSparse
    round step never materialises a float (V, ...) array between the
    client gather and the server scatter-add."""
    step = make_round_step(lstm_loss, params, cfg, mode=mode)
    assert_no_dense_intermediates(step, params, batch_fn(), dim0=V)


def test_planted_densification_is_detected(params):
    """A pipeline that round-trips the delta through to_dense() must trip
    the detector (broadcast_in_dim of the (V, E) zeros)."""

    def bad_step(params, batch):
        toks = batch["tokens"].reshape(-1).astype(jnp.int32)
        ids = jnp.sort(toks)
        rows = jnp.ones((ids.shape[0], E), jnp.float32)
        dense = RowSparse(ids, rows, V).to_dense()       # the planted bug
        return params, dense.sum()

    with pytest.raises(DenseMaterializationError) as ei:
        assert_no_dense_intermediates(bad_step, params, _flat_batch(),
                                      dim0=V)
    assert any(h.shape == (V, E) for h in ei.value.hits)


def test_detector_ignores_int_id_workspaces():
    """O(V) int32/bool mark-scatter workspaces are the union machinery's
    accepted cost; only float row payloads count as densification."""

    def workspace(tokens):
        mark = jnp.zeros((V, 1), jnp.int32).at[tokens].add(1)
        return mark.sum()

    assert find_dense_intermediates(
        workspace, jnp.arange(8), dim0=V) == []


# ---------------------------------------------------------------------------
# donation aliasing
# ---------------------------------------------------------------------------


def test_round_step_donation_aliases(params, cfg):
    """The trainer donates ServerState through the sparse step; the lowered
    HLO must witness the aliasing (XLA drops impossible donations
    silently)."""
    plan = resolve_plan("sparse", cfg)
    step = build_round_step(plan, lstm_loss, params, cfg)
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    assert donation_aliased(step, state, _flat_batch(), donate_argnums=(0,))


def test_donation_aliased_negative():
    def f(x, y):
        return (x[:1] * y[:1]).sum()   # no output matches x's shape

    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")    # jax warns on the dropped buffer
        assert not donation_aliased(f, jnp.ones((8,)), jnp.ones((8,)),
                                    donate_argnums=(0,))


def test_donation_report_maps_buffers():
    """The report names which buffer aliased to which output."""
    rep = donation_aliased(lambda x: x * 2.0, jnp.ones((8,)),
                           donate_argnums=(0,))
    assert rep.aliasing == {0: 0}
    assert rep.num_donated == 1 and rep.dropped == 0


def test_donation_report_partially_dropped():
    """Regression for the substring-check blind spot: donate a 2-leaf tree
    where only one leaf is reusable.  The old `'tf.aliasing_output' in text`
    bool said True; the report must say one aliased, one dropped, and be
    falsy so asserts catch the partial drop."""
    def f(p):
        a, b = p
        return a * 2.0, jnp.sum(b)     # b's (4,) buffer has no (4,) output

    rep = donation_aliased(f, (jnp.ones((8,)), jnp.ones((4,))),
                           donate_argnums=(0,))
    assert rep.num_donated == 2
    assert rep.aliasing == {0: 0}      # only the (8,) leaf aliased
    assert rep.dropped == 1
    assert not rep


# ---------------------------------------------------------------------------
# jit_cache_guard
# ---------------------------------------------------------------------------


def test_cache_guard_passes_on_traced_sweep():
    j = jax.jit(lambda x, s: x * s)
    with jit_cache_guard(j):
        for s in (0.5, 1.5, 2.5):
            j(jnp.ones((4,)), s).block_until_ready()


def test_cache_guard_trips_on_recompiles():
    j = jax.jit(lambda x, n: x[:n], static_argnames=("n",))
    with pytest.raises(CompileCountError, match="compiled 2"):
        with jit_cache_guard(j, max_new_compiles=1):
            j(jnp.ones((8,)), 2).block_until_ready()
            j(jnp.ones((8,)), 3).block_until_ready()


def test_cache_guard_rejects_unjitted():
    with pytest.raises(TypeError, match="_cache_size"):
        with jit_cache_guard(lambda x: x):
            pass


def test_round_step_heat_sweep_compiles_once(params, cfg):
    """Heat is a traced batch input: scaling it (simulating popularity
    drift between rounds) must hit one compiled program."""
    step = jax.jit(make_round_step(lstm_loss, params, cfg, mode="sparse"))
    b = _flat_batch()
    with jit_cache_guard(step):
        for scale in (1.0, 2.0, 5.0, 0.25):
            bb = dict(b, heat_vocab=b["heat_vocab"] * scale)
            jax.block_until_ready(step(params, bb))


def test_trainer_engine_compiles_once_per_plan_shape():
    """The satellite pin: driving run_rounds repeatedly — int8 rounding key
    advancing with ServerState.rounds every round — compiles the engine
    exactly once per (n, capacity) dispatch variant."""
    ds = make_movielens_like(num_clients=40, num_items=40, mean_samples=15)
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=6,
                    local_iters=2, local_batch=4, lr=0.5,
                    algorithm="fedsubavg", sparse=True, sparse_int8=True)
    tr = FederatedTrainer(
        ds, functools.partial(make_lr_params, ds.num_features), lr_loss, cfg,
        predict_fn=lambda p, t: lr_logits(p, jnp.asarray(t["features"])))
    for _ in range(3):
        tr.run_rounds(3)
    engine_keys = {k for k in tr._compiled_keys if k[0] == "engine"}
    assert tr._sparse_engine._cache_size() == len(engine_keys)
    # and re-driving the already-seen variants compiles nothing new
    with jit_cache_guard(tr._sparse_engine, max_new_compiles=0):
        before = set(tr._compiled_keys)
        tr.run_rounds(3)
        assert set(tr._compiled_keys) == before, \
            "new dispatch variant appeared; the guard below would be vacuous"
