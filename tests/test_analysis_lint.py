"""The jit-hygiene linter: every rule fires on its seeded fixture, the
allowlist works, the JSON report is machine-readable — and ``src/`` is
clean (the tier-1 static-analysis gate)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import lint

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "lint_fixtures")

#: fixture file -> the one rule it seeds (each also carries safe variants
#: that must NOT fire)
_SEEDED = {
    "viol_traced_float.py": "traced-float",
    "viol_host_numpy.py": "host-numpy",
    "viol_static_argnames.py": "static-argnames-array",
    "viol_pallas_semantics.py": "pallas-dim-semantics",
    "viol_pallas_blockspec.py": "pallas-blockspec-misaligned",
    "viol_data_dep_shape.py": "data-dep-shape",
    "viol_donated_reuse.py": "donated-reuse",
    "viol_shard_full_aggregate.py": "shard-full-aggregate",
    "viol_shard_missing_psum.py": "shard-missing-psum",
}


# ---------------------------------------------------------------------------
# seeded violations: one fixture per rule, exactly one hit each
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture,rule", sorted(_SEEDED.items()))
def test_seeded_violation_fires(fixture, rule):
    path = os.path.join(FIXTURES, fixture)
    violations, suppressions, n = lint.lint_paths([path])
    assert n == 1
    assert not suppressions
    assert [v.rule for v in violations] == [rule], (
        f"{fixture} must trip exactly its seeded rule; got "
        f"{[(v.rule, v.line) for v in violations]}")
    # the violation anchors at (or within the statement of) the line the
    # fixture marks with a VIOLATION comment
    with open(path, encoding="utf-8") as f:
        marked = [i for i, ln in enumerate(f.read().splitlines(), 1)
                  if "VIOLATION" in ln]
    assert any(abs(violations[0].line - m) <= 2 for m in marked)


def test_cli_nonzero_on_fixtures_zero_on_clean(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", FIXTURES],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert bad.returncode != 0
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(clean)],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr


# ---------------------------------------------------------------------------
# allowlist syntax
# ---------------------------------------------------------------------------

_VIOLATING = """
import jax
import jax.numpy as jnp


@jax.jit
def f(x):
    return jnp.ones(()) * float(x){allow}
"""


def test_allowlist_with_reason_suppresses():
    src = _VIOLATING.format(
        allow="  # repro-lint: ok traced-float -- host-side scale knob")
    violations, suppressions = lint.lint_source(src, "mod.py")
    assert not violations
    assert [s.rule for s in suppressions] == ["traced-float"]
    assert "host-side" in suppressions[0].reason


def test_allowlist_comment_line_above_suppresses():
    src = textwrap.dedent("""
        import jax
        import jax.numpy as jnp


        @jax.jit
        def f(x):
            # repro-lint: ok traced-float -- reason spanning
            # a second comment line
            return jnp.ones(()) * float(x)
    """)
    violations, suppressions = lint.lint_source(src, "mod.py")
    assert not violations
    assert len(suppressions) == 1


def test_bare_allowlist_is_itself_a_violation():
    src = _VIOLATING.format(allow="  # repro-lint: ok traced-float")
    violations, _ = lint.lint_source(src, "mod.py")
    assert [v.rule for v in violations] == ["bare-allowlist"]


def test_unknown_rule_in_allowlist_flagged():
    src = _VIOLATING.format(
        allow="  # repro-lint: ok no-such-rule -- whatever")
    violations, _ = lint.lint_source(src, "mod.py")
    assert "bare-allowlist" in {v.rule for v in violations}
    assert "traced-float" in {v.rule for v in violations}


def test_wildcard_allowlist():
    src = _VIOLATING.format(allow="  # repro-lint: ok * -- prototype code")
    violations, suppressions = lint.lint_source(src, "mod.py")
    assert not violations and len(suppressions) == 1


# ---------------------------------------------------------------------------
# machine-readable report
# ---------------------------------------------------------------------------


def test_json_report_schema(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = tmp_path / "report.json"
    subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", FIXTURES,
         "--json", str(out)],
        cwd=REPO, env=env, capture_output=True, text=True)
    rep = json.loads(out.read_text())
    assert rep["tool"] == "repro.analysis.lint"
    assert rep["ok"] is False
    assert rep["files_scanned"] == len(_SEEDED)
    assert set(rep["rules"]) == set(lint.RULES)
    got = {(v["rule"], os.path.basename(v["path"])) for v in rep["violations"]}
    assert got == {(r, f) for f, r in _SEEDED.items()}
    for v in rep["violations"]:
        assert {"rule", "path", "line", "col", "message"} <= set(v)


# ---------------------------------------------------------------------------
# the gate: the repo's own source is clean
# ---------------------------------------------------------------------------


def test_repo_source_is_lint_clean():
    """Tier-1 CI gate: zero violations over src/, and every suppression is
    explained (carries a reason)."""
    violations, suppressions, n = lint.lint_paths([os.path.join(REPO, "src")])
    assert n > 50, "lint walked suspiciously few files"
    assert not violations, "\n".join(str(v) for v in violations)
    for s in suppressions:
        assert s.reason and s.reason.strip(), f"unexplained suppression: {s}"
