"""The checkify sanitizer behind RoundPlan(debug_checks=True): enabling it
changes nothing (bit-identical losses/params/RNG across the mode x engine
matrix) and corrupted RowSparse inputs trip it."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import checkify

from repro.analysis.sanitize import (check_capacity, check_drop_order,
                                     check_rowsparse, check_union_ids,
                                     checked_jit)
from repro.configs.base import FedConfig
from repro.core.algorithms import ServerState
from repro.data import make_movielens_like
from repro.federated.plan import (RoundPlan, RowSparseTransport,
                                  build_round_step, resolve_plan)
from repro.federated.server import FederatedTrainer
from repro.federated.simulation import make_round_step
from repro.models.recsys import (lr_logits, lr_loss, lstm_loss,
                                 make_lr_params, make_lstm_params)
from repro.sparse.rowsparse import RowSparse, unique_ids_padded

V, E = 128, 6


@pytest.fixture(scope="module")
def params():
    return make_lstm_params(V, emb_dim=E, hidden=8, layers=1,
                            rng=jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def cfg():
    return FedConfig(num_clients=50, clients_per_round=6, lr=0.1,
                     server_lr=1.0, seed=0)


def _flat_batch(seed=0, b=6, s=8):
    r = np.random.RandomState(seed)
    return {"tokens": jnp.asarray(r.randint(0, V, (b, s))),
            "label": jnp.asarray(r.randint(0, V, (b,))),
            "heat_vocab": jnp.asarray(
                np.maximum(r.poisson(3.0, V), 1), jnp.float32)}


def _cohort_batch(seed=0, k=3, i=2, b=2, s=6):
    r = np.random.RandomState(seed)
    return {"tokens": jnp.asarray(r.randint(0, V, (k, i, b, s))),
            "label": jnp.asarray(r.randint(0, V, (k, i, b))),
            "heat_vocab": jnp.asarray(
                np.maximum(r.poisson(3.0, V), 1), jnp.float32)}


def _assert_bit_identical(t1, t2):
    l1, l2 = jax.tree.leaves(t1), jax.tree.leaves(t2)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# parity: debug_checks on vs off is bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,batch_fn", [("sparse", _flat_batch),
                                           ("sparse_replicated",
                                            _cohort_batch)])
def test_debug_checks_parity_make_round_step(params, cfg, mode, batch_fn):
    plain = jax.jit(make_round_step(lstm_loss, params, cfg, mode=mode))
    plan = dataclasses.replace(resolve_plan(mode, cfg), debug_checks=True)
    dbg = make_round_step(lstm_loss, params, cfg, mode=plan)
    p1, p2 = params, params
    for seed in range(3):
        b = batch_fn(seed)
        p1, m1 = plain(p1, b)
        p2, m2 = dbg(p2, b)
        assert float(m1["loss"]) == float(m2["loss"])
    _assert_bit_identical(p1, p2)


def test_debug_checks_parity_int8_rng(params, cfg):
    """The int8 transport draws stochastic-rounding noise from the RNG
    stream; the sanitizer must not consume or shift a single draw."""
    base = resolve_plan("sparse", cfg)
    plan = dataclasses.replace(base, transport=RowSparseTransport(int8=True))
    plain = jax.jit(make_round_step(lstm_loss, params, cfg, mode=plan))
    dbg = make_round_step(
        lstm_loss, params, cfg,
        mode=dataclasses.replace(plan, debug_checks=True))
    b = _flat_batch(3)
    p1, _ = plain(params, b)
    p2, _ = dbg(params, b)
    _assert_bit_identical(p1, p2)


def _trainer(ds, plan=None):
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=6,
                    local_iters=2, local_batch=4, lr=0.5,
                    algorithm="fedsubavg", sparse=True)
    return FederatedTrainer(
        ds, functools.partial(make_lr_params, ds.num_features), lr_loss, cfg,
        predict_fn=lambda p, t: lr_logits(p, jnp.asarray(t["features"])),
        plan=plan)


@pytest.mark.parametrize("engine", ["run_round", "run_rounds"])
def test_debug_checks_parity_trainer(engine):
    """Both trainer execution engines (per-round dispatch and the scan
    engine) are bit-identical with the sanitizer on."""
    ds = make_movielens_like(num_clients=40, num_items=40, mean_samples=15)
    t1 = _trainer(ds)
    t2 = _trainer(ds, plan=dataclasses.replace(t1.plan, debug_checks=True))
    assert "[debug_checks]" in t2.plan.describe()
    if engine == "run_round":
        l1 = [t1.run_round() for _ in range(4)]
        l2 = [t2.run_round() for _ in range(4)]
    else:
        l1 = t1.run_rounds(4)
        l2 = t2.run_rounds(4)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    _assert_bit_identical(t1.state.params, t2.state.params)


def test_dense_plan_debug_checks_is_noop(params, cfg):
    """Dense transport has no RowSparse contract to check: debug_checks
    stays inert and the step still accepts a bare jax.jit."""
    plan = dataclasses.replace(resolve_plan("fedsgd", cfg),
                               debug_checks=True)
    step = jax.jit(make_round_step(lstm_loss, params, cfg, mode=plan))
    _, m = step(params, _flat_batch())
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# the sanitizer trips on contract violations
# ---------------------------------------------------------------------------


def test_sanitizer_trips_on_unsorted_sub_ids(params, cfg):
    plan = dataclasses.replace(resolve_plan("sparse", cfg),
                               debug_checks=True)
    step = checked_jit(build_round_step(plan, lstm_loss, params, cfg))
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    b = _flat_batch()
    state, m = step(state, b)              # derived ids: clean
    assert np.isfinite(float(m["loss"]))
    bad = jnp.concatenate([jnp.asarray([9, 3], jnp.int32),
                           jnp.full((46,), -1, jnp.int32)])
    with pytest.raises(checkify.JaxRuntimeError, match="ascending"):
        step(state, b, bad)


def test_sanitizer_trips_on_interleaved_pads(params, cfg):
    plan = dataclasses.replace(resolve_plan("sparse", cfg),
                               debug_checks=True)
    step = checked_jit(build_round_step(plan, lstm_loss, params, cfg))
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    bad = jnp.asarray([3, -1, 9] + [-1] * 45, jnp.int32)
    with pytest.raises(checkify.JaxRuntimeError, match="trailing"):
        step(state, _flat_batch(), bad)


# ---------------------------------------------------------------------------
# check-function units
# ---------------------------------------------------------------------------


def test_check_union_ids_bounds():
    def f(ids):
        check_union_ids(ids, 8)
        return ids.sum()

    cj = checked_jit(f)
    cj(jnp.asarray([1, 5, 7, -1], jnp.int32))
    with pytest.raises(checkify.JaxRuntimeError, match="out of range"):
        cj(jnp.asarray([1, 5, 9, -1], jnp.int32))


def test_check_rowsparse_pad_rows_zeroed():
    def f(rs):
        check_rowsparse(rs)
        return rs.rows.sum()

    cj = checked_jit(f)
    good = RowSparse(jnp.asarray([2, 5, -1], jnp.int32),
                     jnp.asarray([[1.0], [2.0], [0.0]]), 8)
    cj(good)
    bad = RowSparse(jnp.asarray([2, 5, -1], jnp.int32),
                    jnp.asarray([[1.0], [2.0], [3.0]]), 8)
    with pytest.raises(checkify.JaxRuntimeError, match="pad slot"):
        cj(bad)


def test_check_drop_order():
    def f(ids, toks):
        check_drop_order(ids, toks)
        return ids.sum()

    cj = checked_jit(f)
    toks = jnp.arange(12)
    cj(unique_ids_padded(toks, 8), toks)       # drops 8..11: largest-first
    wrong = jnp.arange(4, 12, dtype=jnp.int32)  # kept largest instead
    with pytest.raises(checkify.JaxRuntimeError, match="largest-first"):
        cj(wrong, toks)
    # a missing id while the union still has pad slots is also a violation
    sparse_union = unique_ids_padded(jnp.asarray([1, 3]), 8)
    with pytest.raises(checkify.JaxRuntimeError):
        cj(sparse_union, jnp.asarray([1, 3, 5]))


def test_check_capacity_static():
    check_capacity(16, V)
    check_capacity(V, V)       # full-vocab bucket is always legal
    with pytest.raises(ValueError, match="multiple of 8"):
        check_capacity(12, V)


def test_checked_jit_exposes_cache_size():
    cj = checked_jit(lambda x: x * 2)
    cj(jnp.ones((3,)))
    assert cj._cache_size() == 1
