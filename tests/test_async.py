"""Buffered-async engine: arrival simulation, sync degeneracy, staleness
weighting, streaming heat, dropout semantics, checkpointing and the
compiled-artifact audits.

The load-bearing pins: (1) zero delay + buffer M=K reproduces the
synchronous ``run_rounds`` engine exactly (losses, params, RNG stream);
(2) zero-staleness weighting equals uniform 1/M averaging; (3) a client
that never arrives leaves its private rows bitwise untouched under the
FedSubAvg correction; (4) scanning the event stream in two halves through a
checkpointed ``AsyncState`` is identical to one uninterrupted scan.
"""
import dataclasses
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_audit import assert_no_dense_intermediates
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import FedConfig
from repro.core.algorithms import ServerState
from repro.data import make_movielens_like
from repro.federated import (ArrivalSim, BufferedAsyncServerUpdate,
                             CohortSharding, DenseTransport, FederatedTrainer,
                             FedSgdLocal, ReplicatedLocal, RoundPlan,
                             RowSparseTransport, ServerUpdate,
                             SubmodelReplicatedLocal, build_async_engine,
                             derive_sub_ids, pow2_capacity, staleness_weight)
from repro.federated.arrivals import ARRIVAL, DISPATCH
from repro.federated.plan import heat_spec_from_axes, sparse_table_paths
from repro.launch.mesh import make_cohort_mesh
from repro.models.recsys import lr_loss, lstm_loss, make_lr_params, \
    make_lstm_params
from repro.sharding.logical import unbox
from repro.sparse.encode import tree_leaf_at

V, E = 64, 4


# ---------------------------------------------------------------------------
# fixtures: a tiny LSTM engine harness + the shared movielens trainer
# ---------------------------------------------------------------------------


def _params():
    return make_lstm_params(V, emb_dim=E, hidden=8, layers=1,
                            rng=jax.random.PRNGKey(1))


def _cfg(**kw):
    kw.setdefault("num_clients", 50)
    kw.setdefault("clients_per_round", 4)
    kw.setdefault("local_iters", 2)
    kw.setdefault("lr", 0.2)
    kw.setdefault("algorithm", "fedsubavg")
    return FedConfig(**kw)


def _plan(server, local=None, transport=None):
    return RoundPlan(local or SubmodelReplicatedLocal(),
                     transport or RowSparseTransport(), server,
                     feature_keys=("tokens",))


def _tasks(num_tasks, seed=0, i=2, b=2, s=6, lo=0, hi=V, special=()):
    """Stacked per-task cohort data; ``special`` tasks draw token ids from a
    reserved range so their rows are provably theirs alone."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(lo, hi, (num_tasks, i, b, s))
    for t, (slo, shi) in special:
        toks[t] = rng.integers(slo, shi, (i, b, s))
    return {"tokens": jnp.asarray(toks, jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, (num_tasks, i, b)),
                                 jnp.int32)}


def _sub_ids(tasks, capacity=None):
    feats = jnp.asarray(np.asarray(tasks["tokens"]).reshape(
        tasks["tokens"].shape[0], -1))
    cap = capacity or pow2_capacity(int(feats.shape[1]))
    return derive_sub_ids(feats, V, cap), cap


def _engine(server, cfg=None, params=None, telemetry=False, **kw):
    cfg = cfg or _cfg()
    params = params if params is not None else _params()
    counts = {"vocab": jnp.full((V,), 5.0, jnp.float32)}
    eng = build_async_engine(_plan(server, **kw), lstm_loss, params, cfg,
                             heat_counts=counts, total=float(cfg.num_clients),
                             telemetry=telemetry)
    return eng, params


@pytest.fixture(scope="module")
def small_ds():
    return make_movielens_like(num_clients=40, num_items=40, mean_samples=15)


def _trainer(ds, **kw):
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=6,
                    local_iters=3, local_batch=4, lr=0.5,
                    algorithm="fedsubavg", sparse=True, **kw)
    return FederatedTrainer(
        ds, functools.partial(make_lr_params, ds.num_features), lr_loss, cfg)


# ---------------------------------------------------------------------------
# ArrivalSim / EventSchedule
# ---------------------------------------------------------------------------


def test_arrival_sim_deterministic_and_well_formed():
    sim = ArrivalSim(num_rounds=4, delay="lognormal", delay_scale=0.5,
                     lognormal_sigma=1.5, straggler_frac=0.1,
                     dropout_frac=0.1, seed=3)
    a, b = sim.compile(5, 4), sim.compile(5, 4)
    for k in a.event_arrays():
        np.testing.assert_array_equal(a.event_arrays()[k],
                                      b.event_arrays()[k])
    live = int((~a.dropped).sum())
    assert a.num_events == 2 * live and a.num_arrivals == live
    assert a.num_fires == live // 4
    assert int(a.fire.sum()) == a.num_fires
    # every live task dispatches before it arrives, on the same slot
    seen = {}
    for e in range(a.num_events):
        t = int(a.task[e])
        if a.kind[e] == DISPATCH:
            assert t not in seen
            seen[t] = int(a.slot[e])
        else:
            assert seen.pop(t) == int(a.slot[e])
            assert a.staleness[e] >= 0
    assert not seen
    assert a.num_slots <= live and int(a.inflight.max()) == a.num_slots


def test_zero_delay_schedule_is_the_synchronous_order():
    sch = ArrivalSim(num_rounds=3).compile(4, 4)
    kinds = sch.kind.reshape(3, 8)
    assert (kinds[:, :4] == DISPATCH).all() and (kinds[:, 4:] == ARRIVAL).all()
    assert (sch.staleness == 0).all()
    assert (sch.task.reshape(3, 8) == np.arange(12).reshape(3, 4).repeat(
        2, axis=0).reshape(3, 8)).all()
    assert sch.sim_speedup() == pytest.approx(1.0)


def test_straggler_and_dropout_injection():
    sim = ArrivalSim(num_rounds=2, delay="exponential", delay_scale=0.5,
                     straggler_tasks=(1,), straggler_factor=50.0,
                     dropout_tasks=(2,), seed=0)
    sch = sim.compile(3, 3)
    base = ArrivalSim(num_rounds=2, delay="exponential", delay_scale=0.5,
                      seed=0).compile(3, 3)
    assert sch.arrival_time[1] == pytest.approx(
        sch.dispatch_time[1] + 50.0 * (base.arrival_time[1]
                                       - base.dispatch_time[1]))
    assert sch.dropped[2] and not np.isfinite(sch.arrival_time[2])
    assert 2 not in set(sch.task.tolist())
    # the barrier engine waits for the straggler; async does not serialise it
    heavy = ArrivalSim(num_rounds=4, delay="lognormal", delay_scale=0.5,
                       lognormal_sigma=1.5, straggler_frac=0.2,
                       straggler_factor=10.0, seed=1).compile(4, 4)
    assert heavy.sim_speedup() > 1.0


def test_arrival_sim_validation():
    with pytest.raises(ValueError, match="num_rounds"):
        ArrivalSim(num_rounds=0)
    with pytest.raises(ValueError, match="delay distribution"):
        ArrivalSim(num_rounds=1, delay="uniform")
    with pytest.raises(ValueError, match="out of range"):
        ArrivalSim(num_rounds=1, dropout_tasks=(99,)).compile(4, 4)
    with pytest.raises(ValueError, match="out of range"):
        ArrivalSim(num_rounds=1, straggler_tasks=(-1,)).compile(4, 4)


# ---------------------------------------------------------------------------
# the degeneracy pin: zero delay + M=K == run_rounds
# ---------------------------------------------------------------------------


def test_zero_delay_full_buffer_matches_run_rounds(small_ds):
    """ISSUE 9 acceptance: same losses, same params, same RNG stream."""
    t_sync, t_async = _trainer(small_ds), _trainer(small_ds)
    losses_sync = t_sync.run_rounds(5)
    losses_async = t_async.run_async(ArrivalSim(num_rounds=5))
    np.testing.assert_allclose(losses_async, losses_sync, rtol=1e-5,
                               atol=1e-6)
    for a, b in zip(jax.tree.leaves(unbox(t_sync.state.params)),
                    jax.tree.leaves(unbox(t_async.state.params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
    assert int(t_sync.state.rounds) == int(t_async.state.rounds) == 5
    # both consumed np_rng identically — the next draw agrees
    assert (t_sync.np_rng.integers(1 << 30)
            == t_async.np_rng.integers(1 << 30))
    # and the per-fire comm accounting matches the per-round accounting
    assert len(t_async.comm_log) == len(t_sync.comm_log) == 5
    for cs, ca in zip(t_sync.comm_log, t_async.comm_log):
        assert ca.bytes_up_sparse == pytest.approx(cs.bytes_up_sparse)


def test_zero_staleness_weighting_is_uniform_mean(small_ds):
    """Property pin: on an all-fresh buffer the polynomial weights are all
    ``w(0) = 1``, so polynomial and constant weighting are the SAME uniform
    1/M average — bit-identical losses and params."""
    runs = {}
    for scheme in ("constant", "polynomial"):
        tr = _trainer(small_ds)
        srv = BufferedAsyncServerUpdate(buffer_size=6, staleness=scheme,
                                        staleness_alpha=0.7)
        runs[scheme] = (tr.run_async(ArrivalSim(num_rounds=4), server=srv),
                        tr.state.params)
    np.testing.assert_allclose(runs["polynomial"][0], runs["constant"][0],
                               rtol=0, atol=0)
    for a, b in zip(jax.tree.leaves(unbox(runs["constant"][1])),
                    jax.tree.leaves(unbox(runs["polynomial"][1]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staleness_weight_values():
    np.testing.assert_allclose(
        np.asarray(staleness_weight(jnp.arange(4), "constant")), 1.0)
    w = np.asarray(staleness_weight(jnp.arange(4), "polynomial", 0.5))
    assert w[0] == pytest.approx(1.0)
    np.testing.assert_allclose(w, 1.0 / np.sqrt(1.0 + np.arange(4)),
                               rtol=1e-6)
    assert (np.diff(w) < 0).all()
    with pytest.raises(ValueError, match="staleness scheme"):
        staleness_weight(jnp.zeros(()), "linear")


def test_polynomial_staleness_damps_stale_deltas():
    """Under real delays the two schemes genuinely diverge (staleness > 0
    exists), and stronger damping shrinks the server step."""
    sim = ArrivalSim(num_rounds=4, delay="lognormal", delay_scale=1.0,
                     lognormal_sigma=1.5, seed=5)
    sch = sim.compile(4, 2)
    assert int(sch.staleness.max()) > 0
    final = {}
    for scheme, alpha in (("constant", 0.0), ("polynomial", 2.0)):
        eng, params = _engine(BufferedAsyncServerUpdate(
            buffer_size=2, staleness=scheme, staleness_alpha=alpha))
        st = eng.init(ServerState(params, (), jnp.zeros((), jnp.int32)),
                      num_slots=sch.num_slots, capacity=32)
        tasks = _tasks(sch.num_tasks, seed=2)
        sub_ids, _ = _sub_ids(tasks, 32)
        st, _ = jax.jit(eng.run)(st, sch.event_arrays(), tasks, sub_ids)
        final[scheme] = unbox(st.server.params)
    diffs = [float(np.abs(np.asarray(a) - np.asarray(b)).max())
             for a, b in zip(jax.tree.leaves(final["constant"]),
                             jax.tree.leaves(final["polynomial"]))]
    assert max(diffs) > 0.0


# ---------------------------------------------------------------------------
# dropout semantics under the FedSubAvg correction
# ---------------------------------------------------------------------------


def test_never_arriving_client_rows_get_zero_update():
    """A dropped client's update must simply not exist: its private rows
    (ids no other client touches) stay BITWISE untouched — the FedSubAvg
    correction never invents mass for rows nobody delivered."""
    k, rounds = 2, 2
    drop_task = 3
    sim = ArrivalSim(num_rounds=rounds, delay="exponential", delay_scale=0.5,
                     dropout_tasks=(drop_task,), seed=4)
    sch = sim.compile(k, 2)
    tasks = _tasks(rounds * k, seed=9, lo=0, hi=48,
                   special=((drop_task, (48, V)),))
    sub_ids, cap = _sub_ids(tasks, 32)
    eng, params = _engine(BufferedAsyncServerUpdate(buffer_size=2))
    st = eng.init(ServerState(params, (), jnp.zeros((), jnp.int32)),
                  num_slots=sch.num_slots, capacity=cap)
    st, _ = jax.jit(eng.run)(st, sch.event_arrays(), tasks, sub_ids)
    spec = heat_spec_from_axes(params)
    path = sparse_table_paths(spec)[0][0]
    before = np.asarray(tree_leaf_at(unbox(params), path))
    after = np.asarray(tree_leaf_at(unbox(st.server.params), path))
    np.testing.assert_array_equal(after[48:V], before[48:V])
    assert np.abs(after[:48] - before[:48]).max() > 0.0


# ---------------------------------------------------------------------------
# streaming heat
# ---------------------------------------------------------------------------


def test_ema_heat_tracks_arrivals_and_stays_clamped():
    sim = ArrivalSim(num_rounds=3, delay="exponential", delay_scale=0.3,
                     seed=6)
    sch = sim.compile(3, 3)
    srv = BufferedAsyncServerUpdate(buffer_size=3, heat="ema", heat_beta=0.2)
    eng, params = _engine(srv)
    cfg = _cfg()
    st = eng.init(ServerState(params, (), jnp.zeros((), jnp.int32)),
                  num_slots=sch.num_slots, capacity=32)
    p0 = np.asarray(st.heat_ema)
    np.testing.assert_allclose(p0, 5.0 / cfg.num_clients, rtol=1e-6)
    tasks = _tasks(sch.num_tasks, seed=3, lo=0, hi=32)  # ids >= 32 never seen
    sub_ids, _ = _sub_ids(tasks, 32)
    st, _ = jax.jit(eng.run)(st, sch.event_arrays(), tasks, sub_ids)
    p = np.asarray(st.heat_ema)
    assert ((0.0 <= p) & (p <= 1.0)).all()
    # untouched ids decayed toward 0; touched ids moved up toward 1
    a = sch.num_arrivals
    np.testing.assert_allclose(p[32:], p0[32:] * (1 - 0.2) ** a, rtol=1e-5)
    assert p[:32].max() > p0.max()
    assert int(st.arrivals) == a


def test_ema_heat_run_converges_on_trainer(small_ds):
    tr = _trainer(small_ds)
    srv = BufferedAsyncServerUpdate(buffer_size=6, heat="ema", heat_beta=0.1)
    l1 = tr.run_async(ArrivalSim(num_rounds=4), server=srv)
    assert tr._async_heat_ema is not None
    ema_after_first = np.asarray(tr._async_heat_ema)
    l2 = tr.run_async(ArrivalSim(num_rounds=4, seed=1), server=srv)
    # the EMA persisted and kept moving across calls
    assert np.abs(np.asarray(tr._async_heat_ema) - ema_after_first).max() > 0
    assert np.isfinite(l1 + l2).all() and l2[-1] < l1[0]


# ---------------------------------------------------------------------------
# mid-run checkpoint / resume
# ---------------------------------------------------------------------------


def test_mid_run_checkpoint_resume_is_exact(tmp_path):
    """Scan [0, e) -> save AsyncState (server + slots + buffer + EMA heat)
    -> restore into a fresh state -> scan [e, E) == one uninterrupted scan,
    to f32 round-trip exactness."""
    sim = ArrivalSim(num_rounds=4, delay="lognormal", delay_scale=0.5,
                     lognormal_sigma=1.2, seed=8)
    sch = sim.compile(3, 2)
    srv = BufferedAsyncServerUpdate(buffer_size=2, staleness="polynomial",
                                    heat="ema", heat_beta=0.1)
    eng, params = _engine(srv)
    tasks = _tasks(sch.num_tasks, seed=11)
    sub_ids, cap = _sub_ids(tasks, 32)
    run = jax.jit(eng.run)

    def fresh():
        return eng.init(ServerState(params, (), jnp.zeros((), jnp.int32)),
                        num_slots=sch.num_slots, capacity=cap)

    full, ys_full = run(fresh(), sch.event_arrays(), tasks, sub_ids)
    cut = sch.num_events // 2
    half, ys_a = run(fresh(), sch.slice_events(0, cut), tasks, sub_ids)
    path = str(tmp_path / "async_state")
    save_checkpoint(path, half, step=cut)
    # clobber, then restore into a freshly-built template
    template = jax.tree.map(lambda x: x * 0 if jnp.issubdtype(
        jnp.asarray(x).dtype, jnp.floating) else x, fresh())
    resumed = load_checkpoint(path, template)
    assert int(resumed.arrivals) == int(half.arrivals)
    done, ys_b = run(resumed, sch.slice_events(cut, sch.num_events), tasks,
                     sub_ids)
    for a, b in zip(jax.tree.leaves(unbox(full.server.params)),
                    jax.tree.leaves(unbox(done.server.params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)
    np.testing.assert_allclose(np.asarray(done.heat_ema),
                               np.asarray(full.heat_ema), rtol=1e-6)
    np.testing.assert_allclose(
        np.concatenate([np.asarray(ys_a["loss"]), np.asarray(ys_b["loss"])]),
        np.asarray(ys_full["loss"]), rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# rejections (each with a reason) + slot validation
# ---------------------------------------------------------------------------


def test_server_slot_validation():
    with pytest.raises(ValueError, match="async server algorithm"):
        BufferedAsyncServerUpdate(algorithm="fedadam")
    with pytest.raises(ValueError, match="buffer_size"):
        BufferedAsyncServerUpdate(buffer_size=0)
    with pytest.raises(ValueError, match="staleness scheme"):
        BufferedAsyncServerUpdate(staleness="exp")
    with pytest.raises(ValueError, match="staleness_alpha"):
        BufferedAsyncServerUpdate(staleness_alpha=-1.0)
    with pytest.raises(ValueError, match="heat mode"):
        BufferedAsyncServerUpdate(heat="exact")
    with pytest.raises(ValueError, match="heat_beta"):
        BufferedAsyncServerUpdate(heat="ema", heat_beta=0.0)
    assert BufferedAsyncServerUpdate().correct
    assert not BufferedAsyncServerUpdate(algorithm="fedavg").correct
    assert BufferedAsyncServerUpdate().stateless


def test_engine_rejects_incompatible_plans():
    params, cfg = _params(), _cfg()
    srv = BufferedAsyncServerUpdate()
    counts = {"vocab": jnp.full((V,), 5.0, jnp.float32)}

    def build(plan):
        return build_async_engine(plan, lstm_loss, params, cfg,
                                  heat_counts=counts, total=50.0)

    with pytest.raises(TypeError, match="BufferedAsyncServerUpdate"):
        build(_plan(ServerUpdate("fedsubavg")))
    with pytest.raises(ValueError, match="inherently sequential"):
        build(dataclasses.replace(
            _plan(srv), sharding=CohortSharding(make_cohort_mesh())))
    with pytest.raises(ValueError, match="RowSparseTransport"):
        build(_plan(srv, transport=DenseTransport()))
    with pytest.raises(ValueError, match="int8"):
        build(_plan(srv, transport=RowSparseTransport(int8=True)))
    with pytest.raises(ValueError, match="FedSgdLocal"):
        build(_plan(srv, local=FedSgdLocal()))
    with pytest.raises(ValueError, match="debug_checks"):
        build(dataclasses.replace(_plan(srv), debug_checks=True))
    with pytest.raises(ValueError, match="heat_counts"):
        build_async_engine(_plan(srv), lstm_loss, params, cfg)
    # ReplicatedLocal (dense local step, sparse-encoded delta) is accepted
    build(_plan(srv, local=ReplicatedLocal()))


def test_trainer_run_async_rejections(small_ds):
    dense = FederatedTrainer(
        small_ds, functools.partial(make_lr_params, small_ds.num_features),
        lr_loss, FedConfig(num_clients=small_ds.num_clients,
                           clients_per_round=6, local_iters=2,
                           algorithm="fedsubavg", sparse=False))
    with pytest.raises(ValueError, match="sparse"):
        dense.run_async(ArrivalSim(num_rounds=1))
    # a cohort-sharded trainer must reject run_async with the reason pinned
    sharded = FederatedTrainer(
        small_ds, functools.partial(make_lr_params, small_ds.num_features),
        lr_loss, FedConfig(num_clients=small_ds.num_clients,
                           clients_per_round=6, local_iters=2,
                           algorithm="fedsubavg", sparse=True),
        mesh=make_cohort_mesh())
    with pytest.raises(ValueError, match="inherently sequential"):
        sharded.run_async(ArrivalSim(num_rounds=1))


# ---------------------------------------------------------------------------
# telemetry threading
# ---------------------------------------------------------------------------


def test_async_telemetry_fields(small_ds):
    tr = _trainer(small_ds)
    srv = BufferedAsyncServerUpdate(buffer_size=3, staleness="polynomial")
    sim = ArrivalSim(num_rounds=4, delay="lognormal", delay_scale=0.5,
                     lognormal_sigma=1.5, straggler_frac=0.1, seed=2)
    losses = tr.run_async(sim, server=srv)
    sch = sim.compile(6, 3)
    rounds = [e for e in tr.telemetry_log if e["event"] == "round"]
    assert len(rounds) == sch.num_fires == len(losses)
    for e in rounds:
        assert sum(e["staleness_hist"]) == pytest.approx(3.0)  # M per fire
        assert e["buffer_occupancy"] >= 0
        assert e["union_size"] > 0 and e["density"] > 0
        assert e["shard_union_sizes"] is None
        assert len(e["dropped_per_client"]) == 3
    # the synchronous engine leaves the async fields None
    tr2 = _trainer(small_ds)
    tr2.run_rounds(1)
    sync_round = [e for e in tr2.telemetry_log if e["event"] == "round"][-1]
    assert sync_round["staleness_hist"] is None
    assert sync_round["buffer_occupancy"] is None


# ---------------------------------------------------------------------------
# compiled-artifact audit at full-vocab scale
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("heat", ["static", "ema"])
def test_async_step_has_no_dense_intermediates(heat):
    """The paper's core claim survives the async engine: no float (V, ...)
    intermediate anywhere in the event scan at V=65536 — slots, buffer,
    aggregation and apply all stay RowSparse; the streaming-heat EMA is a
    1-D (V,) statistic, not a densified table."""
    big_v = 65536
    params = make_lstm_params(big_v, emb_dim=E, hidden=8, layers=1,
                              rng=jax.random.PRNGKey(1))
    cfg = _cfg()
    srv = BufferedAsyncServerUpdate(buffer_size=2, staleness="polynomial",
                                    heat=heat)
    eng = build_async_engine(
        _plan(srv), lstm_loss, params, cfg,
        heat_counts={"vocab": jnp.full((big_v,), 5.0, jnp.float32)},
        total=50.0, telemetry=True)
    sch = ArrivalSim(num_rounds=2, delay="exponential",
                     delay_scale=0.4, seed=0).compile(2, 2)
    rng = np.random.default_rng(0)
    tasks = {"tokens": jnp.asarray(rng.integers(0, big_v, (4, 2, 2, 6)),
                                   jnp.int32),
             "label": jnp.asarray(rng.integers(0, 2, (4, 2, 2)), jnp.int32)}
    feats = jnp.asarray(np.asarray(tasks["tokens"]).reshape(4, -1))
    sub_ids = derive_sub_ids(feats, big_v, 32)
    st = eng.init(ServerState(params, (), jnp.zeros((), jnp.int32)),
                  num_slots=sch.num_slots, capacity=32)
    assert_no_dense_intermediates(eng.run, st, sch.event_arrays(), tasks,
                                  sub_ids, feats, dim0=big_v)


def test_trainer_async_engine_caches_per_server_slot(small_ds):
    tr = _trainer(small_ds)
    tr.run_async(ArrivalSim(num_rounds=2))
    tr.run_async(ArrivalSim(num_rounds=2, seed=1))   # same slot -> cached
    assert len(tr._async_engines) == 1
    tr.run_async(ArrivalSim(num_rounds=2),
                 server=BufferedAsyncServerUpdate(buffer_size=3))
    assert len(tr._async_engines) == 2
