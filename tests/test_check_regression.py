"""The bench regression gate itself: a stale baseline missing a whole
section must fail by name, not pass vacuously (or crash with KeyError)."""
import copy
import importlib
import sys
import os

import pytest

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))))
check_regression = importlib.import_module("benchmarks.check_regression")


def _fresh():
    return {
        "smoke": True,
        "records": [
            {"section": "union_backends", "v": 1024, "density": 0.1, "k": 4,
             "d": 8, "us_sort": 100.0, "us_bitmap": 50.0},
            {"section": "engine", "v": 1024, "k": 4, "rounds": 8,
             "speedup": 3.0},
            {"section": "telemetry", "v": 1024, "k": 4, "rounds": 8,
             "us_per_round_off": 10.0, "us_per_round_on": 11.0,
             "overhead": 0.1, "dropped_ids": 0, "dropped_mass": 0.0,
             "mean_union_size": 12.0, "mean_density": 0.2,
             "jsonl_events": 8, "jsonl": "x.jsonl"},
            {"section": "async", "v": 1024, "k": 4, "rounds": 8,
             "buffer": 2, "events": 60, "fires": 15, "arrivals": 30,
             "us_per_event": 500.0, "barrier_makespan": 40.0,
             "async_makespan": 16.0, "clients_per_unit_barrier": 0.75,
             "clients_per_unit_async": 1.875, "sim_speedup": 2.5},
            {"section": "kernel_roofline", "v": 1024, "density": 0.1,
             "k": 4, "d": 8, "backend": "pallas", "analytic_bytes": 40000,
             "analytic_flops": 8.0e6, "intensity": 200.0, "restream": 1.0,
             "us": 800.0, "achieved_gbps": 0.05, "hbm_frac": 6e-5},
        ],
    }


def test_matching_baseline_passes():
    fresh = _fresh()
    assert check_regression.check(fresh, copy.deepcopy(fresh), 0.25) == []


@pytest.mark.parametrize("section", ["union_backends", "engine", "async"])
def test_baseline_missing_section_fails_by_name(section):
    """The negative path: drop one whole section from the baseline. The
    gate must produce a failure naming that section (previously the
    per-record loops just iterated zero baseline records and the section
    passed silently)."""
    fresh = _fresh()
    baseline = copy.deepcopy(fresh)
    baseline["records"] = [r for r in baseline["records"]
                           if r["section"] != section]
    failures = check_regression.check(fresh, baseline, 0.25)
    named = [f for f in failures if f"'{section}'" in f]
    assert named, f"no named-section failure for {section!r}: {failures}"
    assert "stale or truncated" in named[0]


def test_baseline_missing_section_fresh_lacks_it_too_is_fine():
    """A section absent from BOTH runs is not a staleness signal (e.g. a
    single-device box emits no sharded records)."""
    fresh = _fresh()
    baseline = copy.deepcopy(fresh)
    for d in (fresh, baseline):
        d["records"] = [r for r in d["records"]
                        if r["section"] != "union_backends"]
    failures = check_regression.check(fresh, baseline, 0.25)
    # the only acceptable failure is the pre-existing "no union_backends
    # records" guard on the fresh run
    assert all("stale or truncated" not in f for f in failures)


def test_async_speedup_must_beat_barrier():
    """The acceptance pin: an async section whose modeled speedup does not
    beat the barrier fails regardless of the baseline."""
    fresh = _fresh()
    for rec in fresh["records"]:
        if rec["section"] == "async":
            rec["sim_speedup"] = 0.9
    failures = check_regression.check(fresh, copy.deepcopy(fresh), 0.25)
    assert any("sim_speedup must exceed 1.0" in f for f in failures)


def test_async_speedup_ratio_gated_against_baseline():
    fresh = _fresh()
    baseline = copy.deepcopy(fresh)
    for rec in fresh["records"]:
        if rec["section"] == "async":
            rec["sim_speedup"] = 1.2      # > 1, but way below baseline 2.5
    failures = check_regression.check(fresh, baseline, 0.25)
    assert any("sim_speedup regressed" in f for f in failures)
    # within the threshold: no ratio failure
    for rec in fresh["records"]:
        if rec["section"] == "async":
            rec["sim_speedup"] = 2.3
    assert check_regression.check(fresh, baseline, 0.25) == []


def test_main_exit_codes(tmp_path):
    import json
    fresh = _fresh()
    stale = copy.deepcopy(fresh)
    stale["records"] = [r for r in stale["records"]
                        if r["section"] != "engine"]
    fp, bp, sp = (tmp_path / n for n in ("f.json", "b.json", "s.json"))
    fp.write_text(json.dumps(fresh))
    bp.write_text(json.dumps(fresh))
    sp.write_text(json.dumps(stale))
    assert check_regression.main([str(fp), "--baseline", str(bp)]) == 0
    assert check_regression.main([str(fp), "--baseline", str(sp)]) == 1


def test_kernel_roofline_analytic_bytes_growth_fails():
    """The deterministic pin: analytic bytes growing past the threshold is
    re-streaming or a densified path, regardless of runner speed."""
    fresh = _fresh()
    baseline = copy.deepcopy(fresh)
    for rec in fresh["records"]:
        if rec["section"] == "kernel_roofline":
            rec["analytic_bytes"] = rec["analytic_bytes"] * 2
            rec["restream"] = 64.0
    failures = check_regression.check(fresh, baseline, 0.25)
    assert any("analytic_bytes grew" in f for f in failures)
    assert any("restream grew" in f for f in failures)
    # within the threshold: no failure
    fresh = _fresh()
    for rec in fresh["records"]:
        if rec["section"] == "kernel_roofline":
            rec["analytic_bytes"] = int(rec["analytic_bytes"] * 1.1)
    assert check_regression.check(fresh, baseline, 0.25) == []


def test_kernel_roofline_fresh_sanity():
    """Fresh-only checks: positive analytic bytes; timed records must carry
    a positive achieved bandwidth (analytic-only off-TPU cells are exempt)."""
    fresh = _fresh()
    for rec in fresh["records"]:
        if rec["section"] == "kernel_roofline":
            rec["achieved_gbps"] = 0.0
    failures = check_regression.check(fresh, copy.deepcopy(fresh), 0.25)
    assert any("non-positive achieved_gbps" in f for f in failures)
    for rec in fresh["records"]:
        if rec["section"] == "kernel_roofline":
            rec["analytic_only"] = True   # off-TPU pallas cell: exempt
    fresh2 = copy.deepcopy(fresh)
    assert check_regression.check(fresh2, copy.deepcopy(fresh2), 0.25) == []


def test_kernel_roofline_missing_from_baseline_is_stale():
    fresh = _fresh()
    baseline = copy.deepcopy(fresh)
    baseline["records"] = [r for r in baseline["records"]
                           if r["section"] != "kernel_roofline"]
    failures = check_regression.check(fresh, baseline, 0.25)
    assert any("'kernel_roofline'" in f and "stale or truncated" in f
               for f in failures)
