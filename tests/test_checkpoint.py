import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import FedConfig, get_smoke_config
from repro.models import build_model
from repro.sharding.logical import unbox


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("qwen3_32b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(3))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, step=7, extra={"arch": cfg.name})
    restored = load_checkpoint(path, params)
    for a, b in zip(jax.tree.leaves(unbox(params)), jax.tree.leaves(unbox(restored))):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    import json
    meta = json.load(open(path + ".meta.json"))
    assert meta["step"] == 7
    assert meta["extra"]["arch"] == cfg.name


@pytest.mark.parametrize("alg", ["fedsubavg", "fedadam"])
def test_sparse_trainer_state_checkpoint_resume(tmp_path, alg):
    """Save a sparse FederatedTrainer's ServerState mid-run, restore it into
    a fresh trainer, and verify the resumed losses match an uninterrupted run
    to f32 tolerance — catches pytree/aux-data drift in RowSparse-era params
    (Param boxes, opt momenta slots, the rounds counter)."""
    from repro.data import make_movielens_like
    from repro.federated import FederatedTrainer
    from repro.models.recsys import lr_loss, make_lr_params

    ds = make_movielens_like(num_clients=40, num_items=40, mean_samples=15)

    def make():
        cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=6,
                        local_iters=3, local_batch=4, lr=0.5, algorithm=alg,
                        sparse=True)
        return FederatedTrainer(
            ds, functools.partial(make_lr_params, ds.num_features), lr_loss, cfg)

    path = str(tmp_path / f"state_{alg}")
    tr1 = make()
    for _ in range(3):
        tr1.run_round()
    save_checkpoint(path, tr1.state, step=tr1._rounds_run)
    reference = [tr1.run_round() for _ in range(3)]       # uninterrupted

    tr2 = make()
    for _ in range(3):
        tr2.run_round()                                   # replay the RNG stream
    # clobber the live state so the assertion below can only pass if the
    # checkpoint round-trip truly restored params/opt/rounds
    tr2.state = jax.tree.map(
        lambda x: x * 0 if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
        else x, tr2.state)
    tr2.state = load_checkpoint(path, tr2.state)
    assert int(tr2.state.rounds) == 3
    resumed = [tr2.run_round() for _ in range(3)]
    np.testing.assert_allclose(resumed, reference, rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree.leaves(unbox(tr1.state.params)),
                    jax.tree.leaves(unbox(tr2.state.params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
