import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.sharding.logical import unbox


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("qwen3_32b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(3))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, step=7, extra={"arch": cfg.name})
    restored = load_checkpoint(path, params)
    for a, b in zip(jax.tree.leaves(unbox(params)), jax.tree.leaves(unbox(restored))):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    import json
    meta = json.load(open(path + ".meta.json"))
    assert meta["step"] == 7
    assert meta["extra"]["arch"] == cfg.name
