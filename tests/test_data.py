"""Synthetic federated datasets: statistics + learnability invariants."""
import numpy as np
import pytest

from repro.data.synthetic import (make_alibaba_like, make_amazon_like,
                                  make_lm_federated, make_movielens_like,
                                  make_sent140_like)


@pytest.mark.parametrize("maker,task", [
    (make_movielens_like, "lr"),
    (make_sent140_like, "lstm"),
    (make_amazon_like, "din"),
    (make_alibaba_like, "din"),
    (make_lm_federated, "lm"),
])
def test_dataset_invariants(maker, task):
    ds = maker()
    assert ds.task == task
    assert ds.num_clients == len(ds.sample_counts)
    # heat counts never exceed the client count, dispersion > 1 (hot/cold split)
    assert ds.heat.counts.max() <= ds.num_clients
    assert ds.heat.dispersion() > 2.0
    key = ds.feature_key
    assert key in ds.client_data
    ids = ds.client_data[key]
    assert ids.max() < ds.num_features
    # padded leaves share the leading (clients, max_samples) shape
    shapes = {v.shape[:2] for v in ds.client_data.values()}
    assert len(shapes) == 1


def test_movielens_labels_learnable():
    """Pooled logistic regression on the planted model must beat chance."""
    ds = make_movielens_like(num_clients=100, num_items=60)
    import jax, jax.numpy as jnp
    from repro.models.recsys import lr_loss, lr_logits, make_lr_params
    params = make_lr_params(ds.num_features, rng=jax.random.PRNGKey(0))
    feats, labels = [], []
    for c in range(ds.num_clients):
        n = ds.sample_counts[c]
        feats.append(ds.client_data["features"][c][:n])
        labels.append(ds.client_data["label"][c][:n])
    feats = jnp.asarray(np.concatenate(feats))
    labels = jnp.asarray(np.concatenate(labels))
    batch = {"features": feats, "label": labels}

    @jax.jit
    def step(p):
        g = jax.grad(lr_loss)(p, batch)
        return jax.tree.map(lambda a, b: a - 1.0 * b, p, g)

    for _ in range(60):
        params = step(params)
    acc = float(((lr_logits(params, feats) > 0) == (labels > 0.5)).mean())
    assert acc > 0.65


def test_dispersion_grows_with_zipf_exponent():
    lo = make_movielens_like(num_clients=150, num_items=100, zipf_a=0.6, seed=3)
    hi = make_movielens_like(num_clients=150, num_items=100, zipf_a=1.8, seed=3)
    assert hi.heat.dispersion() >= lo.heat.dispersion()
