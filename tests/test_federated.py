"""Federated runtime: end-to-end rounds, algorithm comparisons, pod-scale
round step semantics."""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_sub
from repro.configs import FedConfig, get_smoke_config
from repro.data import make_movielens_like, make_lm_federated
from repro.federated import FederatedTrainer, heat_spec_from_axes, make_round_step
from repro.federated.metrics import auc
from repro.models import build_model
from repro.models.recsys import lr_logits, lr_loss, make_lr_params
from repro.sharding.logical import unbox


@pytest.fixture(scope="module")
def ds():
    return make_movielens_like(num_clients=80, num_items=60, mean_samples=25)


def _trainer(ds, alg, rounds=20, **kw):
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=8, local_iters=4,
                    local_batch=5, lr=0.5, algorithm=alg, **kw)
    mk = functools.partial(make_lr_params, ds.num_features)
    tr = FederatedTrainer(ds, mk, lr_loss, cfg,
                          predict_fn=lambda p, t: lr_logits(p, jnp.asarray(t["features"])),
                          metric="auc")
    tr.run(rounds, eval_every=rounds)
    return tr


def test_fedsubavg_beats_fedavg(ds):
    """The paper's headline: faster convergence under heat dispersion."""
    t_avg = _trainer(ds, "fedavg")
    t_sub = _trainer(ds, "fedsubavg")
    assert t_sub.history[-1].train_loss < t_avg.history[-1].train_loss
    assert t_sub.history[-1].test_metric > t_avg.history[-1].test_metric


@pytest.mark.parametrize("alg", ["fedprox", "scaffold", "fedadam", "central"])
def test_all_baselines_run(ds, alg):
    tr = _trainer(ds, alg, rounds=5)
    assert np.isfinite(tr.history[-1].train_loss)


def test_randomized_response_heat_still_works(ds):
    tr = _trainer(ds, "fedsubavg", rounds=10, heat_estimator="randomized_response",
                  rr_flip_prob=0.05)
    t_avg = _trainer(ds, "fedavg", rounds=10)
    assert tr.history[-1].train_loss < t_avg.history[-1].train_loss


def test_weighted_correction(ds):
    tr = _trainer(ds, "fedsubavg", rounds=5, weighted=True)
    assert np.isfinite(tr.history[-1].train_loss)
    # weighted heat total equals total training samples
    assert tr.heat.total == pytest.approx(ds.sample_counts.sum())


def test_heat_spec_from_axes_lm():
    cfg = get_smoke_config("mixtral_8x22b")
    api = build_model(cfg)
    spec = heat_spec_from_axes(api.abstract_params())
    leaves = jax.tree.leaves(spec.leaf_spaces,
                             is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                             and isinstance(x[1], int))
    spaces = {l[0] for l in leaves if isinstance(l, tuple)}
    assert spaces == {"vocab", "expert"}


def test_round_step_fedsgd_matches_manual():
    cfg = get_smoke_config("qwen2_5_14b").replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    fed = FedConfig(num_clients=100, clients_per_round=4, lr=0.1, algorithm="fedsubavg")
    step = make_round_step(api.loss, params, fed, mode="fedsgd", correct=True)
    b, s = 4, 32
    heat = jnp.maximum(jax.random.randint(jax.random.PRNGKey(1), (cfg.vocab_size,), 0, 50)
                       .astype(jnp.float32), 0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size),
             "labels": jnp.ones((b, s), jnp.int32),
             "mask": jnp.ones((b, s), jnp.float32),
             "heat_vocab": heat}
    new_params, metrics = jax.jit(step)(params, batch)
    assert np.isfinite(float(metrics["loss"]))

    # manual: grad -> -lr*grad -> heat correct embedding rows -> add
    data = {k: v for k, v in batch.items() if not k.startswith("heat_")}
    grads = jax.grad(api.loss)(params, data)
    g_emb = unbox(grads)["embedding"]
    factor = jnp.where(heat > 0, 100.0 / jnp.maximum(heat, 1.0), 0.0)
    want_emb = unbox(params)["embedding"] - 0.1 * g_emb * factor[:, None]
    np.testing.assert_allclose(np.asarray(unbox(new_params)["embedding"]),
                               np.asarray(want_emb), rtol=5e-4, atol=5e-6)


def test_microbatched_grads_match_full():
    cfg = get_smoke_config("qwen3_32b").replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    heat = jnp.ones((cfg.vocab_size,), jnp.float32)
    b, s = 8, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab_size),
             "labels": jnp.ones((b, s), jnp.int32),
             "mask": jnp.ones((b, s), jnp.float32),
             "heat_vocab": heat}
    fed1 = FedConfig(num_clients=10, lr=0.1, algorithm="fedsubavg", microbatches=1)
    fed4 = FedConfig(num_clients=10, lr=0.1, algorithm="fedsubavg", microbatches=4)
    p1, m1 = jax.jit(make_round_step(api.loss, params, fed1, "fedsgd"))(params, batch)
    p4, m4 = jax.jit(make_round_step(api.loss, params, fed4, "fedsgd"))(params, batch)
    for a, b_ in zip(jax.tree.leaves(unbox(p1)), jax.tree.leaves(unbox(p4))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-5)


def test_replicated_mode_local_iters():
    """I>1 with per-client replicas (paper-scale path) runs and differs from I=1."""
    cfg = get_smoke_config("qwen2_5_14b").replace(dtype="float32", num_layers=2)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    fed = FedConfig(num_clients=10, clients_per_round=2, local_iters=3, lr=0.05,
                    algorithm="fedsubavg")
    step = make_round_step(api.loss, params, fed, mode="replicated")
    k, i, b, s = 2, 3, 2, 16
    batch = {"tokens": jnp.ones((k, i, b, s), jnp.int32),
             "labels": jnp.ones((k, i, b, s), jnp.int32),
             "mask": jnp.ones((k, i, b, s), jnp.float32),
             "heat_vocab": jnp.full((cfg.vocab_size,), 5.0)}
    new_params, metrics = jax.jit(step)(params, batch)
    diff = jax.tree.leaves(tree_sub(unbox(new_params), unbox(params)))
    assert any(float(jnp.abs(d).max()) > 0 for d in diff)


def _max_intermediate_elems(fn, *args):
    """Largest intermediate array (in elements) anywhere in fn's jaxpr.

    Recurses into sub-jaxprs (pjit/scan/cond bodies) so vmapped per-client
    replica buffers inside the local-training scan are counted.
    """
    closed = jax.make_jaxpr(fn)(*args)
    best = 0

    def sub_jaxprs(val):
        if hasattr(val, "eqns"):
            yield val
        elif hasattr(val, "jaxpr"):
            yield from sub_jaxprs(val.jaxpr)
        elif isinstance(val, (tuple, list)):
            for v in val:
                yield from sub_jaxprs(v)

    def walk(jaxpr):
        nonlocal best
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                shape = getattr(getattr(v, "aval", None), "shape", None)
                if shape is not None:
                    best = max(best, int(np.prod(shape)) if shape else 1)
            for val in eqn.params.values():
                for sub in sub_jaxprs(val):
                    walk(sub)

    walk(closed.jaxpr)
    return best


def _lstm_replicated_fixture(v=256, e=8, k=3, i=2, b=2, s=6, seed=0):
    from repro.models.recsys import lstm_loss, make_lstm_params
    params = make_lstm_params(v, emb_dim=e, hidden=8, layers=1,
                              rng=jax.random.PRNGKey(1))
    fed = FedConfig(num_clients=16, clients_per_round=k, local_iters=i,
                    lr=0.1, algorithm="fedsubavg")
    rng = np.random.default_rng(seed)
    tokens = rng.integers(-1, v, (k, i, b, s)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens),
             "label": jnp.asarray(rng.integers(0, 2, (k, i, b)), jnp.int32),
             "heat_vocab": jnp.maximum(jnp.asarray(
                 rng.integers(0, 6, v), jnp.float32), 0)}
    return lstm_loss, params, fed, batch


def test_sparse_replicated_matches_replicated_multi_round():
    """ISSUE 3 acceptance: mode="sparse_replicated" reproduces
    mode="replicated" losses and params to 1e-5 over a multi-round run with
    the same RNG stream — the paper's I>1 protocol on submodel replicas."""
    loss_fn, params0, fed, _ = _lstm_replicated_fixture()

    def run(mode, rounds=4):
        params = params0
        step = jax.jit(make_round_step(loss_fn, params, fed, mode=mode))
        losses = []
        for r in range(rounds):
            _, _, _, batch = _lstm_replicated_fixture(seed=100 + r)
            params, m = step(params, batch)
            losses.append(float(m["loss"]))
        return params, losses

    p_rep, l_rep = run("replicated")
    p_sub, l_sub = run("sparse_replicated")
    np.testing.assert_allclose(l_sub, l_rep, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(unbox(p_rep)), jax.tree.leaves(unbox(p_sub))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_sparse_replicated_matches_replicated_lm():
    """Same parity on an LM (dense head leaves ride the dense branch, the
    embedding table rides the submodel gather), fedavg baseline included."""
    cfg = get_smoke_config("qwen2_5_14b").replace(dtype="float32", num_layers=1)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    k, i, b, s = 2, 2, 2, 12
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (k, i, b, s),
                                          0, cfg.vocab_size),
             "mask": jnp.ones((k, i, b, s), jnp.float32),
             "heat_vocab": jnp.maximum(
                 jax.random.randint(jax.random.PRNGKey(4), (cfg.vocab_size,),
                                    0, 8).astype(jnp.float32), 0)}
    for alg in ("fedsubavg", "fedavg"):
        fed = FedConfig(num_clients=10, clients_per_round=k, local_iters=i,
                        lr=0.05, algorithm=alg)
        correct = alg == "fedsubavg"
        p_rep, m_rep = jax.jit(make_round_step(
            api.loss, params, fed, mode="replicated", correct=correct))(params, batch)
        p_sub, m_sub = jax.jit(make_round_step(
            api.loss, params, fed, mode="sparse_replicated",
            correct=correct))(params, batch)
        np.testing.assert_allclose(float(m_sub["loss"]), float(m_rep["loss"]),
                                   rtol=1e-6)
        assert 0 < float(m_sub["density"]) <= 1
        for a, b_ in zip(jax.tree.leaves(unbox(p_rep)),
                         jax.tree.leaves(unbox(p_sub))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-5, atol=1e-6)


def test_sparse_replicated_replica_memory():
    """ISSUE 3 acceptance: per-client replica memory is O(K * capacity * D),
    not O(K * V * D) — asserted by shape inspection of every intermediate in
    the jitted round step's jaxpr. The dense-replica mode materialises the
    K*V*E stack; the submodel mode's largest array is the (V, E) table
    itself (the server's single copy)."""
    v, e, k = 4096, 8, 4
    from repro.models.recsys import lstm_loss, make_lstm_params
    params = make_lstm_params(v, emb_dim=e, hidden=8, layers=1,
                              rng=jax.random.PRNGKey(1))
    fed = FedConfig(num_clients=16, clients_per_round=k, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, v, (k, 2, 2, 6)), jnp.int32),
             "label": jnp.asarray(rng.integers(0, 2, (k, 2, 2)), jnp.int32),
             "heat_vocab": jnp.full((v,), 4.0)}
    m_rep = _max_intermediate_elems(
        make_round_step(lstm_loss, params, fed, mode="replicated"), params, batch)
    m_sub = _max_intermediate_elems(
        make_round_step(lstm_loss, params, fed, mode="sparse_replicated"),
        params, batch)
    assert m_rep >= k * v * e                 # the dense-replica memory wall
    assert m_sub <= 2 * v * e                 # submodel replicas: no K*V term
    assert m_sub < m_rep / (k - 1)


def test_sparse_replicated_requires_feature_table():
    """Models without an axis-0 feature table cannot gather submodels."""
    from repro.sharding.logical import Param
    params = {"w": Param(jnp.eye(4, dtype=jnp.float32), (None, None))}
    fed = FedConfig(num_clients=4, lr=0.1)
    with pytest.raises(ValueError, match="feature table"):
        make_round_step(lambda p, b: jnp.mean(p["w"].value ** 2), params, fed,
                        mode="sparse_replicated")


def test_weighted_composes_with_randomized_response(ds):
    """Regression: weighted=True must not silently bypass the randomized-
    response estimator with exact counts recomputed from raw client data —
    the weighting is composed with the noisy reported bits (App. D.4 + F)."""
    from repro.core.heat import estimate_heat_randomized_response

    cfg_kw = dict(heat_estimator="randomized_response", rr_flip_prob=0.2,
                  weighted=True)
    tr = _trainer(ds, "fedsubavg", rounds=1, **cfg_kw)

    # exact weighted counts (what the pre-fix code returned)
    w = ds.sample_counts.astype(np.float64)
    exact_w = np.zeros(ds.num_features)
    ind = np.zeros((ds.num_clients, ds.num_features), np.int64)
    for c in range(ds.num_clients):
        ids = ds.client_data[ds.feature_key][c].reshape(-1)
        u = np.unique(ids[ids >= 0])
        exact_w[u] += w[c]
        ind[c, u] = 1
    assert not np.allclose(tr.heat.counts, exact_w), \
        "weighted heat bypassed the randomized-response mechanism"

    # and it matches the weighted RR estimator run under the trainer's seed,
    # clamped into [1, W] (an estimate <= 0 must never zero a hot row's gate)
    want = estimate_heat_randomized_response(
        ind, 0.2, np.random.default_rng(tr.cfg.seed), weights=w)
    want = np.clip(want, 1.0, w.sum())
    np.testing.assert_allclose(tr.heat.counts, want)
    assert tr.heat.total == pytest.approx(w.sum())
    assert np.isfinite(tr.history[-1].train_loss)


def test_microbatch_split_keys_on_name_not_shape():
    """Regression: a genuine batch-size-3 entry with ndim >= 3 must split on
    axis 0 — the old shape-keyed rule routed it down the mrope axis-1 path."""
    from repro.federated.simulation import make_round_step
    from repro.sharding.logical import Param

    params = {"w": Param(jnp.eye(4, dtype=jnp.float32), (None, None))}

    def loss_fn(p, batch):
        x = batch["x"]                       # (B, S, 4) with B == 3
        y = jnp.einsum("bsd,de->bse", x, p["w"].value if hasattr(p["w"], "value")
                       else p["w"])
        return jnp.mean(y ** 2)

    fed1 = FedConfig(num_clients=4, lr=0.1, microbatches=1)
    fed3 = FedConfig(num_clients=4, lr=0.1, microbatches=3)
    # S = 5 is not divisible by nmb=3: the buggy axis-1 split asserts out
    batch = {"x": jnp.asarray(np.random.default_rng(0).normal(size=(3, 5, 4)),
                              jnp.float32),
             "heat_vocab": jnp.ones((4,), jnp.float32)}
    step1 = make_round_step(loss_fn, params, fed1, mode="fedsgd", correct=False)
    step3 = make_round_step(loss_fn, params, fed3, mode="fedsgd", correct=False)
    p1, m1 = jax.jit(step1)(params, batch)
    p3, m3 = jax.jit(step3)(params, batch)
    np.testing.assert_allclose(float(m3["loss"]), float(m1["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(unbox(p1)), jax.tree.leaves(unbox(p3))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_microbatch_mrope_still_splits_on_batch_axis():
    """The name-keyed rule preserves the mrope (3, B, S) handling."""
    cfg = get_smoke_config("qwen2_vl_7b").replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 4, 16
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                          cfg.vocab_size),
             "labels": jnp.ones((b, s), jnp.int32),
             "mask": jnp.ones((b, s), jnp.float32),
             "mrope_pos": pos,
             "patch_embeds": 0.01 * jnp.ones((b, cfg.num_patches, cfg.d_model),
                                             jnp.float32),
             "heat_vocab": jnp.ones((cfg.vocab_size,), jnp.float32)}
    fed1 = FedConfig(num_clients=10, lr=0.1, algorithm="fedsubavg",
                     microbatches=1)
    fed2 = FedConfig(num_clients=10, lr=0.1, algorithm="fedsubavg",
                     microbatches=2)
    p1, m1 = jax.jit(make_round_step(api.loss, params, fed1, "fedsgd"))(params, batch)
    p2, m2 = jax.jit(make_round_step(api.loss, params, fed2, "fedsgd"))(params, batch)
    for a, b_ in zip(jax.tree.leaves(unbox(p1)), jax.tree.leaves(unbox(p2))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-5)
