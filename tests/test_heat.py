"""Heat statistics + private estimation (paper §2, App. F).

Only the property test needs hypothesis; the seeded tests run everywhere so
the estimators keep coverage on hypothesis-free containers.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core.heat import (HeatStats, clamp_heat_estimate, client_indicator,
                             compute_heat_exact,
                             estimate_heat_randomized_response,
                             estimate_heat_secure_agg, heat_correction_factors)


def test_client_indicator_basic():
    v = client_indicator(np.array([0, 2, 2, 5, -1, 99]), 6)
    assert v.tolist() == [1, 0, 1, 0, 0, 1]


def test_exact_heat_counts_clients_not_occurrences():
    # one client using a feature many times still counts once
    c = compute_heat_exact([np.array([1, 1, 1]), np.array([1, 2])], 3)
    assert c.tolist() == [0.0, 2.0, 1.0]


def test_weighted_heat():
    c = compute_heat_exact([np.array([0]), np.array([0, 1])], 2, weights=[3.0, 5.0])
    assert c.tolist() == [8.0, 5.0]


def test_secure_agg_is_exact(rng):
    ind = (rng.random((12, 40)) < 0.3).astype(np.int64)
    est = estimate_heat_secure_agg(ind, rng)
    np.testing.assert_array_equal(est, ind.sum(axis=0))


def _rr_property(f):
    if HAVE_HYPOTHESIS:
        return settings(deadline=None, max_examples=20)(
            given(p=st.floats(0.01, 0.45), seed=st.integers(0, 1000))(f))

    def skipped():                                     # pragma: no cover
        pass

    return pytest.mark.skip(reason="property tests need hypothesis")(skipped)


@_rr_property
def test_randomized_response_unbiased(p, seed):
    # With many clients sharing the same indicator pattern, the estimator
    # should concentrate near the true counts (unbiasedness + LLN).
    rng = np.random.default_rng(seed)
    base = (rng.random((1, 50)) < 0.4).astype(np.int64)
    n = 4000
    ind = np.tile(base, (n, 1))
    est = estimate_heat_randomized_response(ind, p, rng)
    true = ind.sum(axis=0)
    # std of estimator ~ sqrt(n p (1-p)) / (1-2p)
    tol = 6 * np.sqrt(n * p * (1 - p)) / (1 - 2 * p)
    assert np.all(np.abs(est - true) < tol)


def test_correction_factors_zero_rows():
    f = heat_correction_factors(jnp.array([0.0, 1.0, 5.0]), 10.0)
    assert f[0] == 0.0 and f[1] == 10.0 and f[2] == 2.0


def test_heat_stats_dispersion():
    h = HeatStats(counts=np.array([0.0, 2.0, 100.0]), total=100.0)
    assert h.dispersion() == 50.0
    assert h.n_min == 2.0 and h.n_max == 100.0
    assert h.coverage() == pytest.approx(2 / 3)


def test_secure_agg_matches_reference_loop(rng):
    """Pin: the vectorised accumulation (each pair mask generated once) is
    bit-identical to the original per-client O(N^2) re-derivation loop."""
    modulus = 1 << 32

    def loop_version(indicators):
        n, m = indicators.shape
        masked = indicators.astype(np.uint64) % modulus
        acc = np.zeros((m,), dtype=np.uint64)
        for i in range(n):
            vec = masked[i].copy()
            for j in range(n):
                if j == i:
                    continue
                pair_rng = np.random.default_rng(
                    np.random.SeedSequence((min(i, j), max(i, j))))
                mask = pair_rng.integers(0, modulus, size=m, dtype=np.uint64)
                vec = (vec + mask) % modulus if i < j else (vec - mask) % modulus
            acc = (acc + vec) % modulus
        return (acc % modulus).astype(np.float64)

    ind = (rng.random((9, 23)) < 0.35).astype(np.int64)
    got = estimate_heat_secure_agg(ind)
    np.testing.assert_array_equal(got, loop_version(ind))
    np.testing.assert_array_equal(got, ind.sum(axis=0))


def test_secure_agg_rejects_non_pow2_modulus():
    """Regression: the unreduced uint64 accumulation is only congruent mod a
    divisor of 2**64 — a non-power-of-two modulus must be rejected rather
    than return silently wrong sums."""
    ind = np.ones((3, 5), np.int64)
    # 1 << 64 is a power of two but not uint64-representable (would raise a
    # confusing numpy OverflowError deep in the mask arithmetic)
    for bad in (10, 3, (1 << 32) - 1, 0, -8, 1 << 64):
        with pytest.raises(ValueError, match="power of two"):
            estimate_heat_secure_agg(ind, modulus=bad)
    # a pow2 ring smaller than the client count would wrap the true heat
    with pytest.raises(ValueError, match="client count"):
        estimate_heat_secure_agg(ind, modulus=2)
    # non-default powers of two still recover the exact heat
    est = estimate_heat_secure_agg(ind, modulus=1 << 20)
    np.testing.assert_array_equal(est, ind.sum(axis=0))


def test_secure_agg_honors_rng():
    """Regression (ISSUE 5 satellite): the ``rng`` argument used to be dead —
    assigned a default and never consulted, masks coming solely from the
    fixed pair SeedSequence. It now selects the mask stream: the per-client
    masked vectors (what the simulated server sees) change with the
    generator, reproduce for an equal seed, and the unmasked sum stays exact
    for every stream."""
    rng = np.random.default_rng(5)
    ind = (rng.random((6, 17)) < 0.4).astype(np.int64)
    true = ind.sum(axis=0)

    est_d, vecs_default = estimate_heat_secure_agg(ind, return_masked=True)
    est_a, vecs_a = estimate_heat_secure_agg(ind, np.random.default_rng(1),
                                             return_masked=True)
    est_a2, vecs_a2 = estimate_heat_secure_agg(ind, np.random.default_rng(1),
                                               return_masked=True)
    est_b, vecs_b = estimate_heat_secure_agg(ind, np.random.default_rng(2),
                                             return_masked=True)
    # exact under every mask stream (the masks cancel)
    for est in (est_d, est_a, est_b):
        np.testing.assert_array_equal(est, true)
    # the rng is honored: distinct generators -> distinct masked vectors ...
    assert not np.array_equal(vecs_a, vecs_default)
    assert not np.array_equal(vecs_a, vecs_b)
    # ... and an equal seed reproduces the stream bit-identically
    np.testing.assert_array_equal(vecs_a, vecs_a2)


def test_secure_agg_default_stream_pinned():
    """The documented rng=None behavior stays bit-identical to the legacy
    SeedSequence((i, j)) pair masks (companion to the reference-loop pin)."""
    ind = np.eye(4, 9, dtype=np.int64)
    modulus = 1 << 32
    _, vecs = estimate_heat_secure_agg(ind, return_masked=True)
    want = ind.astype(np.uint64) % modulus
    for i in range(4):
        for j in range(i + 1, 4):
            pair_rng = np.random.default_rng(np.random.SeedSequence((i, j)))
            mask = pair_rng.integers(0, modulus, size=9, dtype=np.uint64)
            want[i] = (want[i] + mask) % modulus
            want[j] = (want[j] - mask) % modulus
    np.testing.assert_array_equal(vecs, want)


def test_clamped_estimate_never_zeroes_hot_rows():
    """Regression (ISSUE 5 satellite): a noisy randomized-response estimate
    <= 0 for a genuinely hot feature used to reach the counts > 0 /
    h > 0 gates and zero that row's update in BOTH correction twins. The
    clamp into [min_count, total] keeps every row's factor positive."""
    from repro.sparse.aggregate import heat_factor_at

    total = 10.0
    raw_est = np.array([-2.3, 0.0, 0.4, 5.0])     # rows 0-2: hot, bad draws

    # the pre-fix pipeline (clip at 0) drops rows 0 and 1 entirely
    pre_fix = np.clip(raw_est, 0, total)
    f_dense_pre = np.asarray(heat_correction_factors(pre_fix, total))
    assert f_dense_pre[0] == 0.0 and f_dense_pre[1] == 0.0

    counts = clamp_heat_estimate(raw_est, total)
    np.testing.assert_allclose(counts, [1.0, 1.0, 1.0, 5.0])
    # dense twin
    f_dense = np.asarray(heat_correction_factors(counts, total))
    assert np.all(f_dense > 0)
    np.testing.assert_allclose(f_dense, [10.0, 10.0, 10.0, 2.0])
    # gathered twin (ids index the same counts; -1 stays the pad zero)
    ids = jnp.asarray([0, 1, 2, 3, -1], jnp.int32)
    f_gather = np.asarray(heat_factor_at(jnp.asarray(counts, jnp.float32),
                                         ids, total))
    np.testing.assert_allclose(f_gather[:4], f_dense)
    assert f_gather[4] == 0.0


def test_trainer_randomized_response_counts_are_clamped():
    """End-to-end: the trainer's RR heat never carries a zero (pre-fix the
    lower clip bound was 0, so unlucky hot features were droppable)."""
    import functools

    from repro.configs import FedConfig
    from repro.data import make_movielens_like
    from repro.federated import FederatedTrainer
    from repro.models.recsys import lr_loss, make_lr_params

    ds = make_movielens_like(num_clients=12, num_items=30, mean_samples=4)
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=4,
                    heat_estimator="randomized_response", rr_flip_prob=0.45)
    tr = FederatedTrainer(ds, functools.partial(make_lr_params,
                                                ds.num_features),
                          lr_loss, cfg)
    # p=0.45 makes negative raw estimates near-certain at N=12
    assert tr.heat.counts.min() >= 1.0
    assert tr.heat.counts.max() <= tr.heat.total


def test_randomized_response_weighted_unbiased():
    """Weighted RR (App. D.4 composed with App. F): unbiased for the
    weighted heat, and reduces to the unweighted estimator at w == 1."""
    rng = np.random.default_rng(7)
    base = (rng.random((1, 40)) < 0.4).astype(np.int64)
    n = 4000
    ind = np.tile(base, (n, 1))
    w = rng.integers(1, 5, n).astype(np.float64)
    est = estimate_heat_randomized_response(
        ind, 0.2, np.random.default_rng(0), weights=w)
    true = (w[:, None] * ind).sum(axis=0)
    tol = 6 * np.sqrt((w ** 2).sum() * 0.2 * 0.8) / 0.6
    assert np.all(np.abs(est - true) < tol)
    # w == 1 reproduces the unweighted estimator exactly (same rng stream)
    un = estimate_heat_randomized_response(ind[:50], 0.1,
                                           np.random.default_rng(3))
    wt = estimate_heat_randomized_response(ind[:50], 0.1,
                                           np.random.default_rng(3),
                                           weights=np.ones(50))
    np.testing.assert_allclose(wt, un)
