"""Heat statistics + private estimation (paper §2, App. F)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.heat import (HeatStats, client_indicator, compute_heat_exact,
                             estimate_heat_randomized_response,
                             estimate_heat_secure_agg, heat_correction_factors)


def test_client_indicator_basic():
    v = client_indicator(np.array([0, 2, 2, 5, -1, 99]), 6)
    assert v.tolist() == [1, 0, 1, 0, 0, 1]


def test_exact_heat_counts_clients_not_occurrences():
    # one client using a feature many times still counts once
    c = compute_heat_exact([np.array([1, 1, 1]), np.array([1, 2])], 3)
    assert c.tolist() == [0.0, 2.0, 1.0]


def test_weighted_heat():
    c = compute_heat_exact([np.array([0]), np.array([0, 1])], 2, weights=[3.0, 5.0])
    assert c.tolist() == [8.0, 5.0]


def test_secure_agg_is_exact(rng):
    ind = (rng.random((12, 40)) < 0.3).astype(np.int64)
    est = estimate_heat_secure_agg(ind, rng)
    np.testing.assert_array_equal(est, ind.sum(axis=0))


@settings(deadline=None, max_examples=20)
@given(p=st.floats(0.01, 0.45), seed=st.integers(0, 1000))
def test_randomized_response_unbiased(p, seed):
    # With many clients sharing the same indicator pattern, the estimator
    # should concentrate near the true counts (unbiasedness + LLN).
    rng = np.random.default_rng(seed)
    base = (rng.random((1, 50)) < 0.4).astype(np.int64)
    n = 4000
    ind = np.tile(base, (n, 1))
    est = estimate_heat_randomized_response(ind, p, rng)
    true = ind.sum(axis=0)
    # std of estimator ~ sqrt(n p (1-p)) / (1-2p)
    tol = 6 * np.sqrt(n * p * (1 - p)) / (1 - 2 * p)
    assert np.all(np.abs(est - true) < tol)


def test_correction_factors_zero_rows():
    f = heat_correction_factors(jnp.array([0.0, 1.0, 5.0]), 10.0)
    assert f[0] == 0.0 and f[1] == 10.0 and f[2] == 2.0


def test_heat_stats_dispersion():
    h = HeatStats(counts=np.array([0.0, 2.0, 100.0]), total=100.0)
    assert h.dispersion() == 50.0
    assert h.n_min == 2.0 and h.n_max == 100.0
    assert h.coverage() == pytest.approx(2 / 3)
