"""Comm & memory oracle tests: contracts on real compiled sharded steps.

Every test here lowers a CohortSharding round step on the host mesh and
checks the hlo_audit layer end-to-end: the collective inventory balances
against ``round_collective_budget``, peak live bytes stay under the
analytic memory budget, and the comm-accounting plane's own byte pricing
matches what the compiled HLO moves. Planted-violation tests prove the
gates FAIL (naming the offender) when a resharding or a dense-replica
regression is forced in.

Contract checks need a real multi-device mesh; run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI gate does).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_audit import (collective_contract, comm_drift,
                                      lower_round_step, main,
                                      memory_budget, memory_contract)
from repro.configs.base import FedConfig
from repro.federated.plan import CohortSharding, resolve_plan
from repro.launch.mesh import make_cohort_mesh
from repro.models.recsys import lstm_loss, make_lstm_params

NDEV = len(jax.devices())

pytestmark = pytest.mark.skipif(
    NDEV < 2, reason="hlo_audit contracts need a multi-device mesh "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

V, E = 128, 6


def _params(vocab=V, emb=E):
    return make_lstm_params(vocab, emb_dim=emb, hidden=8, layers=1,
                            rng=jax.random.PRNGKey(1))


def _cohort_batch(vocab=V, k=3, i=2, b=2, s=6, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(-1, vocab, (k, i, b, s)),
                              jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, (k, i, b)), jnp.int32),
        "heat_vocab": jnp.asarray(rng.integers(0, 6, vocab), jnp.float32)}


def _flat_batch(vocab=V, b=8, s=8, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, b), jnp.int32),
        "heat_vocab": jnp.asarray(rng.integers(0, 6, vocab), jnp.float32)}


def _sharded_plan(mode, fed, combine):
    return dataclasses.replace(
        resolve_plan(mode, fed, correct=(fed.algorithm == "fedsubavg")),
        sharding=CohortSharding(make_cohort_mesh(), combine=combine))


_FED = FedConfig(num_clients=16, clients_per_round=3, local_iters=2,
                 lr=0.1, algorithm="fedsubavg")


# ---------------------------------------------------------------------------
# the contract matrix: both sharded sparse plans, both combines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,combine", [
    ("sparse", "psum"), ("sparse", "union"),
    ("sparse_replicated", "psum"), ("sparse_replicated", "union"),
])
def test_contracts_hold_on_sharded_plans(mode, combine):
    params = _params()
    plan = _sharded_plan(mode, _FED, combine)
    batch = _flat_batch() if mode == "sparse" else _cohort_batch()
    compiled = lower_round_step(plan, lstm_loss, params, _FED, batch)

    con = collective_contract(plan, lstm_loss, params, _FED, batch,
                              compiled=compiled)
    assert con.ok, con.failures
    # the verified-byte-exact budget: every measured kind was predicted and
    # every predicted nonzero kind shows up in the compiled module
    assert set(con.measured_by_op) <= set(con.budget_by_op)
    for op, b in con.budget_by_op.items():
        assert con.measured_by_op.get(op, 0) > 0 or b == 0
    # every collective attributed to the cohort mesh axis, none unknown
    assert set(con.by_axis) == {"data"}

    mem = memory_contract(plan, lstm_loss, params, _FED, batch,
                          compiled=compiled)
    assert mem.ok, mem.failures
    assert 0 < mem.measured_bytes <= mem.budget_bytes

    drift = comm_drift(plan, lstm_loss, params, _FED, batch,
                       compiled=compiled)
    assert drift.ok, drift.failures
    # drift really compared something: the combine's dominant op is priced
    dominant = "all-reduce" if combine == "psum" else "all-gather"
    assert drift.predicted_by_op[dominant] > 0
    assert drift.measured_by_op[dominant] > 0


# ---------------------------------------------------------------------------
# planted violations: the gates must FAIL, naming the offender
# ---------------------------------------------------------------------------


def test_planted_resharding_fails_collective_contract():
    """Shard the (V, E) table over the mesh in a psum-combine plan: XLA must
    all-gather it back, and that unpredicted kind is a named failure."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    params = _params()
    plan = _sharded_plan("sparse_replicated", _FED, "psum")
    batch = _cohort_batch()
    mesh = plan.sharding.mesh
    repl = NamedSharding(mesh, P())

    def leaf_sharding(leaf):
        if getattr(leaf, "ndim", 0) == 2 and leaf.shape[0] == V:
            return NamedSharding(mesh, P("data"))
        return repl

    from repro.core.algorithms import ServerState
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    in_shardings = (jax.tree.map(leaf_sharding, state),
                    jax.tree.map(lambda _: repl, batch))
    con = collective_contract(plan, lstm_loss, params, _FED, batch,
                              in_shardings=in_shardings)
    assert not con.ok
    assert any("unbudgeted collective kind 'all-gather'" in f
               for f in con.failures), con.failures


def test_planted_dense_replicas_fail_memory_contract():
    """A dense-replica plan (each of K clients holds the full table) must
    blow through the sparse plan's analytic budget at scale, and the
    failure names the largest budget term."""
    vocab, emb, k = 16384, 8, 40
    params = _params(vocab, emb)
    fed = FedConfig(num_clients=64, clients_per_round=k, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    batch = _cohort_batch(vocab, k=k)
    sparse_plan = _sharded_plan("sparse_replicated", fed, "union")

    lean = memory_contract(sparse_plan, lstm_loss, params, fed, batch)
    assert lean.ok, lean.failures

    dense_plan = _sharded_plan("replicated", fed, "union")
    budget = memory_budget(sparse_plan, params, fed, batch)
    fat = memory_contract(dense_plan, lstm_loss, params, fed, batch,
                          budget=budget)
    assert not fat.ok
    assert fat.measured_bytes > lean.measured_bytes
    assert any("peak live bytes" in f and "largest budget term" in f
               for f in fat.failures), fat.failures


# ---------------------------------------------------------------------------
# CLI gate
# ---------------------------------------------------------------------------


def test_cli_matrix_green_and_json_report(tmp_path, capsys):
    out = tmp_path / "contract-report.json"
    rc = main(["--json", str(out), "--vocab", "128", "--emb", "6"])
    assert rc == 0
    import json
    report = json.loads(out.read_text())
    assert report["ok"] is True
    assert report["device_count"] == NDEV
    assert len(report["results"]) == 8
    for r in report["results"]:
        assert r["ok"], (r["mode"], r["algorithm"], r["combine"])
        for section in ("contract", "memory", "drift"):
            assert r[section]["failures"] == []
    text = capsys.readouterr().out
    assert "all 8 plan contracts hold" in text
