"""Direct unit tests for repro.launch.hlo on synthetic HLO fixtures.

The dryrun/roofline layers exercise analyze_hlo end-to-end on real compiled
modules; these tests pin the PARSER contract itself — result-shape-only byte
attribution, async -start/-done pairing, replica_groups grammar, loop
multipliers, and call-graph dedup — on hand-written HLO where every byte is
known in advance.
"""
import jax
import pytest

from repro.launch.hlo import (_parse_replica_groups, _shape_bytes,
                              _shape_bytes_list, analyze_hlo,
                              mesh_axis_groups)

NDEV = len(jax.devices())


def _mod(*comps):
    return "HloModule synthetic\n\n" + "\n\n".join(comps)


# ---------------------------------------------------------------------------
# shape literals
# ---------------------------------------------------------------------------


def test_shape_bytes_list_order_and_dtypes():
    text = "(f32[4]{0}, s32[2,3]{1,0}, pred[8])"
    assert _shape_bytes_list(text) == [16, 24, 8]
    assert _shape_bytes(text) == 48


def test_shape_bytes_ignores_layout_and_unknown_dtypes():
    # the layout suffix {1,0} and non-dtype brackets must not parse as shapes
    assert _shape_bytes_list("f32[2,2]{1,0}") == [16]
    assert _shape_bytes_list("foo[4]") == []
    assert _shape_bytes("f32[]") == 4          # scalar


# ---------------------------------------------------------------------------
# replica_groups grammar
# ---------------------------------------------------------------------------


def test_replica_groups_explicit():
    assert _parse_replica_groups(
        "x, replica_groups={{0,1},{2,3}}, dims") == ((0, 1), (2, 3))


def test_replica_groups_empty_is_all_devices():
    assert _parse_replica_groups("x, replica_groups={}, y") == ()


def test_replica_groups_iota():
    assert _parse_replica_groups("replica_groups=[2,4]<=[8]") == (
        (0, 1, 2, 3), (4, 5, 6, 7))


def test_replica_groups_transposed_iota_unattributed():
    # a transposed iota interleaves devices; parsing it as consecutive
    # groups would attribute the op to the WRONG axis — None is correct
    assert _parse_replica_groups("replica_groups=[4,2]<=[8]T(1,0)") is None


def test_replica_groups_absent():
    assert _parse_replica_groups("no groups here") is None


# ---------------------------------------------------------------------------
# byte attribution: result only, tuples, async pairs
# ---------------------------------------------------------------------------


def test_result_shape_only_operands_excluded():
    text = _mod(
        "ENTRY %main (x: f32[4]) -> f32[4,8] {\n"
        "  ROOT %ag = f32[4,8]{1,0} all-gather(f32[1,2,4]{2,1,0} %x), "
        "replica_groups={}, dimensions={0}\n"
        "}")
    rep = analyze_hlo(text)
    assert rep.by_op() == {"all-gather": 4 * 8 * 4}
    (op,) = rep.collectives
    assert op.name == "ag" and op.replica_groups == ()


def test_variadic_all_reduce_sums_tuple_elements():
    text = _mod(
        "ENTRY %main (a: f32[4], b: f32[8]) -> (f32[4], f32[8]) {\n"
        "  ROOT %ar = (f32[4]{0}, f32[8]{0}) all-reduce(f32[4]{0} %a, "
        "f32[8]{0} %b), replica_groups={}, to_apply=%add\n"
        "}")
    assert analyze_hlo(text).by_op() == {"all-reduce": 16 + 32}


def test_async_pair_counted_once_groups_from_start():
    # -start result tuple carries (operand, result); bytes must come from
    # the -done result, and replica_groups from the -start line
    text = _mod(
        "ENTRY %main (x: f32[4]) -> f32[4,2] {\n"
        "  %ags = (f32[4]{0}, f32[4,2]{1,0}) all-gather-start(f32[4]{0} %x), "
        "replica_groups={{0,1}}, dimensions={0}\n"
        "  ROOT %agd = f32[4,2]{1,0} all-gather-done((f32[4]{0}, "
        "f32[4,2]{1,0}) %ags)\n"
        "}")
    rep = analyze_hlo(text)
    assert rep.by_op() == {"all-gather": 4 * 2 * 4}
    (op,) = rep.collectives
    assert op.replica_groups == ((0, 1),)


def test_orphan_start_counts_result_half_only():
    # no -done in reach: fall back to the start's own result tuple, second
    # element (the first is the operand buffer of gather-like starts)
    text = _mod(
        "ENTRY %main (x: f32[4]) -> f32[4,2] {\n"
        "  %ags = (f32[4]{0}, f32[4,2]{1,0}) all-gather-start(f32[4]{0} %x), "
        "replica_groups={}, dimensions={0}\n"
        "}")
    assert analyze_hlo(text).by_op() == {"all-gather": 4 * 2 * 4}


def test_lhs_collective_name_is_not_a_use_site():
    # an lhs like %all-gather.1 must not count as a second collective
    text = _mod(
        "ENTRY %main (x: f32[4]) -> f32[2,4] {\n"
        "  ROOT %all-gather.1 = f32[2,4]{1,0} all-gather(f32[4]{0} %x), "
        "replica_groups={}, dimensions={0}\n"
        "}")
    rep = analyze_hlo(text)
    assert len(rep.collectives) == 1
    assert rep.by_op() == {"all-gather": 2 * 4 * 4}


# ---------------------------------------------------------------------------
# loop multipliers + call graph
# ---------------------------------------------------------------------------


def test_known_trip_count_multiplies_body():
    text = _mod(
        "%body (p: (f32[8], s32[])) -> (f32[8], s32[]) {\n"
        "  %ar = f32[8]{0} all-reduce(f32[8]{0} %v), replica_groups={}\n"
        "}",
        "ENTRY %main (x: f32[8]) -> f32[8] {\n"
        "  %w = (f32[8], s32[]) while((f32[8], s32[]) %init), "
        "condition=%cond, body=%body, "
        'backend_config={"known_trip_count":{"n":"5"}}\n'
        "}")
    rep = analyze_hlo(text)
    assert rep.by_op() == {"all-reduce": 32 * 5}
    assert rep.unresolved_loops == 0
    assert all(c.resolved for c in rep.collectives)


def test_unresolved_loop_flagged_multiplier_one():
    text = _mod(
        "%body (p: (f32[8], s32[])) -> (f32[8], s32[]) {\n"
        "  %ar = f32[8]{0} all-reduce(f32[8]{0} %v), replica_groups={}\n"
        "}",
        "ENTRY %main (x: f32[8]) -> f32[8] {\n"
        "  %w = (f32[8], s32[]) while((f32[8], s32[]) %init), "
        "condition=%cond, body=%body\n"
        "}")
    rep = analyze_hlo(text)
    assert rep.unresolved_loops == 1
    (op,) = rep.collectives
    assert op.multiplier == 1 and not op.resolved
    assert rep.by_op() == {"all-reduce": 32}


def test_branch_computations_walked():
    text = _mod(
        "%true_b (p: f32[4]) -> f32[4] {\n"
        "  %ar = f32[4]{0} all-reduce(f32[4]{0} %p), replica_groups={}\n"
        "}",
        "%false_b (p: f32[4]) -> f32[2,4] {\n"
        "  %ag = f32[2,4]{1,0} all-gather(f32[4]{0} %p), replica_groups={}, "
        "dimensions={0}\n"
        "}",
        "ENTRY %main (i: s32[], x: f32[4]) -> f32[4] {\n"
        "  ROOT %c = f32[4]{0} conditional(s32[] %i, f32[4] %x, f32[4] %x), "
        "branch_computations={%true_b, %false_b}\n"
        "}")
    assert analyze_hlo(text).by_op() == {"all-reduce": 16, "all-gather": 32}


def test_rewalk_with_larger_multiplier_replaces_stale_entries():
    # %inner is reached twice: directly (x1) and from a counted loop (x3);
    # the larger multiplier must REPLACE the direct walk, not add to it
    text = _mod(
        "%inner (p: f32[4]) -> f32[4] {\n"
        "  %ar = f32[4]{0} all-reduce(f32[4]{0} %p), replica_groups={}\n"
        "}",
        "%body (p: (f32[4], s32[])) -> (f32[4], s32[]) {\n"
        "  %c = f32[4]{0} fusion(f32[4]{0} %p), kind=kLoop, calls=%inner\n"
        "}",
        "ENTRY %main (x: f32[4]) -> f32[4] {\n"
        "  %direct = f32[4]{0} fusion(f32[4]{0} %x), kind=kLoop, "
        "calls=%inner\n"
        "  %w = (f32[4], s32[]) while((f32[4], s32[]) %init), "
        "condition=%cond, body=%body, "
        'backend_config={"known_trip_count":{"n":"3"}}\n'
        "}")
    rep = analyze_hlo(text)
    ars = [c for c in rep.collectives if c.op == "all-reduce"]
    assert len(ars) == 1
    assert ars[0].multiplier == 3
    assert rep.by_op() == {"all-reduce": 16 * 3}


# ---------------------------------------------------------------------------
# mesh-axis attribution
# ---------------------------------------------------------------------------


def test_attribute_axes_explicit_empty_and_none():
    text = _mod(
        "ENTRY %main (x: f32[4]) -> f32[4] {\n"
        "  %a = f32[4]{0} all-reduce(f32[4]{0} %x), "
        "replica_groups={{0,1},{2,3}}\n"
        "  %b = f32[4]{0} all-reduce(f32[4]{0} %a), replica_groups={}\n"
        "  %c = f32[4]{0} all-reduce(f32[4]{0} %b), "
        "replica_groups=[2,2]<=[4]T(1,0)\n"
        "}")
    rep = analyze_hlo(text)
    rep.attribute_axes({"x": ((0, 1), (2, 3)), "data": ((0, 1, 2, 3),)})
    by_name = {c.name: c.mesh_axis for c in rep.collectives}
    assert by_name == {"a": "x",        # explicit groups match axis "x"
                       "b": "data",     # {} matches the single-group axis
                       "c": None}       # transposed iota stays unattributed
    assert rep.by_axis() == {"x": 16, "data": 16, "?": 16}


def test_mesh_axis_groups_1d():
    mesh = jax.sharding.Mesh(jax.devices(), ("data",))
    assert mesh_axis_groups(mesh) == {"data": (tuple(range(NDEV)),)}


@pytest.mark.skipif(NDEV < 2 or NDEV % 2, reason="needs an even device count")
def test_mesh_axis_groups_2d():
    import numpy as np

    devs = np.asarray(jax.devices()).reshape(2, NDEV // 2)
    groups = mesh_axis_groups(jax.sharding.Mesh(devs, ("a", "b")))
    # axis "a" groups pair device ids stride NDEV//2 apart; axis "b" groups
    # are the contiguous rows
    assert groups["b"] == tuple(
        tuple(range(i * (NDEV // 2), (i + 1) * (NDEV // 2)))
        for i in range(2))
    assert groups["a"] == tuple(
        (i, i + NDEV // 2) for i in range(NDEV // 2))
