"""Kernel contract plane: the static Pallas VMEM/race/cost auditor.

Positive direction: every registered in-repo kernel passes all three
contracts and the registry covers every ``pallas_call`` site. Negative
direction: planted contract breakers — a carried-accumulator grid dim
declared ``"parallel"`` and an over-budget BlockSpec — must fail with their
named diagnostics, and a planted guard that under-reports its footprint or
mispredicts its block picks must be caught as drift. The cost model is
pinned on the one number the whole plane exists to expose: ``union_segsum``
re-streams the ids/rows once per vocab block (restream = nv).
"""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import kernel_audit as ka
from repro.kernels.heat_scatter import _tpu_compiler_params
from repro.kernels.introspect import REGISTRY, GuardReport

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# positive: the in-repo kernels hold their contracts
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def reports():
    return {r.name: r for r in ka.audit_all()}


def test_all_registered_kernels_pass(reports):
    assert set(reports) == {"union_segsum", "rowsparse_scatter",
                            "flash_attention", "flash_decode"}
    for name, rep in reports.items():
        assert rep.ok, (name, rep.failures, rep.vmem.failures,
                        rep.race.failures)


def test_registry_covers_every_pallas_call_site():
    assert ka.registry_coverage() == []


def test_carried_dims_match_declared_semantics(reports):
    """The race detector recovers each kernel's true carried dims."""
    assert reports["union_segsum"].race.required == [0, 1]
    assert reports["rowsparse_scatter"].race.required == [1]
    assert reports["flash_attention"].race.required == [2]
    assert reports["flash_decode"].race.required == [1]


def test_union_segsum_restream_priced(reports):
    """ids/rows are re-fetched once per vocab block: restream = nv."""
    rep = reports["union_segsum"]
    nv = rep.grid[0]
    assert nv > 1
    per_op = rep.cost.per_operand
    assert max(op["restream"] for op in per_op.values()) == float(nv)
    # the payload stream (ids: (T,) i32 and rows: (T, D) f32) is what
    # restreams, not the vocab-partitioned heat
    restreamed = [op for op in per_op.values()
                  if op["kind"] == "input" and op["restream"] == float(nv)]
    assert len(restreamed) >= 2
    assert rep.cost.bytes_touched > 0 and rep.cost.flops > 0
    assert rep.cost.hbm_seconds > 0 and rep.cost.compute_seconds > 0


def test_vmem_guard_matches_structural(reports):
    """Guard >= structural footprint and block predictions match captures."""
    for name, rep in reports.items():
        assert rep.vmem.guard_bytes is not None
        assert rep.vmem.guard_bytes >= rep.vmem.structural_bytes, name
        assert rep.vmem.structural_bytes <= rep.vmem.budget_bytes, name


# ---------------------------------------------------------------------------
# the attention guards: fits_vmem must track the wrapper's block picks
# ---------------------------------------------------------------------------


def test_flash_attention_guard_tracks_block_picks():
    fa = sys.modules["repro.kernels.flash_attention"]
    # the clamp the wrapper applies is the clamp the guard prices
    assert fa._block_sizes(256, 256, 512, 512) == (256, 256)
    assert fa._block_sizes(2048, 2048, 512, 512) == (512, 512)
    assert fa._block_sizes(None, None, 512, 512) == (512, 512)
    assert fa.fits_vmem(128, sq=2048, sk=2048)
    # blowing up the k/v tiles must trip the budget
    assert not fa.fits_vmem(256, sq=1 << 16, sk=1 << 16,
                            blk_q=4096, blk_k=4096)
    # footprint is monotone in the clamped block sizes
    assert (fa.vmem_footprint(128, sq=256, sk=256)
            < fa.vmem_footprint(128, sq=2048, sk=2048))


def test_flash_decode_guard_tracks_block_picks():
    fd = sys.modules["repro.kernels.flash_decode"]
    assert fd._block_sizes(512, 1024) == 512
    assert fd._block_sizes(4096, 1024) == 1024
    assert fd._block_sizes(None, 1024) == 1024
    assert fd.fits_vmem(128, s=4096)
    assert not fd.fits_vmem(1024, s=1 << 16, blk_s=8192)
    assert (fd.vmem_footprint(128, s=512)
            < fd.vmem_footprint(128, s=4096))


# ---------------------------------------------------------------------------
# negative: planted contract breakers fail with named diagnostics
# ---------------------------------------------------------------------------


def _planted_race(semantics):
    """Grid (8,): scratch accumulator reset at i==0, accumulated every
    step, flushed at i==7 — grid dim 0 carries cross-program state."""
    n = 8

    def kernel(x_ref, o_ref, acc_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += x_ref[...]

        @pl.when(i == n - 1)
        def _flush():
            o_ref[...] = acc_ref[...]

    def fn(x):
        return pl.pallas_call(
            kernel,
            grid=(n,),
            in_specs=[pl.BlockSpec((1, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, 128), jnp.float32),
            scratch_shapes=[pltpu.VMEM((1, 128), jnp.float32)],
            compiler_params=_tpu_compiler_params(semantics=semantics),
        )(x)

    return fn, (jax.ShapeDtypeStruct((n, 128), jnp.float32),)


def test_planted_parallel_carry_fails_race_contract():
    fn, args = _planted_race(("parallel",))
    (cap,) = ka.capture_pallas_calls(fn, *args)
    rep = ka.race_contract(cap, kernel="planted")
    assert not rep.ok
    assert rep.required == [0]
    assert any("[megacore-race]" in f and "'parallel'" in f
               and "grid dim 0" in f for f in rep.failures), rep.failures


def test_planted_carry_passes_when_declared_arbitrary():
    fn, args = _planted_race(("arbitrary",))
    (cap,) = ka.capture_pallas_calls(fn, *args)
    assert ka.race_contract(cap, kernel="planted").ok


def _planted_fat():
    """(2048, 1024) f32 blocks, double-buffered in and out: 32 MiB."""

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fn(x):
        return pl.pallas_call(
            kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((2048, 1024), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((2048, 1024), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((8192, 1024), jnp.float32),
            compiler_params=_tpu_compiler_params(semantics=("arbitrary",)),
        )(x)

    return fn, (jax.ShapeDtypeStruct((8192, 1024), jnp.float32),)


def test_planted_overbudget_blockspec_fails_vmem_contract():
    fn, args = _planted_fat()
    (cap,) = ka.capture_pallas_calls(fn, *args)
    rep = ka.vmem_contract(cap, kernel="fat", budget=12 * 1024 * 1024)
    assert not rep.ok
    assert any("[vmem-budget]" in f and "exceeds" in f
               for f in rep.failures), rep.failures
    assert rep.structural_bytes == 2 * 2 * 2048 * 1024 * 4


def test_planted_guard_drift_is_caught():
    """A guard that lies about the kernel is drift, not a pass."""
    entry = next(e for e in REGISTRY if e.name == "union_segsum")

    # under-reporting guard: claims fewer bytes than the capture shows
    lying = dataclasses.replace(
        entry, guard=lambda: GuardReport(fits=True, footprint=1, blocks={}))
    rep = ka.audit_kernel(lying)
    assert any("[vmem-guard-underestimate]" in f
               for f in rep.vmem.failures), rep.vmem.failures

    # verdict drift: guard says the kernel does not fit although it does
    honest = entry.guard()
    pessimist = dataclasses.replace(
        entry, guard=lambda: dataclasses.replace(honest, fits=False))
    rep = ka.audit_kernel(pessimist)
    assert any("[vmem-guard-drift]" in f
               for f in rep.vmem.failures), rep.vmem.failures

    # block-pick drift: guard predicts a block shape the kernel never picks
    blocks = dict(honest.blocks)
    idx, shape = blocks["ids"]
    blocks["ids"] = (idx, (shape[0] * 2,))
    mispredict = dataclasses.replace(
        entry, guard=lambda: dataclasses.replace(honest, blocks=blocks))
    rep = ka.audit_kernel(mispredict)
    assert any("[block-pick-drift]" in f
               for f in rep.vmem.failures), rep.vmem.failures


def test_tiny_budget_fails_registered_kernel():
    entry = next(e for e in REGISTRY if e.name == "flash_decode")
    rep = ka.audit_kernel(entry, budget=1024)
    assert not rep.ok
    assert any("[vmem-budget]" in f for f in rep.vmem.failures)


# ---------------------------------------------------------------------------
# CLI: the CI gate
# ---------------------------------------------------------------------------


def test_cli_json_report(tmp_path):
    out = tmp_path / "kernel-audit.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.kernel_audit",
         "--json", str(out)],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    rep = json.loads(out.read_text())
    assert rep["ok"] is True
    assert rep["coverage_failures"] == []
    names = [k["name"] for k in rep["kernels"]]
    assert names == ["union_segsum", "rowsparse_scatter",
                     "flash_attention", "flash_decode"]
    for k in rep["kernels"]:
        assert k["ok"] is True
        assert {"vmem", "race", "cost"} <= set(k)
        assert k["vmem"]["structural_bytes"] <= k["vmem"]["budget_bytes"]
        assert k["race"]["dimension_semantics"] is not None
        assert k["cost"]["bytes_touched"] > 0
