"""Per-kernel oracle sweeps: shapes x dtypes against repro.kernels.ref."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# heat_scatter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,d,v,v_blk,t_blk", [
    (256, 8, 64, 16, 64),
    (1024, 32, 128, 128, 256),
    (512, 16, 512, 512, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_heat_scatter_sweep(rng, t, d, v, v_blk, t_blk, dtype):
    ids = jnp.asarray(rng.integers(-1, v, t), jnp.int32)
    grads = jnp.asarray(rng.normal(0, 1, (t, d))).astype(dtype)
    heat = jnp.asarray(rng.integers(0, 9, v), jnp.float32)
    out = ops.heat_scatter(ids, grads, heat, 100.0, v, v_blk=v_blk, t_blk=t_blk)
    want = ref.heat_scatter_ref(ids, grads, heat, 100.0, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-2, atol=1e-2)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 10_000), v=st.sampled_from([32, 64, 96]),
       t=st.sampled_from([64, 128]))
def test_heat_scatter_property(seed, v, t):
    """Scatter-sum + scale == dense one-hot matmul, any shape combo."""
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, v, t), jnp.int32)
    grads = jnp.asarray(rng.normal(0, 1, (t, 8)), jnp.float32)
    heat = jnp.asarray(rng.integers(1, 5, v), jnp.float32)
    out = ops.heat_scatter(ids, grads, heat, float(v), v, v_blk=32, t_blk=32)
    onehot = jax.nn.one_hot(ids, v, dtype=jnp.float32).T
    want = (onehot @ grads) * (v / heat)[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,h,kv,hd,blk", [
    (1, 128, 4, 4, 32, 64),     # MHA
    (2, 256, 8, 2, 16, 64),     # GQA 4x
    (1, 192, 6, 3, 64, 64),     # ragged-ish heads
])
@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, b, s, h, kv, hd, blk, window, dtype):
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd))).astype(dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd))).astype(dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd))).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window, blk_q=blk, blk_k=blk)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               **_tol(dtype))


def test_flash_attention_non_causal(rng):
    q = jnp.asarray(rng.normal(0, 1, (1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 128, 4, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 128, 4, 32)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, blk_q=64, blk_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,kv,hd,s,blk", [
    (2, 8, 4, 32, 256, 64),
    (1, 4, 4, 64, 512, 128),
    (3, 6, 2, 16, 128, 128),
])
@pytest.mark.parametrize("window", [0, 100])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(rng, b, h, kv, hd, s, blk, window, dtype):
    kc = jnp.asarray(rng.normal(0, 1, (b, kv, s, hd))).astype(dtype)
    vc = jnp.asarray(rng.normal(0, 1, (b, kv, s, hd))).astype(dtype)
    q = jnp.asarray(rng.normal(0, 1, (b, h, hd))).astype(dtype)
    fill = int(0.8 * s)
    kpos = jnp.where(jnp.arange(s) < fill, jnp.arange(s), -1)
    out = ops.flash_decode(q, kc, vc, kpos, fill - 1, window=window, blk_s=blk)
    want = ref.flash_decode_ref(q, kc, vc, kpos, fill - 1, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               **_tol(dtype))


def test_flash_decode_ring_buffer_positions(rng):
    """Ring cache: slot positions wrap; kernel must mask by position value."""
    from repro.models.layers import cache_slot_positions
    s, written = 64, 100
    kpos = cache_slot_positions(jnp.asarray(written), s, ring=True)
    kc = jnp.asarray(rng.normal(0, 1, (1, 2, s, 16)), jnp.float32)
    vc = jnp.asarray(rng.normal(0, 1, (1, 2, s, 16)), jnp.float32)
    q = jnp.asarray(rng.normal(0, 1, (1, 4, 16)), jnp.float32)
    out = ops.flash_decode(q, kc, vc, kpos, written - 1, window=s, blk_s=32)
    want = ref.flash_decode_ref(q, kc, vc, kpos, written - 1, window=s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
