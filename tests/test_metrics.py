"""Evaluation metrics: the vectorised tie-averaged AUC (ISSUE 5 satellite).

The old implementation averaged tied ranks with a Python while-loop — O(n^2)
on heavily tied score vectors, the common case early in training when a
barely-moved model emits near-constant logits. The rewrite is pure
``np.unique`` group arithmetic; these tests pin exact equality with the old
loop on tied, untied and degenerate inputs.
"""
import numpy as np
import pytest

from repro.federated.metrics import accuracy, auc


def _auc_reference_loop(labels, scores):
    """The pre-rewrite implementation, kept verbatim as the equality oracle."""
    labels = np.asarray(labels).astype(bool)
    scores = np.asarray(scores, dtype=np.float64)
    pos, neg = scores[labels], scores[~labels]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    order = np.argsort(np.concatenate([pos, neg]), kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(order) + 1)
    allv = np.concatenate([pos, neg])
    sortv = allv[order]
    i = 0
    while i < len(sortv):
        j = i
        while j + 1 < len(sortv) and sortv[j + 1] == sortv[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = ranks[order[i:j + 1]].mean()
        i = j + 1
    r_pos = ranks[: len(pos)].sum()
    return float((r_pos - len(pos) * (len(pos) + 1) / 2)
                 / (len(pos) * len(neg)))


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("levels", [2, 3, 17, 0])
def test_auc_matches_reference_on_tied_and_untied(seed, levels):
    """levels=0: continuous (untied) scores; small levels: heavy ties."""
    rng = np.random.default_rng(seed)
    n = 257
    labels = rng.integers(0, 2, n)
    if levels:
        scores = rng.integers(0, levels, n).astype(np.float64)
    else:
        scores = rng.normal(size=n)
    got = auc(labels, scores)
    want = _auc_reference_loop(labels, scores)
    assert got == pytest.approx(want, abs=1e-12)


def test_auc_constant_scores_is_half():
    """The early-training regime the O(n^2) loop choked on: every score
    tied. All ranks average to (n+1)/2 and AUC is exactly 0.5."""
    labels = np.array([0, 1, 0, 1, 1, 0])
    assert auc(labels, np.zeros(6)) == pytest.approx(0.5)


def test_auc_degenerate_classes():
    """Single-class labels have no pos/neg pairs: AUC is undefined and must
    come back NaN (a fake 0.5 hides a broken eval split), one regression
    per degenerate side."""
    all_neg = auc(np.zeros(5), np.arange(5.0))
    assert isinstance(all_neg, float) and np.isnan(all_neg)
    all_pos = auc(np.ones(5), np.arange(5.0))
    assert isinstance(all_pos, float) and np.isnan(all_pos)


def test_auc_perfect_and_inverted_separation():
    labels = np.array([0, 0, 1, 1])
    assert auc(labels, np.array([0.0, 0.1, 0.8, 0.9])) == pytest.approx(1.0)
    assert auc(labels, np.array([0.9, 0.8, 0.1, 0.0])) == pytest.approx(0.0)


def test_accuracy():
    assert accuracy(np.array([1, 0, 1]), np.array([2.0, -1.0, -3.0])) \
        == pytest.approx(2 / 3)
