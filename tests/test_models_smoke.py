"""Per-architecture smoke tests (deliverable f): a REDUCED variant of every
assigned architecture runs one forward/train step and one prefill+decode step
on CPU with correct shapes and no NaNs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model


def _batch(cfg, b, s, train=True):
    batch = {"tokens": jnp.ones((b, s), jnp.int32)}
    if train:
        batch["labels"] = jnp.ones((b, s), jnp.int32)
        batch["mask"] = jnp.ones((b, s), jnp.float32)
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = 0.01 * jnp.ones((b, cfg.num_patches, cfg.d_model),
                                                jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio_frames":
        batch["frames"] = 0.01 * jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                          jnp.dtype(cfg.dtype))
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(s), (3, b, s))
        batch["mrope_pos"] = pos.astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 2, 64
    batch = _batch(cfg, b, s)

    def step(p, batch):
        loss, grads = jax.value_and_grad(api.loss)(p, batch)
        new = jax.tree.map(lambda a, g: a - 0.1 * g.astype(a.dtype), p, grads)
        return loss, new

    loss, new_params = jax.jit(step)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    for leaf in jax.tree.leaves(new_params):
        assert not bool(jnp.isnan(leaf).any()), f"{arch}: NaN in updated params"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch(cfg, b, s, train=False)
    cache = api.init_cache(b, 64)
    logits, cache = jax.jit(api.prefill)(params, batch, cache)
    assert logits.shape == (b, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    db = {"tokens": jnp.ones((b,), jnp.int32)}
    if cfg.mrope:
        db["mrope_pos"] = jnp.full((3, b, 1), s, jnp.int32)
    logits2, cache2 = jax.jit(api.decode_step)(params, cache, db)
    assert logits2.shape == (b, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any())
    assert int(cache2.pos) == s + 1


@pytest.mark.parametrize("arch", ["mistral_large_123b", "qwen3_32b", "mixtral_8x22b",
                                  "xlstm_350m"])
def test_decode_matches_prefill(arch):
    """Decoding token t after prefill[0:t] must match prefill[0:t+1] logits.

    MoE capacity is raised so no tokens drop: capacity dropping is batch-
    dependent by design and breaks exact prefill/decode equivalence.
    """
    cfg = get_smoke_config(arch).replace(dtype="float32", moe_capacity_factor=8.0)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    b, s = 1, 17
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0, cfg.vocab_size)
    c1 = api.init_cache(b, 64)
    l_short, cache = jax.jit(api.prefill)(params, {"tokens": toks[:, :s]}, c1)
    l_dec, _ = jax.jit(api.decode_step)(params, cache, {"tokens": toks[:, s]})
    c2 = api.init_cache(b, 64)
    l_full, _ = jax.jit(api.prefill)(params, {"tokens": toks}, c2)
    np.testing.assert_allclose(np.asarray(l_dec), np.asarray(l_full),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_instantiated():
    """Analytic 6ND bookkeeping vs actual parameter tree (dense arch)."""
    from repro.common.pytree import tree_size
    cfg = get_smoke_config("deepseek_67b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    actual = tree_size(params)
    analytic = cfg.param_counts()["total"]
    # analytic skips norm scales at model level; allow 2% slack
    assert abs(actual - analytic) / analytic < 0.02, (actual, analytic)


def test_full_configs_match_assignment():
    """The full CONFIGs carry exactly the assigned hyperparameters."""
    spec = {
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 8192, 202048),
        "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen2_5_14b": (48, 5120, 40, 8, 13824, 152064),
        "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (nl, d, h, kv, ff, v), arch
    assert get_config("mixtral_8x22b").num_experts == 8
    assert get_config("mixtral_8x22b").experts_per_token == 2
    assert get_config("llama4_maverick_400b_a17b").num_experts == 128
    assert get_config("llama4_maverick_400b_a17b").experts_per_token == 1
    assert get_config("zamba2_1_2b").ssm_state == 64
    assert get_config("qwen3_32b").qk_norm
    assert get_config("qwen2_5_14b").qkv_bias
    assert get_config("qwen2_vl_7b").mrope


def test_chunked_moe_matches_unchunked():
    """token_chunk scans the dispatch; with ample capacity (no drops) the
    result is bit-identical to the unchunked dispatch."""
    import numpy as np
    from repro.models.layers import moe, make_moe
    from repro.sharding.logical import ParamFactory, unbox
    pf = ParamFactory(rng=jax.random.PRNGKey(0), abstract=False, dtype=jnp.float32)
    p = unbox(make_moe(pf, 32, 64, 4))
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 64, 32)), jnp.float32)
    y1, s1 = moe(p, x, num_experts=4, top_k=2, capacity_factor=8.0)
    y2, s2 = moe(p, x, num_experts=4, top_k=2, capacity_factor=8.0, token_chunk=32)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert bool((s1.expert_tokens == s2.expert_tokens).all())
