"""RoundPlan API: plan-equivalence matrix vs the legacy mode strings, the
previously inexpressible compositions, FedConfig validation, label-pinning
unification, and the pinned public surface of repro.federated."""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FedConfig
from repro.data import make_movielens_like
from repro.federated import (DenseTransport, FederatedTrainer, FedSgdLocal,
                             ReplicatedLocal, RoundPlan, RowSparseTransport,
                             ServerUpdate, SubmodelReplicatedLocal,
                             build_round_step, make_round_step, plan_comm_meta,
                             plan_from_config, resolve_plan)
from repro.models.recsys import (lr_logits, lr_loss, lstm_loss, make_lr_params,
                                 make_lstm_params)
from repro.sharding.logical import unbox
from repro.sparse.encode import pin_labels


# ---------------------------------------------------------------------------
# fixtures: a tiny LSTM (one axis-0 feature table) + batches in both layouts
# ---------------------------------------------------------------------------

V, E = 128, 6


def _params():
    return make_lstm_params(V, emb_dim=E, hidden=8, layers=1,
                            rng=jax.random.PRNGKey(1))


def _flat_batch(seed, b=6, s=8):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, V, (b, s)), jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, b), jnp.int32),
            "heat_vocab": jnp.maximum(jnp.asarray(
                rng.integers(0, 6, V), jnp.float32), 0)}


def _cohort_batch(seed, k=3, i=2, b=2, s=6):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(-1, V, (k, i, b, s)), jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, (k, i, b)), jnp.int32),
            "heat_vocab": jnp.maximum(jnp.asarray(
                rng.integers(0, 6, V), jnp.float32), 0)}


_COHORT_MODES = {"replicated", "sparse_replicated"}

#: every legacy mode string and the RoundPlan composition it aliases
_MATRIX = {
    "fedsgd": lambda server: RoundPlan(FedSgdLocal(), DenseTransport(),
                                       server),
    "sparse": lambda server: RoundPlan(FedSgdLocal(), RowSparseTransport(),
                                       server),
    "replicated": lambda server: RoundPlan(ReplicatedLocal(),
                                           DenseTransport(), server),
    "sparse_replicated": lambda server: RoundPlan(SubmodelReplicatedLocal(),
                                                  RowSparseTransport(),
                                                  server),
}


def _run(step_builder, mode_or_plan, correct, rounds=3):
    params = _params()
    fed = FedConfig(num_clients=16, clients_per_round=3, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    step = jax.jit(make_round_step(lstm_loss, params, fed, mode=mode_or_plan,
                                   correct=correct))
    mk = (_cohort_batch if step_builder in _COHORT_MODES else _flat_batch)
    losses = []
    for r in range(rounds):
        params, m = step(params, mk(100 + r))
        losses.append(float(m["loss"]))
    return params, losses


@pytest.mark.parametrize("mode", sorted(_MATRIX))
@pytest.mark.parametrize("correct", [True, False])
def test_plan_matrix_matches_mode_strings(mode, correct):
    """ISSUE 4 acceptance: every legacy mode string x correct flag reproduces
    its explicit RoundPlan composition to 1e-5 over a multi-round run."""
    server = ServerUpdate("fedsubavg" if correct else "fedavg")
    plan = _MATRIX[mode](server)
    p_str, l_str = _run(mode, mode, correct)
    p_plan, l_plan = _run(mode, plan, correct)
    np.testing.assert_allclose(l_plan, l_str, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(unbox(p_str)),
                    jax.tree.leaves(unbox(p_plan))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_resolve_plan_compositions_and_passthrough():
    cfg = FedConfig(num_clients=8, microbatches=2)
    p = resolve_plan("fedsgd", cfg)
    assert isinstance(p.local, FedSgdLocal) and p.local.microbatches == 2
    assert isinstance(p.transport, DenseTransport)
    assert p.server.correct and p.server.stateless
    p = resolve_plan("sparse_replicated", cfg, correct=False)
    assert isinstance(p.local, SubmodelReplicatedLocal)
    assert isinstance(p.transport, RowSparseTransport)
    assert not p.server.correct
    # a RoundPlan passes through untouched
    assert resolve_plan(p, cfg) is p
    with pytest.raises(ValueError):
        resolve_plan("warp", cfg)
    # mode="sparse" rejects microbatched configs up front
    with pytest.raises(ValueError, match="microbatches"):
        resolve_plan("sparse", cfg)


def test_make_round_step_rejects_stateful_server():
    params = _params()
    fed = FedConfig(num_clients=8, algorithm="fedadam")
    plan = RoundPlan(ReplicatedLocal(), DenseTransport(),
                     ServerUpdate("fedadam"))
    with pytest.raises(ValueError, match="stateless"):
        make_round_step(lstm_loss, params, fed, mode=plan)


def test_build_round_step_drives_stateful_server():
    """What the stateless wrapper can't express, build_round_step can: a
    fedadam ServerUpdate threads its optimizer slots through ServerState."""
    from repro.core.algorithms import make_server_algorithm

    params = _params()
    fed = FedConfig(num_clients=16, clients_per_round=3, local_iters=2,
                    lr=0.1, algorithm="fedadam", server_lr=0.05)
    plan = RoundPlan(SubmodelReplicatedLocal(), RowSparseTransport(),
                     ServerUpdate("fedadam"))
    step = jax.jit(build_round_step(plan, lstm_loss, params, fed))
    state = make_server_algorithm(fed).init(params)
    for r in range(3):
        state, m = step(state, _cohort_batch(70 + r))
        assert np.isfinite(float(m["loss"]))
    assert int(state.rounds) == 3
    m0, _ = state.opt
    assert any(float(jnp.abs(l).max()) > 0 for l in jax.tree.leaves(m0))


# ---------------------------------------------------------------------------
# previously inexpressible compositions
# ---------------------------------------------------------------------------


def test_topk_int8_on_simulation_sparse_path_with_comm_bytes():
    """ISSUE 4 acceptance: top-k / int8 compression under build_round_step's
    sparse path — a composition no mode string could express — runs
    end-to-end and its comm bytes are priced by the transport."""
    params = _params()
    fed = FedConfig(num_clients=16, clients_per_round=3, lr=0.1,
                    algorithm="fedsubavg")
    base = RoundPlan(FedSgdLocal(), RowSparseTransport(),
                     ServerUpdate("fedsubavg"))
    batch = _flat_batch(7)
    p_base, m_base = jax.jit(make_round_step(
        lstm_loss, params, fed, mode=base))(params, batch)

    for transport in (RowSparseTransport(topk=4),
                      RowSparseTransport(int8=True),
                      RowSparseTransport(topk=4, int8=True)):
        plan = RoundPlan(FedSgdLocal(), transport, ServerUpdate("fedsubavg"))
        step = jax.jit(make_round_step(lstm_loss, params, fed, mode=plan))
        p_c, m_c = step(params, batch)
        assert np.isfinite(float(m_c["loss"]))
        assert int(m_c["sub_rows"]) == int(m_base["sub_rows"])
        # compression changes the applied update
        emb_base = np.asarray(unbox(p_base)["embedding"])
        emb_c = np.asarray(unbox(p_c)["embedding"])
        assert not np.allclose(emb_base, emb_c, atol=1e-12)
        if transport.topk:
            # at most topk embedding rows moved
            moved = (np.abs(emb_c - np.asarray(unbox(params)["embedding"]))
                     .max(axis=1) > 0).sum()
            assert moved <= transport.topk

        # comm pricing: the transport owns the bytes
        meta = plan_comm_meta(params)
        counts = np.asarray([int(m_c["sub_rows"])])
        stats = transport.round_comm(0, meta, counts, V)
        assert stats.bytes_up_sparse > 0
        assert stats.bytes_up_sparse < stats.bytes_up_dense
        per_row_f32 = 4 + meta.row_payload_bytes
        per_row = (4 + meta.row_elems + 4) if transport.int8 else per_row_f32
        rows_up = min(counts[0], transport.topk) if transport.topk else counts[0]
        want = meta.sparse_static_bytes + rows_up * per_row
        assert stats.bytes_up_sparse == pytest.approx(want)


def test_submodel_local_training_with_dense_transport():
    """The other unlocked combination: submodel-replica local training against
    a dense server transport reproduces dense-replica training exactly."""
    params = _params()
    fed = FedConfig(num_clients=16, clients_per_round=3, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    plan_sub = RoundPlan(SubmodelReplicatedLocal(), DenseTransport(),
                         ServerUpdate("fedsubavg"))
    step_sub = jax.jit(make_round_step(lstm_loss, params, fed, mode=plan_sub))
    step_rep = jax.jit(make_round_step(lstm_loss, params, fed,
                                       mode="replicated"))
    p_s, p_r = params, params
    for r in range(3):
        batch = _cohort_batch(50 + r)
        p_s, m_s = step_sub(p_s, batch)
        p_r, m_r = step_rep(p_r, batch)
        np.testing.assert_allclose(float(m_s["loss"]), float(m_r["loss"]),
                                   rtol=1e-6)
    for a, b in zip(jax.tree.leaves(unbox(p_r)), jax.tree.leaves(unbox(p_s))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_fedprox_style_plan_via_local_prox():
    """A FedProx-style variant is a LocalStep knob, not a new branch: the
    prox_mu override reproduces cfg.algorithm='fedprox' local training."""
    params = _params()
    mu = 0.05
    fed_prox = FedConfig(num_clients=16, clients_per_round=3, local_iters=3,
                         lr=0.1, algorithm="fedprox", prox_mu=mu)
    fed_avg = FedConfig(num_clients=16, clients_per_round=3, local_iters=3,
                        lr=0.1, algorithm="fedavg")
    plan = RoundPlan(ReplicatedLocal(prox_mu=mu), DenseTransport(),
                     ServerUpdate("fedavg"))
    batch = _cohort_batch(9)
    p_cfg, _ = jax.jit(make_round_step(
        lstm_loss, params, fed_prox, mode="replicated",
        correct=False))(params, batch)
    p_plan, _ = jax.jit(make_round_step(
        lstm_loss, params, fed_avg, mode=plan))(params, batch)
    for a, b in zip(jax.tree.leaves(unbox(p_cfg)),
                    jax.tree.leaves(unbox(p_plan))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    # and the prox term actually bites (differs from plain fedavg locals)
    p_plain, _ = jax.jit(make_round_step(
        lstm_loss, params, fed_avg, mode="replicated",
        correct=False))(params, batch)
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(unbox(p_plain)),
                 jax.tree.leaves(unbox(p_plan)))]
    assert max(diffs) > 0


# ---------------------------------------------------------------------------
# FederatedTrainer consumes the same plans
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def plan_ds():
    return make_movielens_like(num_clients=40, num_items=40, mean_samples=15)


def _trainer(ds, cfg, plan=None):
    mk = functools.partial(make_lr_params, ds.num_features)
    return FederatedTrainer(
        ds, mk, lr_loss, cfg,
        predict_fn=lambda p, t: lr_logits(p, jnp.asarray(t["features"])),
        metric="auc", plan=plan)


def test_trainer_explicit_plan_matches_config_flags(plan_ds):
    """One dispatch system: an explicit RoundPlan reproduces the FedConfig
    flag resolution exactly (same RNG stream, same losses/params)."""
    cfg = FedConfig(num_clients=plan_ds.num_clients, clients_per_round=6,
                    local_iters=3, local_batch=4, lr=0.5,
                    algorithm="fedsubavg", sparse=True, sparse_topk=6)
    tr_flags = _trainer(plan_ds, cfg)
    plan = RoundPlan(SubmodelReplicatedLocal(),
                     RowSparseTransport(topk=6),
                     ServerUpdate("fedsubavg"), ("features",))
    tr_plan = _trainer(plan_ds, cfg, plan=plan)
    assert tr_flags.plan == tr_plan.plan
    l_flags = [tr_flags.run_round() for _ in range(4)]
    l_plan = [tr_plan.run_round() for _ in range(4)]
    np.testing.assert_allclose(l_plan, l_flags, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(unbox(tr_flags.state.params)),
                    jax.tree.leaves(unbox(tr_plan.state.params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    # comm accounting rides the plan's transport
    assert len(tr_plan.comm_log) == 4
    assert tr_plan.comm_log[-1].bytes_up_sparse == pytest.approx(
        tr_flags.comm_log[-1].bytes_up_sparse)


def test_trainer_plan_algorithm_must_match_config(plan_ds):
    cfg = FedConfig(num_clients=plan_ds.num_clients, algorithm="fedsubavg")
    plan = RoundPlan(ReplicatedLocal(), DenseTransport(),
                     ServerUpdate("fedavg"))
    with pytest.raises(ValueError, match="algorithm"):
        _trainer(plan_ds, cfg, plan=plan)


def test_trainer_rejects_flat_local_plans(plan_ds):
    """The trainer samples stacked (K, I, B, ...) cohorts; a FedSgdLocal plan
    would be fed shapes it cannot consume — rejected at construction."""
    cfg = FedConfig(num_clients=plan_ds.num_clients, algorithm="fedsubavg")
    for transport in (DenseTransport(), RowSparseTransport()):
        plan = RoundPlan(FedSgdLocal(), transport, ServerUpdate("fedsubavg"))
        with pytest.raises(ValueError, match="flat pooled"):
            _trainer(plan_ds, cfg, plan=plan)


def test_fedsgd_microbatched_keeps_param_dtype():
    """Regression: the f32 microbatch gradient accumulator must be cast back
    to each param's dtype before the server add — bf16 params were coming
    back silently promoted to float32 (legacy fedsgd always cast)."""
    from repro.sharding.logical import Param

    params = {"w": Param(jnp.ones((4, 4), jnp.bfloat16), (None, None))}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"].value.astype(jnp.float32)) ** 2)

    fed = FedConfig(num_clients=4, lr=0.1, microbatches=2)
    step = jax.jit(make_round_step(loss_fn, params, fed, mode="fedsgd",
                                   correct=False))
    batch = {"x": jnp.ones((4, 4), jnp.float32)}
    new_params, _ = step(params, batch)
    assert unbox(new_params)["w"].dtype == jnp.bfloat16


def test_dense_plan_rejects_conflicting_microbatches():
    """Consistent with the sparse branch: an explicit dense FedSgdLocal plan
    must not silently discard cfg.microbatches."""
    params = _params()
    fed = FedConfig(num_clients=8, microbatches=4)
    plan = RoundPlan(FedSgdLocal(), DenseTransport(), ServerUpdate("fedavg"))
    with pytest.raises(ValueError, match="microbatches"):
        make_round_step(lstm_loss, params, fed, mode=plan)
    # the matching plan passes
    ok = RoundPlan(FedSgdLocal(microbatches=4), DenseTransport(),
                   ServerUpdate("fedavg"))
    make_round_step(lstm_loss, params, fed, mode=ok)


def test_resolve_plan_rejects_conflicting_args():
    """An explicit RoundPlan is the whole truth: the string-mode knobs must
    not silently contradict it."""
    cfg = FedConfig(num_clients=8)
    plan = RoundPlan(FedSgdLocal(), RowSparseTransport(),
                     ServerUpdate("fedsubavg"))
    with pytest.raises(ValueError, match="correct=False"):
        resolve_plan(plan, cfg, correct=False)
    with pytest.raises(ValueError, match="feature_key"):
        resolve_plan(plan, cfg, feature_key="hist")
    # consistent values pass through
    assert resolve_plan(plan, cfg, feature_key="tokens") is plan
    avg = RoundPlan(FedSgdLocal(), RowSparseTransport(), ServerUpdate("fedavg"))
    assert resolve_plan(avg, cfg, correct=False) is avg


def test_stateless_int8_keys_off_batch_fingerprint():
    """Regression: the stateless make_round_step wrapper must not pin the
    int8 stochastic-rounding key to rounds=0 forever (correlated noise every
    round) — it seeds the counter with a batch fingerprint instead."""
    from repro.core.algorithms import ServerState

    params = _params()
    fed = FedConfig(num_clients=16, clients_per_round=3, lr=0.1,
                    algorithm="fedsubavg")
    plan = RoundPlan(FedSgdLocal(), RowSparseTransport(int8=True),
                     ServerUpdate("fedavg"))
    wrapper = jax.jit(make_round_step(lstm_loss, params, fed, mode=plan,
                                      correct=False))
    inner = jax.jit(build_round_step(plan, lstm_loss, params, fed))
    batch = _flat_batch(1)
    p_w, _ = wrapper(params, batch)

    def inner_emb(rounds):
        s = ServerState(params, (), jnp.asarray(rounds, jnp.int32))
        ns, _ = inner(s, batch)
        return np.asarray(unbox(ns.params)["embedding"])

    fp = int(np.asarray(batch["tokens"], np.uint32).sum()
             & np.uint32(0x7FFFFFFF))
    assert fp != 0
    # the wrapper's noise comes from the fingerprint-seeded counter...
    np.testing.assert_array_equal(np.asarray(unbox(p_w)["embedding"]),
                                  inner_emb(fp))
    # ...not the pre-fix constant 0 (distinct keys -> distinct noise)
    assert not np.array_equal(np.asarray(unbox(p_w)["embedding"]),
                              inner_emb(0))
    # same batch -> same key -> deterministic
    p_w2, _ = wrapper(params, batch)
    np.testing.assert_array_equal(np.asarray(unbox(p_w)["embedding"]),
                                  np.asarray(unbox(p_w2)["embedding"]))


def test_plan_from_config_resolution():
    cfg = FedConfig(num_clients=8)
    p = plan_from_config(cfg)
    assert isinstance(p.local, ReplicatedLocal)
    assert isinstance(p.transport, DenseTransport)
    p = plan_from_config(FedConfig(num_clients=8, sparse=True,
                                   sparse_int8=True), gatherable=True)
    assert isinstance(p.local, SubmodelReplicatedLocal)
    assert p.transport == RowSparseTransport(int8=True)
    p = plan_from_config(FedConfig(num_clients=8, sparse=True),
                         gatherable=False)
    assert isinstance(p.local, ReplicatedLocal)
    with pytest.raises(ValueError, match="central"):
        plan_from_config(FedConfig(num_clients=8, algorithm="central"))


# ---------------------------------------------------------------------------
# satellite: FedConfig construction-time validation
# ---------------------------------------------------------------------------


def test_fedconfig_rejects_unknown_algorithm():
    with pytest.raises(ValueError, match="algorithm"):
        FedConfig(algorithm="sgd")


def test_fedconfig_rejects_unknown_heat_estimator():
    with pytest.raises(ValueError, match="heat_estimator"):
        FedConfig(heat_estimator="oracle")


def test_fedconfig_rejects_unknown_sparse_local():
    with pytest.raises(ValueError, match="sparse_local"):
        FedConfig(sparse_local="dense")


def test_fedconfig_rejects_negative_topk():
    with pytest.raises(ValueError, match="sparse_topk"):
        FedConfig(sparse_topk=-1)


def test_fedconfig_rejects_microbatched_sparse():
    with pytest.raises(ValueError, match="microbatches"):
        FedConfig(sparse=True, microbatches=4)
    # each constraint alone stays legal
    FedConfig(sparse=True, microbatches=1)
    FedConfig(sparse=False, microbatches=4)


# ---------------------------------------------------------------------------
# satellite: unified CE-label pinning
# ---------------------------------------------------------------------------


def test_pin_labels_layouts_agree():
    """The (B, S) and (K, I, B, S) layouts produce identical labels for the
    same sequences — the rule that used to be re-implemented per mode."""
    rng = np.random.default_rng(0)
    b, s = 4, 9
    toks = jnp.asarray(rng.integers(0, 50, (b, s)), jnp.int32)
    flat = pin_labels({"tokens": toks})["labels"]
    nested = pin_labels({"tokens": toks.reshape(1, 1, b, s)})["labels"]
    np.testing.assert_array_equal(np.asarray(flat),
                                  np.asarray(nested)[0, 0])
    # shifted-left next-token targets, zero-padded at the sequence end
    np.testing.assert_array_equal(np.asarray(flat[:, :-1]),
                                  np.asarray(toks[:, 1:]))
    assert np.all(np.asarray(flat[:, -1]) == 0)


def test_pin_labels_noop_cases():
    toks = jnp.ones((2, 3), jnp.int32)
    labels = jnp.zeros((2, 3), jnp.int32)
    d = pin_labels({"tokens": toks, "labels": labels})
    assert d["labels"] is labels
    d = pin_labels({"label": jnp.ones((4,), jnp.int32)})  # no feature key
    assert "labels" not in d
    d = pin_labels({"tokens": jnp.ones((4,), jnp.int32)})  # no sequence axis
    assert "labels" not in d


# ---------------------------------------------------------------------------
# satellite: pinned public surface
# ---------------------------------------------------------------------------


def test_federated_public_api_surface():
    import repro.federated as fed

    assert sorted(fed.__all__) == sorted([
        "RoundPlan", "CohortSharding", "FedSgdLocal", "ReplicatedLocal",
        "SubmodelReplicatedLocal", "DenseTransport", "RowSparseTransport",
        "ServerUpdate", "build_round_step", "resolve_plan",
        "plan_from_config", "plan_comm_meta", "split_heat_batch",
        "make_round_step", "FederatedTrainer", "cohort_submodel_deltas",
        "make_local_trainer", "make_submodel_local_trainer", "RoundRecord",
        "comm_summary", "count_sub_ids", "derive_sub_ids", "pow2_capacity",
        "heat_spec_from_axes", "round_capacity", "sparse_table_paths",
        "ArrivalSim", "EventSchedule", "AsyncEngine", "AsyncState",
        "BufferedAsyncServerUpdate", "build_async_engine", "staleness_weight",
    ])
    for name in fed.__all__:
        assert getattr(fed, name) is not None
