"""Roofline analytics validation.

``cost_analysis`` counts loop bodies once (verified below), so the roofline
uses analytic FLOP totals. With num_layers=1 and a single attention/loss
chunk there are no multi-trip loops, so HLO and analytic counts must agree —
that pins the analytic calculator to ground truth.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from benchmarks.roofline import analytic_flops_for
from repro.configs import get_smoke_config
from repro.launch.hlo import cost_analysis_dict
from repro.models import build_model


def test_cost_analysis_counts_loop_body_once():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=10)[0]

    x = jnp.zeros((64, 64))
    w = jnp.zeros((64, 64))
    flops_scan = cost_analysis_dict(jax.jit(f).lower(x, w).compile())["flops"]
    flops_once = cost_analysis_dict(
        jax.jit(lambda x, w: x @ w).lower(x, w).compile())["flops"]
    assert flops_scan < 2 * flops_once  # NOT ~10x: body counted once


@pytest.mark.parametrize("arch", ["mistral_large_123b", "qwen3_32b"])
def test_analytic_flops_match_hlo_single_layer(arch):
    """L=1, one attention chunk, one loss chunk -> HLO flops ~= analytic."""
    cfg = get_smoke_config(arch).replace(num_layers=1, dtype="float32",
                                         query_chunk=64, kv_chunk=64)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 2, 64
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32),
             "mask": jnp.ones((b, s), jnp.float32)}
    hlo = cost_analysis_dict(jax.jit(api.loss).lower(params, batch).compile())["flops"]
    af = analytic_flops_for(cfg, "prefill", b, s)   # forward-only loss
    # loss() is forward only here (no grad), so compare to the prefill estimate
    ratio = hlo / af["total"]
    assert 0.5 < ratio < 2.0, (hlo, af)


def test_hlo_collective_parser_loop_multiplier():
    """Covered end-to-end in the dry-run; here: the text-level parser math."""
    from repro.launch.hlo import analyze_hlo
    fake = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ag = f32[8,8] all-gather(%x), replica_groups={}
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %w = (s32[], f32[8,8]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  %ar = f32[4,4] all-reduce(%y), to_apply=%add
}
"""
    rep = analyze_hlo(fake)
    by = rep.by_op()
    assert by["all-gather"] == 8 * 8 * 4 * 7       # trip-multiplied
    assert by["all-reduce"] == 4 * 4 * 4           # top level once
    assert rep.unresolved_loops == 0


def test_analytic_flops_moe_uses_active_params():
    cfg = get_smoke_config("mixtral_8x22b")
    dense_equiv = cfg.replace(num_experts=0, d_ff=cfg.d_ff * cfg.experts_per_token)
    f_moe = analytic_flops_for(cfg, "decode", 8, 4096)["matmul"]
    f_dense = analytic_flops_for(dense_equiv, "decode", 8, 4096)["matmul"]
    # top-2 of 4 experts ~ dense with 2x d_ff (+ router); within 15%
    assert abs(f_moe - f_dense) / f_dense < 0.15


def test_bench_roofline_missing_artifact_is_graceful(tmp_path, monkeypatch):
    """No dry-run artifact: one explanatory row, no crash, no table."""
    from benchmarks import bench_roofline
    monkeypatch.chdir(tmp_path)
    rows = bench_roofline.run()
    assert len(rows) == 1
    name, us, derived = rows[0]
    assert name == "roofline/missing"
    assert us == 0.0
    assert "dryrun" in derived


def test_hardware_constants_single_sourced():
    """Every roofline consumer reads the same HW dict object: the LLM
    roofline (benchmarks.roofline), the mesh model (repro.launch.mesh) and
    the kernel cost model (repro.analysis.kernel_audit) cannot disagree on
    peak FLOP/s or HBM bandwidth."""
    import benchmarks.roofline as llm_roofline
    from repro.analysis import kernel_audit
    from repro.common.hw import HW
    from repro.launch import mesh

    assert llm_roofline.HW is HW
    assert mesh.HW is HW
    assert kernel_audit.HW is HW
    for key in ("peak_flops_bf16", "hbm_bandwidth", "ici_bandwidth",
                "hbm_bytes", "vmem_bytes"):
        assert HW[key] > 0
    # the kernel VMEM budgets derive from the same source
    from repro.kernels.heat_scatter import VMEM_BUDGET
    assert VMEM_BUDGET == 3 * HW["vmem_bytes"] // 4
