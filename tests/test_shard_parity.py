"""Cohort-sharded rounds: sharded-vs-single-device parity (ISSUE 5).

The matrix runs on whatever devices are visible; CI's shard-parity step
forces 8 virtual CPU devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set BEFORE jax
initialises), which is the configuration the acceptance criteria pin. On a
single real device the same tests still exercise the shard_map machinery
with one shard.
"""
import dataclasses
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FedConfig
from repro.data import make_movielens_like
from repro.federated import (CohortSharding, DenseTransport, FederatedTrainer,
                             FedSgdLocal, RoundPlan, RowSparseTransport,
                             ServerUpdate, make_round_step, resolve_plan)
from repro.launch.mesh import make_cohort_mesh
from repro.models.recsys import lr_logits, lr_loss, lstm_loss, make_lr_params, \
    make_lstm_params
from repro.sharding.logical import unbox

NDEV = len(jax.devices())
V, E = 128, 6


def _params():
    return make_lstm_params(V, emb_dim=E, hidden=8, layers=1,
                            rng=jax.random.PRNGKey(1))


def _flat_batch(seed, b=8, s=8):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, V, (b, s)), jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, b), jnp.int32),
            "heat_vocab": jnp.maximum(jnp.asarray(
                rng.integers(0, 6, V), jnp.float32), 0)}


def _cohort_batch(seed, k=3, i=2, b=2, s=6):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(-1, V, (k, i, b, s)), jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, (k, i, b)), jnp.int32),
            "heat_vocab": jnp.maximum(jnp.asarray(
                rng.integers(0, 6, V), jnp.float32), 0)}


_FLAT_MODES = {"fedsgd", "sparse"}


def _run(mode_or_plan, mode_name, correct, rounds=3, k=3):
    params = _params()
    fed = FedConfig(num_clients=16, clients_per_round=k, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    step = jax.jit(make_round_step(lstm_loss, params, fed, mode=mode_or_plan,
                                   correct=correct))
    mk = (_flat_batch if mode_name in _FLAT_MODES
          else functools.partial(_cohort_batch, k=k))
    losses, subs = [], []
    for r in range(rounds):
        params, m = step(params, mk(100 + r))
        losses.append(float(m["loss"]))
        if "sub_rows" in m:
            subs.append(int(m["sub_rows"]))
    return params, losses, subs


def _assert_tree_close(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree.leaves(unbox(a)), jax.tree.leaves(unbox(b))):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# the acceptance matrix: mode x algorithm, sharded vs single-device
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["fedsgd", "sparse", "sparse_replicated",
                                  "replicated"])
@pytest.mark.parametrize("correct", [True, False])
def test_sharded_matches_single_device(mode, correct):
    """ISSUE 5 acceptance: wrapping any mode's plan in CohortSharding
    reproduces the single-device step to 1e-5 over a multi-round run with
    the same RNG stream — {fedavg, fedsubavg} x the mode matrix."""
    fed = FedConfig(num_clients=16, clients_per_round=3, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    plan = resolve_plan(mode, fed, correct=correct)
    sharded = dataclasses.replace(
        plan, sharding=CohortSharding(make_cohort_mesh()))
    p1, l1, s1 = _run(mode, mode, correct)
    p2, l2, s2 = _run(sharded, mode, correct)
    np.testing.assert_allclose(l2, l1, rtol=1e-5)
    assert s2 == s1                        # density metrics agree exactly
    _assert_tree_close(p1, p2)


@pytest.mark.parametrize("combine", ["psum", "union"])
def test_both_combine_strategies_are_exact(combine):
    """psum-densify and union-of-unions are the same math: both reproduce
    the single-device sparse_replicated round."""
    fed = FedConfig(num_clients=16, clients_per_round=3, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    plan = resolve_plan("sparse_replicated", fed)
    sharded = dataclasses.replace(
        plan, sharding=CohortSharding(make_cohort_mesh(), combine=combine))
    p1, l1, s1 = _run("sparse_replicated", "sparse_replicated", True)
    p2, l2, s2 = _run(sharded, "sparse_replicated", True)
    np.testing.assert_allclose(l2, l1, rtol=1e-5)
    assert s2 == s1
    _assert_tree_close(p1, p2)


@pytest.mark.skipif(NDEV < 2, reason="padding needs a multi-device mesh")
def test_non_divisible_cohort_pads_and_masks():
    """A cohort that does not divide over the mesh (the issue's 10-on-8
    case) is padded shard-major and masked — still exact vs single-device."""
    k = NDEV + 2                           # 10 on 8 devices
    p1, l1, s1 = _run("sparse_replicated", "sparse_replicated", True, k=k)
    fed = FedConfig(num_clients=16, clients_per_round=k, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    plan = resolve_plan("sparse_replicated", fed)
    sharded = dataclasses.replace(
        plan, sharding=CohortSharding(make_cohort_mesh()))
    p2, l2, s2 = _run(sharded, "sparse_replicated", True, k=k)
    np.testing.assert_allclose(l2, l1, rtol=1e-5)
    assert s2 == s1
    _assert_tree_close(p1, p2)


@pytest.mark.skipif(NDEV < 2, reason="needs a multi-device mesh")
def test_flat_batch_must_divide():
    """Flat (pooled-batch) plans reject a batch the mesh cannot split."""
    params = _params()
    fed = FedConfig(num_clients=16, lr=0.1, algorithm="fedsubavg")
    plan = dataclasses.replace(resolve_plan("fedsgd", fed),
                               sharding=CohortSharding(make_cohort_mesh()))
    step = make_round_step(lstm_loss, params, fed, mode=plan)
    with pytest.raises(ValueError, match="does not divide"):
        jax.jit(step)(params, _flat_batch(0, b=NDEV + 1))


def test_flat_sparse_explicit_sub_ids_shards_exactly():
    """A caller-provided flat union (build_round_step's sub_ids argument) is
    replicated to every shard and reproduces the single-device step."""
    from repro.core.algorithms import ServerState
    from repro.federated import build_round_step
    from repro.sparse.encode import batch_union_ids

    params = _params()
    fed = FedConfig(num_clients=16, clients_per_round=3, lr=0.1,
                    algorithm="fedsubavg")
    plan = resolve_plan("sparse", fed)
    sharded = dataclasses.replace(
        plan, sharding=CohortSharding(make_cohort_mesh()))
    s1 = jax.jit(build_round_step(plan, lstm_loss, params, fed))
    s2 = jax.jit(build_round_step(sharded, lstm_loss, params, fed))
    batch = _flat_batch(3)
    sub_ids = batch_union_ids(batch, ("tokens",), 64)
    st1, m1 = s1(ServerState(params, (), 0), batch, sub_ids)
    st2, m2 = s2(ServerState(params, (), 0), batch, sub_ids)
    np.testing.assert_allclose(float(m2["loss"]), float(m1["loss"]),
                               rtol=1e-6)
    assert int(m1["sub_rows"]) == int(m2["sub_rows"])
    _assert_tree_close(st1.params, st2.params)


def test_sharded_microbatch_divisibility_is_validated():
    """Per-shard gradient accumulation needs B % (ndev * microbatches) == 0;
    the violation is a ValueError, not a mid-trace assert."""
    params = _params()
    fed = FedConfig(num_clients=16, lr=0.1, microbatches=4)
    plan = RoundPlan(FedSgdLocal(microbatches=4), DenseTransport(),
                     ServerUpdate("fedavg"),
                     sharding=CohortSharding(make_cohort_mesh()))
    step = make_round_step(lstm_loss, params, fed, mode=plan, correct=False)
    with pytest.raises(ValueError, match="microbatches"):
        jax.jit(step)(params, _flat_batch(0, b=2 * NDEV))


def test_sharding_rejects_int8_and_flat_topk():
    fed = FedConfig(num_clients=16, lr=0.1, algorithm="fedsubavg")
    params = _params()
    sh = CohortSharding(make_cohort_mesh())
    bad_int8 = RoundPlan(FedSgdLocal(), RowSparseTransport(int8=True),
                         ServerUpdate("fedsubavg"), sharding=sh)
    with pytest.raises(ValueError, match="int8"):
        make_round_step(lstm_loss, params, fed, mode=bad_int8)
    bad_topk = RoundPlan(FedSgdLocal(), RowSparseTransport(topk=4),
                         ServerUpdate("fedsubavg"), sharding=sh)
    with pytest.raises(ValueError, match="top-k"):
        make_round_step(lstm_loss, params, fed, mode=bad_topk)


def test_cohort_sharding_validation():
    mesh = make_cohort_mesh()
    with pytest.raises(ValueError, match="axis"):
        CohortSharding(mesh, axis="model")
    with pytest.raises(ValueError, match="combine"):
        CohortSharding(mesh, combine="allgather")
    assert CohortSharding(mesh).num_shards == NDEV


# ---------------------------------------------------------------------------
# FederatedTrainer: mesh= threads the sharding through both round drivers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def shard_ds():
    return make_movielens_like(num_clients=40, num_items=40, mean_samples=15)


def _trainer(ds, mesh=None, **kw):
    cfg = FedConfig(num_clients=ds.num_clients,
                    clients_per_round=kw.pop("clients_per_round", NDEV + 2),
                    local_iters=3, local_batch=4, lr=0.5,
                    algorithm=kw.pop("algorithm", "fedsubavg"), **kw)
    mk = functools.partial(make_lr_params, ds.num_features)
    return FederatedTrainer(
        ds, mk, lr_loss, cfg,
        predict_fn=lambda p, t: lr_logits(p, jnp.asarray(t["features"])),
        metric="auc", mesh=mesh)


def test_trainer_mesh_round_loop_parity(shard_ds):
    """Same RNG stream, per-round driver: losses/params/comm bytes identical
    (cohort NDEV+2 on NDEV devices — the non-divisible trainer case)."""
    t1 = _trainer(shard_ds, sparse=True)
    t2 = _trainer(shard_ds, mesh=make_cohort_mesh(), sparse=True)
    l1 = [t1.run_round() for _ in range(4)]
    l2 = [t2.run_round() for _ in range(4)]
    np.testing.assert_allclose(l2, l1, rtol=1e-5)
    _assert_tree_close(t1.state.params, t2.state.params)
    assert (t2.comm_log[-1].bytes_up_sparse
            == pytest.approx(t1.comm_log[-1].bytes_up_sparse))


def test_trainer_mesh_run_rounds_engine_parity(shard_ds):
    """The in-jit run_rounds scan runs sharded too — identical losses."""
    t1 = _trainer(shard_ds, sparse=True)
    t2 = _trainer(shard_ds, mesh=make_cohort_mesh(), sparse=True)
    l1 = t1.run_rounds(4)
    l2 = t2.run_rounds(4)
    np.testing.assert_allclose(l2, l1, rtol=1e-5)
    _assert_tree_close(t1.state.params, t2.state.params)


def test_trainer_mesh_dense_and_stateful(shard_ds):
    """Dense plans and stateful server optimizers shard identically."""
    for kw in (dict(sparse=False), dict(sparse=True, algorithm="fedadam")):
        t1 = _trainer(shard_ds, **dict(kw))
        t2 = _trainer(shard_ds, mesh=make_cohort_mesh(), **dict(kw))
        l1 = [t1.run_round() for _ in range(3)]
        l2 = [t2.run_round() for _ in range(3)]
        np.testing.assert_allclose(l2, l1, rtol=1e-5)
        _assert_tree_close(t1.state.params, t2.state.params)


def test_trainer_mesh_conflicts_rejected(shard_ds):
    with pytest.raises(ValueError, match="central"):
        _trainer(shard_ds, mesh=make_cohort_mesh(), algorithm="central")


def test_sharded_debug_checks_parity():
    """The checkify sanitizer (RoundPlan.debug_checks) crosses shard_map:
    the sharded round with checks on is bit-identical to checks off."""
    from repro.analysis.sanitize import checked_jit
    from repro.core.algorithms import ServerState
    from repro.federated import build_round_step

    params = _params()
    fed = FedConfig(num_clients=16, clients_per_round=8, local_iters=2,
                    lr=0.1, algorithm="fedsubavg")
    base = dataclasses.replace(
        resolve_plan("sparse_replicated", fed),
        sharding=CohortSharding(make_cohort_mesh()))
    plain = jax.jit(build_round_step(base, lstm_loss, params, fed))
    dbg = checked_jit(build_round_step(
        dataclasses.replace(base, debug_checks=True), lstm_loss, params, fed))
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    b = _cohort_batch(0, k=8)
    s1, m1 = plain(state, b)
    s2, m2 = dbg(state, b)
    assert float(m1["loss"]) == float(m2["loss"])
    for a, c in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
