"""Logical axes, rules, and spec construction."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.context import spec_for_axes
from repro.sharding.logical import Param, ParamFactory, axes_tree, boxed_like, unbox
from repro.sharding.rules import make_rules


def test_param_is_transparent_pytree():
    p = {"a": Param(jnp.ones((2, 3)), ("vocab", "embed"))}
    doubled = jax.tree.map(lambda x: x * 2, p)
    assert isinstance(doubled["a"], Param)
    assert doubled["a"].axes == ("vocab", "embed")
    np.testing.assert_allclose(doubled["a"].value, 2.0)


def test_grad_through_boxes():
    p = {"a": Param(jnp.ones((2,)), ("vocab",))}

    def loss(tree):
        v = unbox(tree)
        return (v["a"] ** 2).sum()

    g = jax.grad(loss)(p)
    assert isinstance(g["a"], Param)
    assert g["a"].axes == ("vocab",)
    np.testing.assert_allclose(g["a"].value, 2.0)


def test_param_factory_abstract_and_stack():
    pf = ParamFactory(abstract=True, dtype=jnp.bfloat16)
    p = pf((4, 8), ("embed", "ffn"), stack=3)
    assert p.value.shape == (3, 4, 8)
    assert p.axes == ("layers", "embed", "ffn")
    assert isinstance(p.value, jax.ShapeDtypeStruct)


def test_boxed_like_roundtrip():
    pf = ParamFactory(rng=jax.random.PRNGKey(0))
    tmpl = {"w": pf((2, 2), ("embed", "ffn"))}
    vals = unbox(tmpl)
    back = boxed_like(vals, tmpl)
    assert back["w"].axes == ("embed", "ffn")


def test_rules_and_specs():
    r = make_rules("train")
    assert spec_for_axes(("vocab", "embed"), r) == P("model", None)
    assert spec_for_axes(("layers", "embed", "ffn"), r) == P(None, None, "model")
    r_mp = make_rules("train", multi_pod=True)
    assert spec_for_axes(("batch", None), r_mp) == P(("pod", "data"), None)
    r_dec = make_rules("decode")
    assert spec_for_axes(("kv_seq",), r_dec) == P("model")
    r_train = make_rules("train")
    assert spec_for_axes(("kv_seq",), r_train) == P(None)
    r_ep = make_rules("train", expert_parallel=True)
    assert spec_for_axes(("experts", "embed", "ffn"), r_ep)[0] == "model"


def test_fit_spec_replicates_indivisible():
    import os
    from repro.launch.shardings import _fit_spec
    # build a tiny fake mesh over 1 device: every axis size 1 -> all divisible
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = _fit_spec(mesh, P("model", "data"), (51866, 1280))
    assert spec == P("model", "data")     # axis size 1 divides everything
